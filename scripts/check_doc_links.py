#!/usr/bin/env python3
"""Verify every relative link in the repo's Markdown files resolves.

Scans all tracked *.md files (git ls-files) for inline links/images
(`[text](target)`) and reference definitions (`[label]: target`),
skips absolute URLs (http/https/mailto) and pure in-page anchors
(`#...`), strips `#fragment` suffixes, and checks that the remaining
path exists relative to the file that links it.

Exit status: 0 when every link resolves, 1 otherwise (one line per
broken link: `file:line: broken link -> target`). Run from anywhere
inside the repo; CI runs it from the repo root.
"""

import re
import subprocess
import sys
from pathlib import Path

# Inline links/images: [text](target) — target taken up to the first
# unescaped ')', tolerating titles: [t](path "title").
INLINE = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)\s>]+)>?(?:\s+\"[^\"]*\")?\s*\)")
# Reference definitions at line start: [label]: target
REFDEF = re.compile(r"^\s{0,3}\[[^\]]+\]:\s+<?(\S+?)>?(?:\s+\"[^\"]*\")?\s*$")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "ftp://", "#")


def tracked_markdown(root: Path) -> list[Path]:
    out = subprocess.run(
        ["git", "ls-files", "*.md", "**/*.md"],
        cwd=root,
        capture_output=True,
        text=True,
        check=True,
    )
    return [root / line for line in out.stdout.splitlines() if line]


def iter_links(text: str):
    fenced = False
    for lineno, line in enumerate(text.splitlines(), start=1):
        # Links inside fenced code blocks are examples, not navigation.
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        if fenced:
            continue
        m = REFDEF.match(line)
        if m:
            yield lineno, m.group(1)
            continue
        for m in INLINE.finditer(line):
            yield lineno, m.group(1)


def main() -> int:
    root = Path(
        subprocess.run(
            ["git", "rev-parse", "--show-toplevel"],
            capture_output=True,
            text=True,
            check=True,
        ).stdout.strip()
    )
    broken = []
    checked = 0
    for md in tracked_markdown(root):
        text = md.read_text(encoding="utf-8")
        for lineno, target in iter_links(text):
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            checked += 1
            if not resolved.exists():
                broken.append(f"{md.relative_to(root)}:{lineno}: broken link -> {target}")
    for line in broken:
        print(line, file=sys.stderr)
    print(f"checked {checked} relative link(s) in tracked markdown: "
          f"{'OK' if not broken else f'{len(broken)} broken'}")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
