#!/usr/bin/env python3
"""Merge a fresh bench run into its committed BENCH_*.json baseline.

Two record kinds share the same gate-then-merge lifecycle, told apart
by the record's "bench" field:

wire ("bench": "wire", from `cargo bench --bench wire`):
  * For every (encoding, mode) cell present in both files, if the new
    `p99_e2e_3g_ms` is more than GATE (20%) worse than the baseline's,
    the merge FAILS (exit 1) and the baseline is left untouched.
  * Byte counts are deterministic codec identities, so a change there
    is a wire-format change, not noise: any drift beyond 1% also fails.
  * The run's q8+pipelined vs raw+lockstep bytes-cut ratio must hold
    its >= 3.5x acceptance bar.

scenario ("bench": "scenario", from `branchyserve scenario run`):
  * The run's own SLO verdict must be a pass — a scenario that failed
    its assertions is not a baseline candidate.
  * If the baseline is measured and describes the same scenario, a
    `totals.p99_ms` more than GATE (20%) worse fails the merge.

serve ("bench": "serve", from `cargo bench --bench serve`):
  * For every front-end mode present in both files (thread-per-conn,
    reactor), req/s is higher-is-better: a new `req_per_s` below
    (1 - GATE) of the baseline's fails the merge.
  * A full (non-smoke) run must hold the reactor's >= 2x req/s
    acceptance bar over thread-per-conn (`derived.reactor_speedup`);
    smoke runs are too small for the ratio to mean anything.

joint ("bench": "joint", from `cargo bench --bench fig_joint`):
  * Run-intrinsic bars, checked whatever the baseline: the joint
    (encoding x split) plan must never lose to the fixed plan on any
    grid cell (`derived.joint_never_loses` and per-cell joint_ms <=
    fixed_ms) and must strictly beat it on at least one
    (`derived.cells_strictly_better` >= 1).
  * For every (mbps, p) cell present in both files, a new `joint_ms`
    more than GATE (20%) worse than the baseline's fails the merge.

ktier ("bench": "ktier", from `cargo bench --bench ktier`):
  * Run-intrinsic bars: the three-tier chain plan must never lose to
    the best two-tier plan on any cell (`derived.three_tier_never_loses`
    and per-cell three_ms <= two_ms) and must strictly beat it on at
    least one (`derived.cells_strictly_better` >= 1) — the two-tier
    space embeds in the chain's, so a loss is a planner bug.
  * For every mbps cell present in both files, a new `three_ms` more
    than GATE (20%) worse than the baseline's fails the merge.

Either kind: baselines whose `source` is not "measured" (seed baselines
are derived from the timing/codec model, marked "model") never gate —
the first measured run simply replaces them.

On success the new run becomes the baseline and the previous
baseline's p99 figures are kept under `previous` for one-step history.

Usage:
    python3 scripts/bench_record.py [--baseline BENCH_wire.json]
                                    [--run BENCH_wire.json] [--check]

With --check, gates only: reports pass/fail without rewriting the
baseline (what CI runs on pull requests). Exit status: 0 on pass,
1 on regression or malformed input.
"""

import argparse
import json
import sys
from pathlib import Path

GATE = 0.20  # fail if p99 regresses by more than this fraction
BYTE_DRIFT = 0.01  # bytes are deterministic; >1% drift is a format change
KINDS = ("wire", "scenario", "serve", "joint", "ktier")
SERVE_SPEEDUP_BAR = 2.0  # reactor vs thread-per-conn req/s, full runs only


def cell_key(run: dict) -> tuple[str, str]:
    return (run["encoding"], run["mode"])


def load(path: Path) -> dict:
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        sys.exit(f"bench_record: cannot read {path}: {e}")
    kind = doc.get("bench")
    if kind not in KINDS:
        sys.exit(f"bench_record: {path} is not a bench record (kinds: {KINDS})")
    if kind in ("wire", "serve") and not isinstance(doc.get("runs"), list):
        sys.exit(f"bench_record: {path} is not a {kind}-bench record")
    if kind in ("joint", "ktier") and not isinstance(doc.get("cells"), list):
        sys.exit(f"bench_record: {path} is not a {kind}-bench record")
    return doc


def gate_wire(baseline: dict, run: dict) -> list[str]:
    """Return a list of human-readable regression findings (empty = pass)."""
    if baseline.get("source") != "measured":
        return []  # seed baseline is modeled, not measured: never gates
    if baseline.get("smoke") != run.get("smoke"):
        return []  # smoke and full traces are not comparable
    base_cells = {cell_key(r): r for r in baseline["runs"]}
    findings = []
    for new in run["runs"]:
        old = base_cells.get(cell_key(new))
        if old is None:
            continue
        name = "{}+{}".format(*cell_key(new))
        old_p99, new_p99 = old["p99_e2e_3g_ms"], new["p99_e2e_3g_ms"]
        if new_p99 > old_p99 * (1.0 + GATE):
            findings.append(
                f"{name}: p99 e2e @3G regressed {old_p99:.3f} -> {new_p99:.3f} ms "
                f"(+{(new_p99 / old_p99 - 1.0) * 100.0:.0f}%, gate {GATE * 100:.0f}%)"
            )
        old_b, new_b = old["bytes_sent_per_request"], new["bytes_sent_per_request"]
        if abs(new_b - old_b) > old_b * BYTE_DRIFT:
            findings.append(
                f"{name}: bytes/req drifted {old_b:.1f} -> {new_b:.1f} "
                "(deterministic codec identity: this is a wire-format change)"
            )
    return findings


def gate_scenario(baseline: dict, run: dict) -> list[str]:
    findings = []
    name = run.get("scenario", "?")
    if not run.get("slo", {}).get("pass", False):
        failed = [
            c.get("name", "?")
            for c in run.get("slo", {}).get("checks", [])
            if not c.get("pass", False)
        ]
        findings.append(
            f"scenario '{name}': SLO verdict is FAIL ({', '.join(failed) or 'no checks'})"
        )
    if baseline.get("source") != "measured":
        return findings  # seed baseline is modeled, not measured: never gates
    if baseline.get("scenario") != name:
        return findings  # different scenarios are not comparable
    old_p99 = baseline.get("totals", {}).get("p99_ms")
    new_p99 = run.get("totals", {}).get("p99_ms")
    if old_p99 and new_p99 and new_p99 > old_p99 * (1.0 + GATE):
        findings.append(
            f"scenario '{name}': virtual p99 regressed {old_p99:.3f} -> "
            f"{new_p99:.3f} ms "
            f"(+{(new_p99 / old_p99 - 1.0) * 100.0:.0f}%, gate {GATE * 100:.0f}%)"
        )
    return findings


def gate_serve(baseline: dict, run: dict) -> list[str]:
    """req/s is higher-is-better; modes are compared independently."""
    if baseline.get("source") != "measured":
        return []  # seed baseline is modeled, not measured: never gates
    if baseline.get("smoke") != run.get("smoke"):
        return []  # smoke and full fleets are not comparable
    base_modes = {r["mode"]: r for r in baseline["runs"]}
    findings = []
    for new in run["runs"]:
        old = base_modes.get(new["mode"])
        if old is None:
            continue
        old_rps, new_rps = old["req_per_s"], new["req_per_s"]
        if new_rps < old_rps * (1.0 - GATE):
            findings.append(
                f"{new['mode']}: req/s regressed {old_rps:.1f} -> {new_rps:.1f} "
                f"(-{(1.0 - new_rps / old_rps) * 100.0:.0f}%, gate {GATE * 100:.0f}%)"
            )
    return findings


def gate_joint(baseline: dict, run: dict) -> list[str]:
    """The joint plan may never lose to the fixed one; joint_ms gates."""
    findings = []
    derived = run.get("derived", {})
    if not derived.get("joint_never_loses", False):
        findings.append("derived.joint_never_loses is false: joint lost somewhere")
    if derived.get("cells_strictly_better", 0) < 1:
        findings.append("joint search found no strict win on the whole grid")
    for c in run["cells"]:
        if c["joint_ms"] > c["fixed_ms"]:
            findings.append(
                f"cell ({c['mbps']} Mbps, p={c['p']}): joint {c['joint_ms']:.3f} ms "
                f"lost to the fixed plan's {c['fixed_ms']:.3f} ms"
            )
    if baseline.get("source") != "measured":
        return findings  # seed baseline is modeled, not measured: never gates
    if baseline.get("smoke") != run.get("smoke"):
        return findings  # smoke and full grids are not comparable
    base_cells = {(c["mbps"], c["p"]): c for c in baseline["cells"]}
    for new in run["cells"]:
        old = base_cells.get((new["mbps"], new["p"]))
        if old is None:
            continue
        old_ms, new_ms = old["joint_ms"], new["joint_ms"]
        if new_ms > old_ms * (1.0 + GATE):
            findings.append(
                f"cell ({new['mbps']} Mbps, p={new['p']}): joint E[T] regressed "
                f"{old_ms:.3f} -> {new_ms:.3f} ms "
                f"(+{(new_ms / old_ms - 1.0) * 100.0:.0f}%, gate {GATE * 100:.0f}%)"
            )
    return findings


def gate_ktier(baseline: dict, run: dict) -> list[str]:
    """The chain may never lose to the best two-tier plan; three_ms gates."""
    findings = []
    derived = run.get("derived", {})
    if not derived.get("three_tier_never_loses", False):
        findings.append(
            "derived.three_tier_never_loses is false: the chain lost somewhere"
        )
    if derived.get("cells_strictly_better", 0) < 1:
        findings.append("the chain found no strict win on the whole grid")
    for c in run["cells"]:
        if c["three_ms"] > c["two_ms"]:
            findings.append(
                f"cell ({c['mbps']} Mbps): three-tier {c['three_ms']:.3f} ms "
                f"lost to the two-tier plan's {c['two_ms']:.3f} ms"
            )
    if baseline.get("source") != "measured":
        return findings  # seed baseline is modeled, not measured: never gates
    if baseline.get("smoke") != run.get("smoke"):
        return findings  # smoke and full grids are not comparable
    base_cells = {c["mbps"]: c for c in baseline["cells"]}
    for new in run["cells"]:
        old = base_cells.get(new["mbps"])
        if old is None:
            continue
        old_ms, new_ms = old["three_ms"], new["three_ms"]
        if new_ms > old_ms * (1.0 + GATE):
            findings.append(
                f"cell ({new['mbps']} Mbps): three-tier E[T] regressed "
                f"{old_ms:.3f} -> {new_ms:.3f} ms "
                f"(+{(new_ms / old_ms - 1.0) * 100.0:.0f}%, gate {GATE * 100:.0f}%)"
            )
    return findings


def previous_of(baseline: dict) -> dict:
    if baseline.get("bench") == "scenario":
        return {
            "source": baseline.get("source"),
            "p99_ms": baseline.get("totals", {}).get("p99_ms"),
        }
    if baseline.get("bench") == "serve":
        return {
            "source": baseline.get("source"),
            "req_per_s": {r["mode"]: r["req_per_s"] for r in baseline["runs"]},
        }
    if baseline.get("bench") == "joint":
        return {
            "source": baseline.get("source"),
            "joint_ms": {
                f"{c['mbps']}@{c['p']}": c["joint_ms"] for c in baseline["cells"]
            },
        }
    if baseline.get("bench") == "ktier":
        return {
            "source": baseline.get("source"),
            "three_ms": {str(c["mbps"]): c["three_ms"] for c in baseline["cells"]},
        }
    return {
        "source": baseline.get("source"),
        "p99_e2e_3g_ms": {
            "{}+{}".format(*cell_key(r)): r["p99_e2e_3g_ms"]
            for r in baseline["runs"]
        },
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", type=Path, default=Path("BENCH_wire.json"))
    ap.add_argument("--run", type=Path, default=Path("BENCH_wire.json"))
    ap.add_argument(
        "--check",
        action="store_true",
        help="gate only; do not rewrite the baseline",
    )
    args = ap.parse_args()

    run = load(args.run)
    if args.baseline.resolve() == args.run.resolve():
        # The bench overwrote the baseline in place: the freshly written
        # file IS the run, so there is nothing older to gate against.
        # Still validate the run's own acceptance bars.
        baseline = run
    else:
        baseline = load(args.baseline)
        if baseline.get("bench") != run.get("bench"):
            sys.exit(
                "bench_record: baseline is a {} record but the run is a {}".format(
                    baseline.get("bench"), run.get("bench")
                )
            )

    if run.get("bench") == "scenario":
        findings = gate_scenario(baseline, run)
    elif run.get("bench") == "joint":
        findings = gate_joint(baseline, run)
    elif run.get("bench") == "ktier":
        findings = gate_ktier(baseline, run)
    elif run.get("bench") == "serve":
        findings = gate_serve(baseline, run)
        speedup = run.get("derived", {}).get("reactor_speedup")
        if not run.get("smoke") and speedup is not None and speedup < SERVE_SPEEDUP_BAR:
            findings.append(
                f"reactor speedup over thread-per-conn is {speedup:.2f}x "
                f"(< {SERVE_SPEEDUP_BAR:.1f}x bar)"
            )
    else:
        findings = gate_wire(baseline, run)
        ratio = run.get("derived", {}).get(
            "bytes_cut_q8_pipelined_vs_raw_lockstep", 0.0
        )
        if ratio < 3.5:
            findings.append(
                f"q8+pipelined bytes cut vs raw+lockstep is {ratio:.2f}x (< 3.5x bar)"
            )

    for f in findings:
        print(f"REGRESSION: {f}", file=sys.stderr)
    if findings:
        return 1

    if not args.check and args.baseline.resolve() != args.run.resolve():
        merged = dict(run)
        merged["previous"] = previous_of(baseline)
        args.baseline.write_text(json.dumps(merged, indent=2) + "\n")
        print(f"bench_record: baseline {args.baseline} updated")
    else:
        print("bench_record: gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
