#!/usr/bin/env python3
"""Verify Cargo.toml's explicit target lists cover rust/tests and rust/benches.

The manifest sets autotests/autobenches = false, so a test or bench
file that is not registered with an explicit [[test]]/[[bench]] entry
silently never runs — CI stays green while the suite shrinks. This
check is bidirectional:

  * every tracked rust/tests/*.rs has a [[test]] entry whose `path`
    points at it, and every tracked rust/benches/*.rs (shared helper
    modules under rust/benches/common/ excluded) has a [[bench]] entry;
  * every [[test]]/[[bench]] `path` under those directories points at a
    file that exists (a rename must not strand a stale entry).

Exit status: 0 when the lists match, 1 otherwise (one line per
mismatch). Run from anywhere inside the repo; CI runs it from the root.
"""

import re
import subprocess
import sys
from pathlib import Path

# One explicit target block: [[test]] / [[bench]] followed by its
# key = "value" lines (name/path/harness) up to the next section.
TARGET = re.compile(
    r"^\[\[(test|bench)\]\]\s*$(?P<body>(?:\n(?!\[).*)*)", re.MULTILINE
)
PATH_KEY = re.compile(r'^\s*path\s*=\s*"([^"]+)"\s*$', re.MULTILINE)


def tracked(root: Path, pattern: str) -> set[str]:
    out = subprocess.run(
        ["git", "ls-files", pattern],
        cwd=root,
        capture_output=True,
        text=True,
        check=True,
    )
    return {line for line in out.stdout.splitlines() if line}


def declared_paths(manifest_text: str) -> dict[str, set[str]]:
    found: dict[str, set[str]] = {"test": set(), "bench": set()}
    for m in TARGET.finditer(manifest_text):
        kind = m.group(1)
        paths = PATH_KEY.findall(m.group("body"))
        if len(paths) != 1:
            print(
                f"Cargo.toml: [[{kind}]] block without exactly one path key",
                file=sys.stderr,
            )
            sys.exit(1)
        found[kind].add(paths[0])
    return found


def main() -> int:
    root = Path(
        subprocess.run(
            ["git", "rev-parse", "--show-toplevel"],
            capture_output=True,
            text=True,
            check=True,
        ).stdout.strip()
    )
    declared = declared_paths((root / "Cargo.toml").read_text(encoding="utf-8"))
    tests = tracked(root, "rust/tests/*.rs")
    benches = {
        p for p in tracked(root, "rust/benches/**/*.rs") | tracked(root, "rust/benches/*.rs")
        if not p.startswith("rust/benches/common/")
    }

    problems = []
    for path in sorted(tests - declared["test"]):
        problems.append(f"{path}: no [[test]] entry in Cargo.toml — it never runs")
    for path in sorted(benches - declared["bench"]):
        problems.append(f"{path}: no [[bench]] entry in Cargo.toml — it never runs")
    for path in sorted(declared["test"] - tests):
        if path.startswith("rust/tests/"):
            problems.append(f"Cargo.toml: [[test]] path {path} does not exist")
    for path in sorted(declared["bench"] - benches):
        if path.startswith("rust/benches/"):
            problems.append(f"Cargo.toml: [[bench]] path {path} does not exist")

    for line in problems:
        print(line, file=sys.stderr)
    print(
        f"checked {len(tests)} test file(s) and {len(benches)} bench file(s) "
        f"against Cargo.toml: {'OK' if not problems else f'{len(problems)} problem(s)'}"
    )
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
