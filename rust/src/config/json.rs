//! Recursive-descent JSON parser and serializer (RFC 8259 subset: full
//! value grammar, `\uXXXX` escapes incl. surrogate pairs, no comments).
//!
//! Exists because serde/serde_json are not in the offline vendor set; used
//! to read `artifacts/manifest.json`, profiles, and the server's control
//! messages, and to write reports.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ------------------------------------------------------------- access

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")` — dotted descent.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= u64::MAX as f64 => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Vec of u64 from a numeric array.
    pub fn as_u64_vec(&self) -> Option<Vec<u64>> {
        self.as_arr()?.iter().map(Json::as_u64).collect()
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }

    // -------------------------------------------------------- construction

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // -------------------------------------------------------------- parse

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ---------------------------------------------------------- serialize

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    out.push_str(&format!("{}", *v as i64));
                } else {
                    out.push_str(&format!("{v}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(ind + 1));
                        item.write(out, Some(ind + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    out.push_str(&"  ".repeat(ind));
                }
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(ind + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(ind + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    out.push_str(&"  ".repeat(ind));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    /// Compact form.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        s.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let len = utf8_len(c).ok_or_else(|| self.err("bad utf8"))?;
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("bad number '{text}'")))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.path("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
        // Raw multibyte UTF-8 passthrough:
        let v = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "\"\\q\"", "{a:1}"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"b":[1,2.5,true,null],"a":{"x":"y"},"s":"q\"uote"}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn accessor_types() {
        let v = Json::parse(r#"{"n": 3, "f": 3.5, "neg": -1, "arr": [1,2,3]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("f").unwrap().as_u64(), None);
        assert_eq!(v.get("neg").unwrap().as_u64(), None);
        assert_eq!(v.get("arr").unwrap().as_u64_vec(), Some(vec![1, 2, 3]));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "stages": [
            {"index": 1, "name": "conv1", "out_bytes_per_sample": 57600,
             "artifacts": {"pl": {"1": "a.hlo.txt"}}}
          ],
          "batch_sizes": [1, 4, 8]
        }"#;
        let v = Json::parse(src).unwrap();
        let s0 = &v.get("stages").unwrap().as_arr().unwrap()[0];
        assert_eq!(s0.path("artifacts.pl.1").unwrap().as_str(), Some("a.hlo.txt"));
        assert_eq!(v.get("batch_sizes").unwrap().as_u64_vec(), Some(vec![1, 4, 8]));
    }
}
