//! Typed settings: defaults <- config file (TOML) <- CLI overrides.
//!
//! Every knob the coordinator, partitioner, network model and server
//! expose lives here, with validation at load time so a bad config fails
//! fast instead of mid-serve.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::json::Json;
use super::toml;
use crate::network::encoding::WireEncoding;

/// Which kernel flavor of the artifacts to execute (DESIGN.md: both are
/// exported; 'pl' is the Pallas-lowered path, 'ref' the XLA-fused one).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flavor {
    Pallas,
    Ref,
}

impl Flavor {
    pub fn as_str(&self) -> &'static str {
        match self {
            Flavor::Pallas => "pl",
            Flavor::Ref => "ref",
        }
    }

    pub fn parse(s: &str) -> Result<Flavor> {
        match s {
            "pl" | "pallas" => Ok(Flavor::Pallas),
            "ref" => Ok(Flavor::Ref),
            _ => bail!("unknown flavor '{s}' (expected 'pl' or 'ref')"),
        }
    }
}

/// Partitioning strategy selector (solver = the paper's contribution;
/// the rest are baselines from §II / §VI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// G'_BDNN + Dijkstra (the paper).
    ShortestPath,
    /// Exhaustive evaluation of Eq. 6 over every split point.
    BruteForce,
    /// Branch-blind partitioning (Neurosurgeon [3]): p = 0 everywhere.
    Neurosurgeon,
    /// All layers on the edge device.
    EdgeOnly,
    /// All layers in the cloud.
    CloudOnly,
}

impl Strategy {
    pub fn as_str(&self) -> &'static str {
        match self {
            Strategy::ShortestPath => "shortest-path",
            Strategy::BruteForce => "brute-force",
            Strategy::Neurosurgeon => "neurosurgeon",
            Strategy::EdgeOnly => "edge-only",
            Strategy::CloudOnly => "cloud-only",
        }
    }

    pub fn parse(s: &str) -> Result<Strategy> {
        match s {
            "shortest-path" | "sp" | "paper" => Ok(Strategy::ShortestPath),
            "brute-force" | "brute" => Ok(Strategy::BruteForce),
            "neurosurgeon" => Ok(Strategy::Neurosurgeon),
            "edge-only" | "edge" => Ok(Strategy::EdgeOnly),
            "cloud-only" | "cloud" => Ok(Strategy::CloudOnly),
            _ => bail!("unknown strategy '{s}'"),
        }
    }
}

/// Validate a `HOST:PORT` endpoint string — shared by the TOML
/// (`[fleet] cloud_addr`) and CLI (`--cloud-addr`) paths so a typo
/// fails fast on both instead of silently degrading to local-only
/// serving.
pub fn validate_host_port(addr: &str) -> Result<()> {
    match addr.rsplit_once(':') {
        // Port 0 is "pick one for me" on a listener; as a *target* it
        // can never be connected to, so reject it here too.
        Some((host, port))
            if !host.is_empty() && matches!(port.parse::<u16>(), Ok(p) if p != 0) =>
        {
            Ok(())
        }
        _ => bail!("expected HOST:PORT; got '{addr}'"),
    }
}

#[derive(Debug, Clone)]
pub struct ModelSettings {
    pub artifacts_dir: PathBuf,
    pub flavor: Flavor,
}

#[derive(Debug, Clone)]
pub struct NetworkSettings {
    /// Named profile: "3g", "4g", "wifi", or "custom".
    pub kind: String,
    /// Uplink rate in Mbps (used when kind == "custom"; named profiles
    /// carry the paper's rates).
    pub uplink_mbps: f64,
    /// One-way base latency added per transfer, seconds.
    pub rtt_s: f64,
    /// Optional bandwidth trace file (CSV: t_seconds,mbps) for re-planning.
    pub trace: Option<PathBuf>,
}

#[derive(Debug, Clone)]
pub struct EdgeSettings {
    /// Processing factor gamma: t_e = gamma * t_c (paper §VI).
    pub gamma: f64,
}

#[derive(Debug, Clone)]
pub struct BranchSettings {
    /// Entropy threshold (nats) below which a sample exits at b1.
    pub entropy_threshold: f64,
    /// Exit-probability override for planning; `None` = measure/assume.
    pub exit_probability: Option<f64>,
}

#[derive(Debug, Clone)]
pub struct PartitionSettings {
    pub strategy: Strategy,
    /// The paper's epsilon disambiguation weight on the (v*c, output) link.
    pub epsilon: f64,
}

/// `[planner]`: joint configuration search (branch placement ×
/// partition × precision) — whether classes run it when they (re)plan,
/// and the accuracy floor it must respect.
#[derive(Debug, Clone)]
pub struct PlannerSettings {
    /// Run `Planner::plan_joint` at class startup: keep the class's
    /// branch set but adopt the (wire encoding, split) pair that
    /// minimizes expected time at the class link. Per-class
    /// `joint_search` overrides this.
    pub joint_search: bool,
    /// Minimum final survival mass `Π (1 − p_k)` a candidate branch
    /// set must keep — the joint search may never buy latency below
    /// this accuracy proxy. 0 disables the floor.
    pub min_accuracy_proxy: f64,
}

#[derive(Debug, Clone)]
pub struct ServeSettings {
    pub port: u16,
    /// Dynamic batcher: max batch size (must be an exported batch size).
    pub max_batch: usize,
    /// Dynamic batcher: flush deadline.
    pub batch_timeout_ms: f64,
    /// Admission queue capacity (backpressure beyond this).
    pub queue_capacity: usize,
}

/// `[fleet]`: how many pipelines the serving path fans out to, and the
/// per-request / feedback planning knobs.
#[derive(Debug, Clone)]
pub struct FleetSettings {
    /// Edge/cloud pipeline pairs per link class.
    pub shards: usize,
    /// Cloud worker threads per shard.
    pub cloud_workers: usize,
    /// Shard routing policy: "round-robin" | "hash" | "least-loaded".
    pub routing: String,
    /// Solve each request's split at the class channel's instantaneous
    /// link estimate (plan override per sample) instead of only at
    /// adaptive-replan boundaries.
    pub per_request_planning: bool,
    /// Track each class's observed exit rate and re-derive its planner
    /// view when the estimate drifts.
    pub online_estimation: bool,
    /// Absolute |p̂ − p_planned| drift that triggers a view rebuild
    /// (only meaningful with `online_estimation`).
    pub drift_threshold: f64,
    /// Exit-rate probing: fraction of per-request overrides rerouted
    /// through a branch-active split so the estimator keeps observing
    /// when the executed split has the branch inactive. Requires
    /// `per_request_planning`; 0 disables.
    pub probe_fraction: f64,
    /// `HOST:PORT` of a remote cloud-stage server (`branchyserve
    /// cloud-serve`). When set, the serving fleet ships transferred
    /// activations there instead of running cloud stages in-process.
    pub cloud_addr: Option<String>,
    /// Activation wire encoding for remote cloud offload: `raw` (f32,
    /// bit-exact), `q8` (8-bit linear quantization, 4x smaller) or `q4`
    /// (4-bit, ~8x smaller). The planner prices transfers at this
    /// encoding's wire size, so changing it can move the optimal split.
    pub wire_encoding: WireEncoding,
    /// Grow/shrink each class's shard group from observed load
    /// (queue depth, admission rejections) between
    /// `min_shards..=max_shards`; `shards` is the starting size.
    pub autoscale: bool,
    /// Autoscale floor (>= 1).
    pub min_shards: usize,
    /// Autoscale ceiling (<= 64).
    pub max_shards: usize,
    /// Mean admission-queue depth per shard that triggers a scale-up.
    pub scale_up_depth: f64,
    /// Mean depth per shard below which an idle class scales down
    /// (must be < scale_up_depth; the gap is the hysteresis band).
    pub scale_down_depth: f64,
    /// Control-loop sampling tick, milliseconds.
    pub scale_interval_ms: f64,
    /// Samples aggregated into one scaling decision.
    pub scale_window: usize,
    /// Minimum time between two resizes of one class, milliseconds.
    pub scale_cooldown_ms: f64,
    /// Fleet-wide shard budget: the sum of live shards across every
    /// class may never exceed this. `None` = unbounded. Grows that
    /// would bust the budget are denied (the class's `last_trigger`
    /// records the budget denial).
    pub max_total_shards: Option<usize>,
    /// Serve with the event-driven epoll front end (Linux; elsewhere
    /// the portable thread-per-connection path runs with a warning).
    pub reactor: bool,
    /// Reactor event-loop threads (>= 1).
    pub reactor_threads: usize,
    /// Accept-time connection cap on both serving paths; connections
    /// over it are answered one THROTTLE frame and closed. 0 = no cap.
    pub max_conns: usize,
    /// Per-connection in-flight request window on the reactor path
    /// (>= 1); frames past it are answered THROTTLE without touching
    /// admission.
    pub conn_window: usize,
}

impl FleetSettings {
    /// Assemble the autoscaler's config from the `[fleet]` knobs,
    /// validating as it goes (millisecond fields must be checked before
    /// they become `Duration`s — a negative would panic there). Callers
    /// gate on `self.autoscale` themselves; the CLI overlays
    /// `--min-shards`/`--max-shards` on the result.
    pub fn autoscale_config(&self) -> Result<crate::fleet::AutoscaleConfig> {
        if !(self.scale_interval_ms.is_finite() && self.scale_interval_ms > 0.0) {
            bail!(
                "fleet.scale_interval_ms must be positive and finite; got {}",
                self.scale_interval_ms
            );
        }
        if !(self.scale_cooldown_ms.is_finite() && self.scale_cooldown_ms >= 0.0) {
            bail!(
                "fleet.scale_cooldown_ms must be non-negative and finite; got {}",
                self.scale_cooldown_ms
            );
        }
        let cfg = crate::fleet::AutoscaleConfig {
            min_shards: self.min_shards,
            max_shards: self.max_shards,
            scale_up_depth: self.scale_up_depth,
            scale_down_depth: self.scale_down_depth,
            interval: std::time::Duration::from_secs_f64(self.scale_interval_ms / 1e3),
            window: self.scale_window,
            cooldown: std::time::Duration::from_secs_f64(self.scale_cooldown_ms / 1e3),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// One `[[link_class]]` entry: a named client population with its own
/// uplink (and hence its own partition plan).
#[derive(Debug, Clone)]
pub struct LinkClassSettings {
    pub name: String,
    pub uplink_mbps: f64,
    pub rtt_s: f64,
    /// Planning exit-probability override for this class.
    pub exit_probability: Option<f64>,
    /// Per-class cloud-stage server override (`HOST:PORT`); `None`
    /// falls back to the fleet-wide `fleet.cloud_addr`.
    pub cloud_addr: Option<String>,
    /// Per-class autoscale floor override; `None` falls back to
    /// `fleet.min_shards`.
    pub min_shards: Option<usize>,
    /// Per-class autoscale ceiling override; `None` falls back to
    /// `fleet.max_shards`.
    pub max_shards: Option<usize>,
    /// Per-class joint-search override; `None` falls back to
    /// `planner.joint_search`.
    pub joint_search: Option<bool>,
}

#[derive(Debug, Clone)]
pub struct Settings {
    pub model: ModelSettings,
    pub network: NetworkSettings,
    pub edge: EdgeSettings,
    pub branch: BranchSettings,
    pub partition: PartitionSettings,
    pub planner: PlannerSettings,
    pub serve: ServeSettings,
    pub fleet: FleetSettings,
    /// Empty = a single default class derived from `network`.
    pub link_classes: Vec<LinkClassSettings>,
    /// `[[tier]]` entries: a K-tier partition chain beyond the edge, in
    /// order from the chain head the edge ships to, down to the
    /// terminal tier. Empty = no chain (the cloud half is
    /// `fleet.cloud_addr`, or in-process). Non-terminal entries carry
    /// `uplink_mbps`/`rtt_ms` describing their hop to the *next* tier;
    /// hop 0 — edge to chain head — is each class's own link.
    pub tiers: Vec<crate::fleet::TierSpec>,
}

impl Default for Settings {
    fn default() -> Self {
        Settings {
            model: ModelSettings {
                artifacts_dir: PathBuf::from("artifacts"),
                flavor: Flavor::Ref,
            },
            network: NetworkSettings {
                kind: "4g".into(),
                uplink_mbps: 5.85,
                rtt_s: 0.0,
                trace: None,
            },
            edge: EdgeSettings { gamma: 100.0 },
            branch: BranchSettings {
                entropy_threshold: 0.3,
                exit_probability: None,
            },
            partition: PartitionSettings {
                strategy: Strategy::ShortestPath,
                epsilon: 1e-9,
            },
            planner: PlannerSettings {
                joint_search: false,
                min_accuracy_proxy: 0.0,
            },
            serve: ServeSettings {
                port: 7878,
                max_batch: 8,
                batch_timeout_ms: 2.0,
                queue_capacity: 1024,
            },
            fleet: FleetSettings {
                shards: 1,
                cloud_workers: 1,
                routing: "least-loaded".into(),
                per_request_planning: false,
                online_estimation: false,
                drift_threshold: 0.1,
                probe_fraction: 0.0,
                cloud_addr: None,
                wire_encoding: WireEncoding::Raw,
                autoscale: false,
                min_shards: 1,
                max_shards: 8,
                scale_up_depth: 4.0,
                scale_down_depth: 0.5,
                scale_interval_ms: 100.0,
                scale_window: 5,
                scale_cooldown_ms: 2000.0,
                max_total_shards: None,
                reactor: false,
                reactor_threads: 1,
                max_conns: 0,
                conn_window: 32,
            },
            link_classes: Vec::new(),
            tiers: Vec::new(),
        }
    }
}

impl Settings {
    /// Load defaults, then overlay a TOML config file if given.
    pub fn load(config_path: Option<&Path>) -> Result<Settings> {
        let mut s = Settings::default();
        if let Some(path) = config_path {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config {}", path.display()))?;
            let doc = toml::parse(&text)
                .with_context(|| format!("parsing config {}", path.display()))?;
            s.apply(&doc)?;
        }
        s.validate()?;
        Ok(s)
    }

    /// Overlay values from a parsed config tree onto `self`.
    pub fn apply(&mut self, doc: &Json) -> Result<()> {
        if let Some(v) = doc.path("model.artifacts_dir").and_then(Json::as_str) {
            self.model.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = doc.path("model.flavor").and_then(Json::as_str) {
            self.model.flavor = Flavor::parse(v)?;
        }
        if let Some(v) = doc.path("network.kind").and_then(Json::as_str) {
            self.network.kind = v.to_string();
        }
        if let Some(v) = doc.path("network.uplink_mbps").and_then(Json::as_f64) {
            self.network.uplink_mbps = v;
        }
        if let Some(v) = doc.path("network.rtt_ms").and_then(Json::as_f64) {
            self.network.rtt_s = v / 1e3;
        }
        if let Some(v) = doc.path("network.trace").and_then(Json::as_str) {
            self.network.trace = Some(PathBuf::from(v));
        }
        if let Some(v) = doc.path("edge.gamma").and_then(Json::as_f64) {
            self.edge.gamma = v;
        }
        if let Some(v) = doc.path("branch.entropy_threshold").and_then(Json::as_f64) {
            self.branch.entropy_threshold = v;
        }
        if let Some(v) = doc.path("branch.exit_probability").and_then(Json::as_f64) {
            self.branch.exit_probability = Some(v);
        }
        if let Some(v) = doc.path("partition.strategy").and_then(Json::as_str) {
            self.partition.strategy = Strategy::parse(v)?;
        }
        if let Some(v) = doc.path("partition.epsilon").and_then(Json::as_f64) {
            self.partition.epsilon = v;
        }
        if let Some(v) = doc.path("planner.joint_search").and_then(Json::as_bool) {
            self.planner.joint_search = v;
        }
        if let Some(v) = doc.path("planner.min_accuracy_proxy").and_then(Json::as_f64) {
            self.planner.min_accuracy_proxy = v;
        }
        if let Some(v) = doc.path("serve.port").and_then(Json::as_u64) {
            self.serve.port = u16::try_from(v).context("serve.port out of range")?;
        }
        if let Some(v) = doc.path("serve.max_batch").and_then(Json::as_usize) {
            self.serve.max_batch = v;
        }
        if let Some(v) = doc.path("serve.batch_timeout_ms").and_then(Json::as_f64) {
            self.serve.batch_timeout_ms = v;
        }
        if let Some(v) = doc.path("serve.queue_capacity").and_then(Json::as_usize) {
            self.serve.queue_capacity = v;
        }
        if let Some(v) = doc.path("fleet.shards").and_then(Json::as_usize) {
            self.fleet.shards = v;
        }
        if let Some(v) = doc.path("fleet.cloud_workers").and_then(Json::as_usize) {
            self.fleet.cloud_workers = v;
        }
        if let Some(v) = doc.path("fleet.routing").and_then(Json::as_str) {
            self.fleet.routing = v.to_string();
        }
        if let Some(v) = doc.path("fleet.per_request_planning").and_then(Json::as_bool) {
            self.fleet.per_request_planning = v;
        }
        if let Some(v) = doc.path("fleet.online_estimation").and_then(Json::as_bool) {
            self.fleet.online_estimation = v;
        }
        if let Some(v) = doc.path("fleet.drift_threshold").and_then(Json::as_f64) {
            self.fleet.drift_threshold = v;
        }
        if let Some(v) = doc.path("fleet.probe_fraction").and_then(Json::as_f64) {
            self.fleet.probe_fraction = v;
        }
        if let Some(v) = doc.path("fleet.cloud_addr").and_then(Json::as_str) {
            self.fleet.cloud_addr = Some(v.to_string());
        }
        if let Some(v) = doc.path("fleet.wire_encoding").and_then(Json::as_str) {
            self.fleet.wire_encoding =
                WireEncoding::parse(v).context("fleet.wire_encoding")?;
        }
        if let Some(v) = doc.path("fleet.autoscale").and_then(Json::as_bool) {
            self.fleet.autoscale = v;
        }
        if let Some(v) = doc.path("fleet.min_shards").and_then(Json::as_usize) {
            self.fleet.min_shards = v;
        }
        if let Some(v) = doc.path("fleet.max_shards").and_then(Json::as_usize) {
            self.fleet.max_shards = v;
        }
        if let Some(v) = doc.path("fleet.scale_up_depth").and_then(Json::as_f64) {
            self.fleet.scale_up_depth = v;
        }
        if let Some(v) = doc.path("fleet.scale_down_depth").and_then(Json::as_f64) {
            self.fleet.scale_down_depth = v;
        }
        if let Some(v) = doc.path("fleet.scale_interval_ms").and_then(Json::as_f64) {
            self.fleet.scale_interval_ms = v;
        }
        if let Some(v) = doc.path("fleet.scale_window").and_then(Json::as_usize) {
            self.fleet.scale_window = v;
        }
        if let Some(v) = doc.path("fleet.scale_cooldown_ms").and_then(Json::as_f64) {
            self.fleet.scale_cooldown_ms = v;
        }
        if let Some(v) = doc.path("fleet.max_total_shards").and_then(Json::as_usize) {
            self.fleet.max_total_shards = Some(v);
        }
        if let Some(v) = doc.path("fleet.reactor").and_then(Json::as_bool) {
            self.fleet.reactor = v;
        }
        if let Some(v) = doc.path("fleet.reactor_threads").and_then(Json::as_usize) {
            self.fleet.reactor_threads = v;
        }
        if let Some(v) = doc.path("fleet.max_conns").and_then(Json::as_usize) {
            self.fleet.max_conns = v;
        }
        if let Some(v) = doc.path("fleet.conn_window").and_then(Json::as_usize) {
            self.fleet.conn_window = v;
        }
        if let Some(arr) = doc.get("link_class").and_then(Json::as_arr) {
            self.link_classes.clear();
            for (i, entry) in arr.iter().enumerate() {
                let name = entry
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("link_class[{i}].name is required"))?
                    .to_string();
                // A bare builtin name ("3g"/"4g"/"wifi") may omit the rate.
                let builtin = crate::network::bandwidth::Profile::parse(&name).ok();
                let uplink_mbps = entry
                    .get("uplink_mbps")
                    .and_then(Json::as_f64)
                    .or_else(|| builtin.map(|p| p.uplink_mbps()))
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "link_class[{i}] ('{name}'): uplink_mbps is required for \
                             non-builtin classes"
                        )
                    })?;
                let rtt_s = entry
                    .get("rtt_ms")
                    .and_then(Json::as_f64)
                    .map(|ms| ms / 1e3)
                    .unwrap_or(0.0);
                let exit_probability = entry.get("exit_probability").and_then(Json::as_f64);
                let cloud_addr = entry
                    .get("cloud_addr")
                    .and_then(Json::as_str)
                    .map(str::to_string);
                let min_shards = entry.get("min_shards").and_then(Json::as_usize);
                let max_shards = entry.get("max_shards").and_then(Json::as_usize);
                let joint_search = entry.get("joint_search").and_then(Json::as_bool);
                self.link_classes.push(LinkClassSettings {
                    name,
                    uplink_mbps,
                    rtt_s,
                    exit_probability,
                    cloud_addr,
                    min_shards,
                    max_shards,
                    joint_search,
                });
            }
        }
        if let Some(arr) = doc.get("tier").and_then(Json::as_arr) {
            self.tiers.clear();
            for (i, entry) in arr.iter().enumerate() {
                let addr = entry
                    .get("addr")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("tier[{i}].addr is required"))?
                    .to_string();
                let uplink_mbps = entry.get("uplink_mbps").and_then(Json::as_f64);
                let rtt_s = entry
                    .get("rtt_ms")
                    .and_then(Json::as_f64)
                    .map(|ms| ms / 1e3);
                let compute_scale = entry
                    .get("compute_scale")
                    .and_then(Json::as_f64)
                    .unwrap_or(1.0);
                self.tiers.push(crate::fleet::TierSpec {
                    addr,
                    uplink_mbps,
                    rtt_s,
                    compute_scale,
                });
            }
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.edge.gamma < 1.0 {
            bail!(
                "edge.gamma must be >= 1 (edge is never faster than cloud in the \
                 paper's model); got {}",
                self.edge.gamma
            );
        }
        if self.network.uplink_mbps <= 0.0 {
            bail!("network.uplink_mbps must be > 0");
        }
        if !(0.0..=f64::ln(2.0) + 1e-9).contains(&self.branch.entropy_threshold) {
            bail!(
                "branch.entropy_threshold must be within [0, ln 2] for a binary \
                 classifier; got {}",
                self.branch.entropy_threshold
            );
        }
        if let Some(p) = self.branch.exit_probability {
            if !(0.0..=1.0).contains(&p) {
                bail!("branch.exit_probability must be in [0, 1]; got {p}");
            }
        }
        if self.partition.epsilon <= 0.0 || self.partition.epsilon > 1e-3 {
            bail!(
                "partition.epsilon must be tiny and positive (paper §V); got {}",
                self.partition.epsilon
            );
        }
        if !(self.planner.min_accuracy_proxy.is_finite()
            && (0.0..=1.0).contains(&self.planner.min_accuracy_proxy))
        {
            bail!(
                "planner.min_accuracy_proxy must be in [0, 1]; got {}",
                self.planner.min_accuracy_proxy
            );
        }
        if self.serve.max_batch == 0 || self.serve.queue_capacity == 0 {
            bail!("serve.max_batch and serve.queue_capacity must be > 0");
        }
        if self.serve.batch_timeout_ms < 0.0 {
            bail!("serve.batch_timeout_ms must be >= 0");
        }
        if !(1..=64).contains(&self.fleet.shards) {
            bail!("fleet.shards must be in 1..=64; got {}", self.fleet.shards);
        }
        if !(1..=64).contains(&self.fleet.cloud_workers) {
            bail!(
                "fleet.cloud_workers must be in 1..=64; got {}",
                self.fleet.cloud_workers
            );
        }
        if let Err(e) = crate::fleet::router::RoutePolicy::parse(&self.fleet.routing) {
            bail!("fleet.routing: {e}");
        }
        if !(self.fleet.drift_threshold > 0.0 && self.fleet.drift_threshold < 1.0) {
            bail!(
                "fleet.drift_threshold must be in (0, 1); got {}",
                self.fleet.drift_threshold
            );
        }
        if !(0.0..=1.0).contains(&self.fleet.probe_fraction) {
            bail!(
                "fleet.probe_fraction must be in [0, 1]; got {}",
                self.fleet.probe_fraction
            );
        }
        if self.fleet.probe_fraction > 0.0 && !self.fleet.per_request_planning {
            bail!(
                "fleet.probe_fraction requires fleet.per_request_planning = true \
                 (probes ride on per-request plan overrides)"
            );
        }
        if let Some(addr) = &self.fleet.cloud_addr {
            if let Err(e) = validate_host_port(addr) {
                bail!("fleet.cloud_addr: {e}");
            }
        }
        if !(1..=64).contains(&self.fleet.reactor_threads) {
            bail!(
                "fleet.reactor_threads must be in 1..=64; got {}",
                self.fleet.reactor_threads
            );
        }
        if self.fleet.conn_window == 0 {
            bail!("fleet.conn_window must be >= 1 (0 would throttle every request)");
        }
        if self.fleet.autoscale {
            let acfg = self.fleet.autoscale_config()?;
            if !(acfg.min_shards..=acfg.max_shards).contains(&self.fleet.shards) {
                bail!(
                    "fleet.shards ({}) must lie within fleet.min_shards..=fleet.max_shards \
                     ({}..={}) when fleet.autoscale is on",
                    self.fleet.shards,
                    acfg.min_shards,
                    acfg.max_shards
                );
            }
        }
        if !self.tiers.is_empty() {
            if self.tiers.len() < 2 {
                bail!(
                    "a [[tier]] chain needs at least 2 entries (a forwarding middle \
                     and a terminal); for a single remote tier use fleet.cloud_addr"
                );
            }
            if self.fleet.cloud_addr.is_some() {
                bail!(
                    "[[tier]] and fleet.cloud_addr are mutually exclusive \
                     (the chain head *is* the cloud endpoint)"
                );
            }
            for (i, t) in self.tiers.iter().enumerate() {
                if let Err(e) = validate_host_port(&t.addr) {
                    bail!("tier[{i}].addr: {e}");
                }
                if !(t.compute_scale.is_finite() && t.compute_scale > 0.0) {
                    bail!(
                        "tier[{i}] ('{}'): compute_scale must be finite and > 0; got {}",
                        t.addr,
                        t.compute_scale
                    );
                }
                if i + 1 < self.tiers.len() {
                    match (t.uplink_mbps, t.rtt_s) {
                        (Some(bw), Some(rtt))
                            if bw.is_finite()
                                && bw > 0.0
                                && rtt.is_finite()
                                && rtt >= 0.0 => {}
                        (Some(_), Some(_)) => bail!(
                            "tier[{i}] ('{}'): uplink_mbps must be positive and finite, \
                             rtt_ms non-negative and finite",
                            t.addr
                        ),
                        _ => bail!(
                            "tier[{i}] ('{}') is not the terminal tier and needs \
                             uplink_mbps and rtt_ms for its hop to the next tier",
                            t.addr
                        ),
                    }
                }
            }
        }
        if self.link_classes.len() > 256 {
            bail!(
                "at most 256 link_class entries (u8 wire tag); got {}",
                self.link_classes.len()
            );
        }
        let mut seen = std::collections::HashSet::new();
        for (i, c) in self.link_classes.iter().enumerate() {
            if c.name.trim().is_empty() {
                bail!("link_class[{i}].name must be non-empty");
            }
            if !seen.insert(c.name.to_ascii_lowercase()) {
                bail!("link_class[{i}].name duplicates '{}'", c.name);
            }
            if !(c.uplink_mbps.is_finite() && c.uplink_mbps > 0.0) {
                bail!(
                    "link_class[{i}] ('{}'): uplink_mbps must be positive and finite; got {}",
                    c.name,
                    c.uplink_mbps
                );
            }
            if !(c.rtt_s.is_finite() && c.rtt_s >= 0.0) {
                bail!(
                    "link_class[{i}] ('{}'): rtt_ms must be non-negative and finite; got {}",
                    c.name,
                    c.rtt_s * 1e3
                );
            }
            if let Some(p) = c.exit_probability {
                if !(0.0..=1.0).contains(&p) {
                    bail!(
                        "link_class[{i}] ('{}'): exit_probability must be in [0, 1]; got {p}",
                        c.name
                    );
                }
            }
            if let Some(addr) = &c.cloud_addr {
                if let Err(e) = validate_host_port(addr) {
                    bail!("link_class[{i}] ('{}').cloud_addr: {e}", c.name);
                }
            }
            // Per-class autoscale bounds: validated against the same
            // 1..=64 envelope as the fleet-wide values, with the
            // fallbacks applied so a partial override cannot invert
            // the range it inherits the other half of.
            let lo = c.min_shards.unwrap_or(self.fleet.min_shards);
            let hi = c.max_shards.unwrap_or(self.fleet.max_shards);
            if !(1..=64).contains(&lo) || !(1..=64).contains(&hi) {
                bail!(
                    "link_class[{i}] ('{}'): min_shards/max_shards must be in 1..=64; \
                     got {lo}..={hi}",
                    c.name
                );
            }
            if lo > hi {
                bail!(
                    "link_class[{i}] ('{}'): min_shards ({lo}) exceeds max_shards ({hi}) \
                     after [fleet] fallbacks",
                    c.name
                );
            }
            if self.fleet.autoscale && !(lo..=hi).contains(&self.fleet.shards) {
                bail!(
                    "link_class[{i}] ('{}'): starting fleet.shards ({}) must lie within \
                     this class's autoscale range {lo}..={hi}",
                    c.name,
                    self.fleet.shards
                );
            }
        }
        if let Some(cap) = self.fleet.max_total_shards {
            let classes = self.link_classes.len().max(1);
            let starting = classes * self.fleet.shards;
            if cap < starting {
                bail!(
                    "fleet.max_total_shards ({cap}) is below the starting fleet size \
                     ({classes} class(es) x {} shard(s) = {starting})",
                    self.fleet.shards
                );
            }
            // No separate floor-sum check is needed: per-entry
            // validation already forces `shards >= min` for every class
            // when autoscaling, so the starting size bounds the floor
            // sum from above.
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        Settings::default().validate().unwrap();
    }

    #[test]
    fn toml_overlay() {
        let doc = toml::parse(
            r#"
[model]
flavor = "pl"

[network]
kind = "3g"
uplink_mbps = 1.10
rtt_ms = 20

[edge]
gamma = 10

[branch]
entropy_threshold = 0.5
exit_probability = 0.8

[partition]
strategy = "brute-force"

[serve]
port = 9000
max_batch = 4
"#,
        )
        .unwrap();
        let mut s = Settings::default();
        s.apply(&doc).unwrap();
        s.validate().unwrap();
        assert_eq!(s.model.flavor, Flavor::Pallas);
        assert_eq!(s.network.kind, "3g");
        assert_eq!(s.network.rtt_s, 0.02);
        assert_eq!(s.edge.gamma, 10.0);
        assert_eq!(s.branch.exit_probability, Some(0.8));
        assert_eq!(s.partition.strategy, Strategy::BruteForce);
        assert_eq!(s.serve.port, 9000);
        assert_eq!(s.serve.max_batch, 4);
    }

    #[test]
    fn validation_rejects_bad_values() {
        let mut s = Settings::default();
        s.edge.gamma = 0.5;
        assert!(s.validate().is_err());

        let mut s = Settings::default();
        s.branch.exit_probability = Some(1.5);
        assert!(s.validate().is_err());

        let mut s = Settings::default();
        s.branch.entropy_threshold = 0.8; // > ln 2
        assert!(s.validate().is_err());

        let mut s = Settings::default();
        s.partition.epsilon = 0.1;
        assert!(s.validate().is_err());

        // The joint-search accuracy floor must be a probability.
        for bad in [-0.1, 1.5, f64::NAN, f64::INFINITY] {
            let mut s = Settings::default();
            s.planner.min_accuracy_proxy = bad;
            let e = s.validate().unwrap_err().to_string();
            assert!(e.contains("planner.min_accuracy_proxy"), "{bad}: {e}");
        }
        let mut s = Settings::default();
        s.planner.joint_search = true;
        s.planner.min_accuracy_proxy = 1.0;
        s.validate().unwrap();
    }

    #[test]
    fn fleet_and_link_class_overlay() {
        let doc = toml::parse(
            r#"
[planner]
joint_search = true
min_accuracy_proxy = 0.35

[fleet]
shards = 4
cloud_workers = 2
routing = "hash"
per_request_planning = true
online_estimation = true
drift_threshold = 0.25
probe_fraction = 0.05
cloud_addr = "cloud.internal:7879"
wire_encoding = "q8"
autoscale = true
min_shards = 2
max_shards = 6
scale_up_depth = 8.0
scale_down_depth = 1.0
scale_interval_ms = 50
scale_window = 3
scale_cooldown_ms = 500
reactor = true
reactor_threads = 4
max_conns = 2000
conn_window = 64

[[link_class]]
name = "3g"

[[link_class]]
name = "satellite"
uplink_mbps = 0.35
rtt_ms = 280
exit_probability = 0.8
cloud_addr = "sat-cloud.internal:7880"
joint_search = false
"#,
        )
        .unwrap();
        let mut s = Settings::default();
        s.apply(&doc).unwrap();
        s.validate().unwrap();
        assert!(s.planner.joint_search);
        assert!((s.planner.min_accuracy_proxy - 0.35).abs() < 1e-12);
        assert_eq!(s.fleet.shards, 4);
        assert_eq!(s.fleet.cloud_workers, 2);
        assert_eq!(s.fleet.routing, "hash");
        assert!(s.fleet.per_request_planning);
        assert!(s.fleet.online_estimation);
        assert!((s.fleet.drift_threshold - 0.25).abs() < 1e-12);
        assert!((s.fleet.probe_fraction - 0.05).abs() < 1e-12);
        assert_eq!(s.fleet.cloud_addr.as_deref(), Some("cloud.internal:7879"));
        assert_eq!(s.fleet.wire_encoding, WireEncoding::Q8);
        assert!(s.fleet.reactor);
        assert_eq!(s.fleet.reactor_threads, 4);
        assert_eq!(s.fleet.max_conns, 2000);
        assert_eq!(s.fleet.conn_window, 64);
        assert!(s.fleet.autoscale);
        let acfg = s.fleet.autoscale_config().unwrap();
        assert_eq!((acfg.min_shards, acfg.max_shards), (2, 6));
        assert!((acfg.scale_up_depth - 8.0).abs() < 1e-12);
        assert!((acfg.scale_down_depth - 1.0).abs() < 1e-12);
        assert_eq!(acfg.interval, std::time::Duration::from_millis(50));
        assert_eq!(acfg.window, 3);
        assert_eq!(acfg.cooldown, std::time::Duration::from_millis(500));
        assert_eq!(s.link_classes.len(), 2);
        // Builtin name: paper rate filled in automatically.
        assert_eq!(s.link_classes[0].name, "3g");
        assert!((s.link_classes[0].uplink_mbps - 1.10).abs() < 1e-12);
        assert!((s.link_classes[1].rtt_s - 0.28).abs() < 1e-12);
        assert_eq!(s.link_classes[1].exit_probability, Some(0.8));
        // Per-class cloud override rides next to the fleet-wide one.
        assert_eq!(s.link_classes[0].cloud_addr, None);
        assert_eq!(
            s.link_classes[1].cloud_addr.as_deref(),
            Some("sat-cloud.internal:7880")
        );
        // Per-class joint_search: absent = inherit, present = override.
        assert_eq!(s.link_classes[0].joint_search, None);
        assert_eq!(s.link_classes[1].joint_search, Some(false));
    }

    #[test]
    fn fleet_validation_errors_name_the_field() {
        let mut s = Settings::default();
        s.fleet.shards = 0;
        let e = s.validate().unwrap_err().to_string();
        assert!(e.contains("fleet.shards"), "{e}");

        let mut s = Settings::default();
        s.fleet.routing = "magic".into();
        let e = s.validate().unwrap_err().to_string();
        assert!(e.contains("fleet.routing"), "{e}");

        let mut s = Settings::default();
        s.fleet.drift_threshold = 0.0;
        let e = s.validate().unwrap_err().to_string();
        assert!(e.contains("fleet.drift_threshold"), "{e}");

        let mut s = Settings::default();
        s.fleet.drift_threshold = 1.0;
        assert!(s.validate().is_err());

        let mut s = Settings::default();
        s.fleet.per_request_planning = true;
        s.fleet.probe_fraction = 1.5;
        let e = s.validate().unwrap_err().to_string();
        assert!(e.contains("fleet.probe_fraction"), "{e}");

        // Probing without per-request planning has nothing to ride on.
        let mut s = Settings::default();
        s.fleet.probe_fraction = 0.1;
        let e = s.validate().unwrap_err().to_string();
        assert!(e.contains("per_request_planning"), "{e}");

        // Autoscale: starting size must lie inside the scaling range.
        let mut s = Settings::default();
        s.fleet.autoscale = true;
        s.fleet.shards = 1;
        s.fleet.min_shards = 2;
        s.fleet.max_shards = 4;
        let e = s.validate().unwrap_err().to_string();
        assert!(e.contains("fleet.shards") && e.contains("min_shards"), "{e}");
        s.fleet.shards = 2;
        s.validate().unwrap();
        // Off, the range is not enforced (it is inert).
        s.fleet.autoscale = false;
        s.fleet.shards = 1;
        s.validate().unwrap();

        // A collapsed hysteresis band fails loudly, naming the fields.
        let mut s = Settings::default();
        s.fleet.autoscale = true;
        s.fleet.scale_down_depth = s.fleet.scale_up_depth;
        assert!(s.validate().is_err());

        // Negative milliseconds must fail validation, not panic at the
        // Duration conversion.
        let mut s = Settings::default();
        s.fleet.autoscale = true;
        s.fleet.scale_cooldown_ms = -1.0;
        let e = s.validate().unwrap_err().to_string();
        assert!(e.contains("scale_cooldown_ms"), "{e}");
        let mut s = Settings::default();
        s.fleet.autoscale = true;
        s.fleet.scale_interval_ms = 0.0;
        let e = s.validate().unwrap_err().to_string();
        assert!(e.contains("scale_interval_ms"), "{e}");

        // Front-end knobs: a zero window or thread count fails loudly.
        let mut s = Settings::default();
        s.fleet.reactor_threads = 0;
        let e = s.validate().unwrap_err().to_string();
        assert!(e.contains("fleet.reactor_threads"), "{e}");
        let mut s = Settings::default();
        s.fleet.reactor_threads = 65;
        assert!(s.validate().is_err());
        let mut s = Settings::default();
        s.fleet.conn_window = 0;
        let e = s.validate().unwrap_err().to_string();
        assert!(e.contains("fleet.conn_window"), "{e}");
        // max_conns = 0 is the documented "no cap" value.
        let mut s = Settings::default();
        s.fleet.max_conns = 0;
        s.validate().unwrap();

        for bad in ["cloud.internal", ":7879", "host:notaport", "host:99999", "host:0"] {
            let mut s = Settings::default();
            s.fleet.cloud_addr = Some(bad.into());
            let e = s.validate().unwrap_err().to_string();
            assert!(e.contains("fleet.cloud_addr"), "'{bad}': {e}");
        }
        let mut s = Settings::default();
        s.fleet.cloud_addr = Some("10.0.0.7:7879".into());
        s.validate().unwrap();

        let mut s = Settings::default();
        s.link_classes.push(LinkClassSettings {
            name: "x".into(),
            uplink_mbps: -2.0,
            rtt_s: 0.0,
            exit_probability: None,
            cloud_addr: None,
            min_shards: None,
            max_shards: None,
            joint_search: None,
        });
        let e = s.validate().unwrap_err().to_string();
        assert!(e.contains("link_class[0]") && e.contains("uplink_mbps"), "{e}");

        let mut s = Settings::default();
        for name in ["a", "A"] {
            s.link_classes.push(LinkClassSettings {
                name: name.into(),
                uplink_mbps: 5.0,
                rtt_s: 0.0,
                exit_probability: None,
                cloud_addr: None,
                min_shards: None,
                max_shards: None,
                joint_search: None,
            });
        }
        let e = s.validate().unwrap_err().to_string();
        assert!(e.contains("link_class[1].name"), "{e}");

        let mut s = Settings::default();
        s.link_classes.push(LinkClassSettings {
            name: "x".into(),
            uplink_mbps: 5.0,
            rtt_s: 0.0,
            exit_probability: Some(1.5),
            cloud_addr: None,
            min_shards: None,
            max_shards: None,
            joint_search: None,
        });
        let e = s.validate().unwrap_err().to_string();
        assert!(e.contains("exit_probability"), "{e}");

        // A malformed per-class cloud endpoint names its entry.
        let mut s = Settings::default();
        s.link_classes.push(LinkClassSettings {
            name: "edgey".into(),
            uplink_mbps: 5.0,
            rtt_s: 0.0,
            exit_probability: None,
            cloud_addr: Some("nocolon".into()),
            min_shards: None,
            max_shards: None,
            joint_search: None,
        });
        let e = s.validate().unwrap_err().to_string();
        assert!(e.contains("link_class[0]") && e.contains("cloud_addr"), "{e}");

        // An unknown wire encoding fails at overlay time, naming the key.
        let doc = toml::parse("[fleet]\nwire_encoding = \"q2\"\n").unwrap();
        let mut s = Settings::default();
        let e = format!("{:#}", s.apply(&doc).unwrap_err());
        assert!(e.contains("fleet.wire_encoding"), "{e}");

        // A non-builtin class without a rate fails at overlay time.
        let doc = toml::parse("[[link_class]]\nname = \"mystery\"\n").unwrap();
        let mut s = Settings::default();
        let e = s.apply(&doc).unwrap_err().to_string();
        assert!(e.contains("link_class[0]") && e.contains("uplink_mbps"), "{e}");
    }

    #[test]
    fn per_class_shard_bounds_and_fleet_budget() {
        let doc = toml::parse(
            r#"
[fleet]
autoscale = true
shards = 2
min_shards = 1
max_shards = 8
max_total_shards = 10

[[link_class]]
name = "3g"
min_shards = 2
max_shards = 3

[[link_class]]
name = "wifi"
"#,
        )
        .unwrap();
        let mut s = Settings::default();
        s.apply(&doc).unwrap();
        s.validate().unwrap();
        assert_eq!(s.fleet.max_total_shards, Some(10));
        assert_eq!(s.link_classes[0].min_shards, Some(2));
        assert_eq!(s.link_classes[0].max_shards, Some(3));
        // The second class inherits the [fleet] values.
        assert_eq!(s.link_classes[1].min_shards, None);
        assert_eq!(s.link_classes[1].max_shards, None);

        // An inverted per-class range (after fallbacks) names its entry.
        let mut bad = s.clone();
        bad.link_classes[0].min_shards = Some(5);
        bad.link_classes[0].max_shards = Some(3);
        let e = bad.validate().unwrap_err().to_string();
        assert!(e.contains("link_class[0]") && e.contains("min_shards"), "{e}");

        // A partial override is checked against the inherited half:
        // min 9 > fleet max 8.
        let mut bad = s.clone();
        bad.link_classes[1].min_shards = Some(9);
        bad.link_classes[1].max_shards = None;
        let e = bad.validate().unwrap_err().to_string();
        assert!(e.contains("link_class[1]"), "{e}");

        // The starting size must fit every class's range.
        let mut bad = s.clone();
        bad.link_classes[0].min_shards = Some(3);
        bad.link_classes[0].max_shards = Some(4);
        let e = bad.validate().unwrap_err().to_string();
        assert!(e.contains("link_class[0]") && e.contains("range 3..=4"), "{e}");

        // Budget below the starting fleet size fails loudly.
        let mut bad = s.clone();
        bad.fleet.max_total_shards = Some(3);
        let e = bad.validate().unwrap_err().to_string();
        assert!(e.contains("max_total_shards") && e.contains("starting"), "{e}");

        // A budget exactly at the starting size is the tightest valid one.
        let mut tight = s.clone();
        tight.fleet.max_total_shards = Some(4);
        tight.validate().unwrap();
    }

    #[test]
    fn strategy_and_flavor_parse() {
        assert_eq!(Strategy::parse("paper").unwrap(), Strategy::ShortestPath);
        assert_eq!(Strategy::parse("edge").unwrap(), Strategy::EdgeOnly);
        assert!(Strategy::parse("x").is_err());
        assert_eq!(Flavor::parse("pallas").unwrap(), Flavor::Pallas);
        assert!(Flavor::parse("x").is_err());
    }

    #[test]
    fn tier_chain_parse_and_validation() {
        let doc = toml::parse(
            "[[tier]]\naddr = \"edge-agg.internal:7879\"\nuplink_mbps = 1000.0\n\
             rtt_ms = 2.0\ncompute_scale = 4.0\n\n\
             [[tier]]\naddr = \"cloud.internal:7879\"\n",
        )
        .unwrap();
        let mut s = Settings::default();
        s.apply(&doc).unwrap();
        assert_eq!(s.tiers.len(), 2);
        assert_eq!(s.tiers[0].addr, "edge-agg.internal:7879");
        assert!((s.tiers[0].uplink_mbps.unwrap() - 1000.0).abs() < 1e-12);
        assert!((s.tiers[0].rtt_s.unwrap() - 0.002).abs() < 1e-12);
        assert!((s.tiers[0].compute_scale - 4.0).abs() < 1e-12);
        // Terminal tier: no hop fields needed, compute scale defaults
        // to the profiled cloud's.
        assert_eq!(s.tiers[1].uplink_mbps, None);
        assert!((s.tiers[1].compute_scale - 1.0).abs() < 1e-12);
        s.validate().unwrap();

        // A single tier is not a chain.
        let mut one = Settings::default();
        one.apply(&toml::parse("[[tier]]\naddr = \"cloud.internal:7879\"\n").unwrap())
            .unwrap();
        let e = one.validate().unwrap_err().to_string();
        assert!(e.contains("at least 2"), "{e}");

        // Non-terminal tiers must describe their hop to the next tier.
        let mut no_hop = s.clone();
        no_hop.tiers[0].uplink_mbps = None;
        let e = no_hop.validate().unwrap_err().to_string();
        assert!(e.contains("tier[0]") && e.contains("uplink_mbps"), "{e}");

        // The chain replaces the single cloud endpoint, never joins it.
        let mut both = s.clone();
        both.fleet.cloud_addr = Some("cloud.internal:7879".into());
        let e = both.validate().unwrap_err().to_string();
        assert!(e.contains("mutually exclusive"), "{e}");

        // Degenerate compute scales and malformed endpoints are loud.
        let mut bad = s.clone();
        bad.tiers[0].compute_scale = 0.0;
        let e = bad.validate().unwrap_err().to_string();
        assert!(e.contains("compute_scale"), "{e}");
        let mut bad = s;
        bad.tiers[1].addr = "no-port".into();
        let e = bad.validate().unwrap_err().to_string();
        assert!(e.contains("tier[1].addr"), "{e}");
    }
}
