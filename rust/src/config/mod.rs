//! Configuration subsystem: a hand-rolled JSON parser/serializer (serde is
//! unavailable offline — DESIGN.md §3), a TOML-subset loader for config
//! files, and the typed `Settings` used by the CLI and the coordinator.

pub mod json;
pub mod settings;
pub mod toml;

pub use json::Json;
pub use settings::Settings;
