//! TOML-subset parser for config files (`branchyserve --config serve.toml`).
//!
//! Supported: `[section]` / `[a.b]` tables, `[[entry]]` arrays of tables
//! (each header appends one element; keys land in the newest element —
//! how `[[link_class]]` fleet configs are written), `key = value` with
//! string, integer, float, boolean and homogeneous-array values, `#`
//! comments. Unsupported (rejected, not silently misread): multiline
//! strings, datetimes, inline tables. That subset covers every config
//! this project ships; values land in the same `Json` tree the JSON
//! parser produces so `Settings` has one extraction path.

use std::collections::BTreeMap;

use super::json::Json;

#[derive(Debug, thiserror::Error)]
#[error("toml parse error on line {line}: {msg}")]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

/// Parse TOML text into a nested `Json::Obj` tree.
pub fn parse(text: &str) -> Result<Json, TomlError> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError {
            line: lineno + 1,
            msg: msg.to_string(),
        };

        if let Some(rest) = line.strip_prefix("[[") {
            // Array-of-tables header: append one element, point the
            // cursor at it.
            let inner = rest
                .strip_suffix("]]")
                .ok_or_else(|| err("unclosed '[['"))?;
            let path = split_header(inner).map_err(|m| err(&m))?;
            let (last, parents) = path.split_last().expect("split_header is non-empty");
            let parent = resolve_table(&mut root, parents).map_err(|m| err(&m))?;
            match parent
                .entry(last.clone())
                .or_insert_with(|| Json::Arr(Vec::new()))
            {
                Json::Arr(items) => items.push(Json::Obj(BTreeMap::new())),
                _ => return Err(err(&format!("'{last}' is not an array of tables"))),
            }
            current_path = path;
            continue;
        }

        if let Some(rest) = line.strip_prefix('[') {
            let inner = rest.strip_suffix(']').ok_or_else(|| err("unclosed '['"))?;
            let path = split_header(inner).map_err(|m| err(&m))?;
            // Materialize the table even if empty. Parent segments may
            // pass through array-of-tables elements, but the named table
            // itself must be a plain table — `[a]` cannot reopen `[[a]]`.
            let (last, parents) = path.split_last().expect("split_header is non-empty");
            let parent = resolve_table(&mut root, parents).map_err(|m| err(&m))?;
            match parent
                .entry(last.clone())
                .or_insert_with(|| Json::Obj(BTreeMap::new()))
            {
                Json::Obj(_) => {}
                _ => return Err(err(&format!("'{last}' is not a table"))),
            }
            current_path = path;
            continue;
        }

        let eq = line.find('=').ok_or_else(|| err("expected 'key = value'"))?;
        let key = line[..eq].trim();
        let vtext = line[eq + 1..].trim();
        if key.is_empty() || !is_bare_key(key) {
            return Err(err("invalid key"));
        }
        if vtext.is_empty() {
            return Err(err("missing value"));
        }
        let value = parse_value(vtext).map_err(|m| err(&m))?;
        let table = resolve_table(&mut root, &current_path).map_err(|m| err(&m))?;
        if table.insert(key.to_string(), value).is_some() {
            return Err(err(&format!("duplicate key '{key}'")));
        }
    }
    Ok(Json::Obj(root))
}

fn split_header(inner: &str) -> Result<Vec<String>, String> {
    if inner.trim().is_empty() {
        return Err("empty table name".to_string());
    }
    let path: Vec<String> = inner.split('.').map(|s| s.trim().to_string()).collect();
    if path.iter().any(|s| s.is_empty() || !is_bare_key(s)) {
        return Err("invalid table name".to_string());
    }
    Ok(path)
}

fn is_bare_key(s: &str) -> bool {
    !s.is_empty()
        && s.chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

/// Strip a `#` comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut quote = ' ';
    for (i, c) in line.char_indices() {
        match c {
            '"' | '\'' if !in_str => {
                in_str = true;
                quote = c;
            }
            c if in_str && c == quote => in_str = false,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Walk `path` creating plain tables for missing segments. A segment
/// holding an array of tables resolves to its *newest* element — that is
/// how `[a]` headers and `k = v` lines nested under a `[[a]]` entry find
/// their home.
fn resolve_table<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, Json>, String> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        match entry {
            Json::Obj(m) => cur = m,
            Json::Arr(items) => match items.last_mut() {
                Some(Json::Obj(m)) => cur = m,
                _ => return Err(format!("'{part}' is not an array of tables")),
            },
            _ => return Err(format!("'{part}' is both a value and a table")),
        }
    }
    Ok(cur)
}

/// Parse a value expression: a scalar, or a (possibly nested) array
/// whose elements re-enter this function.
fn parse_value(s: &str) -> Result<Json, String> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part)?);
        }
        return Ok(Json::Arr(items));
    }
    parse_scalar(s)
}

/// The one typed-value coercion path. Every non-array value — basic or
/// literal string, boolean, number — funnels through here, whether it
/// sits on the right of `key = value` or inside an array, so the two
/// positions cannot drift in what they accept or how they complain.
fn parse_scalar(s: &str) -> Result<Json, String> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return unescape(inner);
    }
    if let Some(rest) = s.strip_prefix('\'') {
        let inner = rest
            .strip_suffix('\'')
            .ok_or_else(|| "unterminated literal string".to_string())?;
        return Ok(Json::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Json::Bool(true)),
        "false" => return Ok(Json::Bool(false)),
        _ => {}
    }
    // Number: allow underscores per TOML.
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Ok(v) = cleaned.parse::<f64>() {
        if v.is_finite() {
            return Ok(Json::Num(v));
        }
    }
    Err(format!(
        "cannot parse value '{s}' (expected a quoted string, boolean, number, or array)"
    ))
}

/// Split array elements on top-level commas (strings may contain commas).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut quote = ' ';
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' | '\'' if !in_str => {
                in_str = true;
                quote = c;
            }
            c if in_str && c == quote => in_str = false,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

fn unescape(s: &str) -> Result<Json, String> {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => return Err(format!("bad escape '\\{:?}'", other)),
        }
    }
    Ok(Json::Str(out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_document() {
        let doc = r#"
# serving config
title = "edge demo"
max_batch = 8
timeout_ms = 12.5
debug = false

[network]
kind = "4g"
uplink_mbps = 5.85

[partition.solver]
epsilon = 1e-9
layers = [1, 2, 3]
names = ["a", "b,c"]
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("title").unwrap().as_str(), Some("edge demo"));
        assert_eq!(v.get("max_batch").unwrap().as_u64(), Some(8));
        assert_eq!(v.get("debug").unwrap().as_bool(), Some(false));
        assert_eq!(v.path("network.uplink_mbps").unwrap().as_f64(), Some(5.85));
        assert_eq!(v.path("partition.solver.epsilon").unwrap().as_f64(), Some(1e-9));
        assert_eq!(
            v.path("partition.solver.layers").unwrap().as_u64_vec(),
            Some(vec![1, 2, 3])
        );
        let names = v.path("partition.solver.names").unwrap().as_arr().unwrap();
        assert_eq!(names[1].as_str(), Some("b,c"));
    }

    #[test]
    fn comments_inside_strings_survive() {
        let v = parse(r##"k = "a # not comment" # real comment"##).unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some("a # not comment"));
    }

    #[test]
    fn numbers_with_underscores() {
        let v = parse("n = 1_000_000").unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(1_000_000));
    }

    #[test]
    fn rejects_unsupported_and_malformed() {
        for bad in [
            "k =",
            "= 3",
            "k = nope",
            "[a.]",
            "[[a.]]",
            "[[a]",
            "k = \"unterminated",
            "k = 1\nk = 2",
        ] {
            assert!(parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn arrays_of_tables() {
        let doc = r#"
[fleet]
shards = 4

[[link_class]]
name = "3g"
uplink_mbps = 1.10

[[link_class]]
name = "wifi"
uplink_mbps = 18.8
rtt_ms = 5
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.path("fleet.shards").unwrap().as_u64(), Some(4));
        let classes = v.get("link_class").unwrap().as_arr().unwrap();
        assert_eq!(classes.len(), 2);
        assert_eq!(classes[0].get("name").unwrap().as_str(), Some("3g"));
        assert_eq!(classes[1].get("rtt_ms").unwrap().as_f64(), Some(5.0));
        // Keys after a [[header]] land in the newest element only.
        assert!(classes[0].get("rtt_ms").is_none());
    }

    #[test]
    fn array_of_tables_conflicts_rejected() {
        // A plain table cannot reopen an array-of-tables name...
        assert!(parse("[[a]]\nx = 1\n[a]\ny = 2").is_err());
        // ...nor can an array header reuse a plain table or value name.
        assert!(parse("[a]\nx = 1\n[[a]]\ny = 2").is_err());
        assert!(parse("a = 3\n[[a]]\ny = 2").is_err());
    }

    #[test]
    fn value_vs_table_conflict() {
        assert!(parse("a = 1\n[a.b]\nc = 2").is_err());
    }

    #[test]
    fn scalar_coercion_identical_in_value_and_array_position() {
        // Both positions funnel through parse_scalar: same types out,
        // same actionable complaint on garbage.
        let v = parse("a = 'lit'\nb = [true, 2.5, \"q\"]").unwrap();
        assert_eq!(v.get("a").unwrap().as_str(), Some("lit"));
        let arr = v.get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_bool(), Some(true));
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert_eq!(arr[2].as_str(), Some("q"));
        for bad in ["k = nope", "k = [1, nope]"] {
            let e = parse(bad).unwrap_err();
            assert_eq!(e.line, 1);
            assert!(e.msg.contains("expected a quoted string"), "{e}");
        }
    }

    #[test]
    fn empty_and_comment_only() {
        assert_eq!(parse("").unwrap(), Json::Obj(Default::default()));
        assert_eq!(parse("# hi\n\n").unwrap(), Json::Obj(Default::default()));
    }
}
