//! TOML-subset parser for config files (`branchyserve --config serve.toml`).
//!
//! Supported: `[section]` / `[a.b]` tables, `key = value` with string,
//! integer, float, boolean and homogeneous-array values, `#` comments.
//! Unsupported (rejected, not silently misread): multiline strings,
//! datetimes, inline tables, arrays of tables. That subset covers every
//! config this project ships; values land in the same `Json` tree the
//! JSON parser produces so `Settings` has one extraction path.

use std::collections::BTreeMap;

use super::json::Json;

#[derive(Debug, thiserror::Error)]
#[error("toml parse error on line {line}: {msg}")]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

/// Parse TOML text into a nested `Json::Obj` tree.
pub fn parse(text: &str) -> Result<Json, TomlError> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError {
            line: lineno + 1,
            msg: msg.to_string(),
        };

        if let Some(rest) = line.strip_prefix('[') {
            if line.starts_with("[[") {
                return Err(err("arrays of tables are not supported"));
            }
            let inner = rest.strip_suffix(']').ok_or_else(|| err("unclosed '['"))?;
            if inner.is_empty() {
                return Err(err("empty table name"));
            }
            current_path = inner.split('.').map(|s| s.trim().to_string()).collect();
            if current_path.iter().any(|s| s.is_empty() || !is_bare_key(s)) {
                return Err(err("invalid table name"));
            }
            // Materialize the table even if empty.
            ensure_table(&mut root, &current_path).map_err(|m| err(&m))?;
            continue;
        }

        let eq = line.find('=').ok_or_else(|| err("expected 'key = value'"))?;
        let key = line[..eq].trim();
        let vtext = line[eq + 1..].trim();
        if key.is_empty() || !is_bare_key(key) {
            return Err(err("invalid key"));
        }
        if vtext.is_empty() {
            return Err(err("missing value"));
        }
        let value = parse_value(vtext).map_err(|m| err(&m))?;
        let table = ensure_table(&mut root, &current_path).map_err(|m| err(&m))?;
        if table.insert(key.to_string(), value).is_some() {
            return Err(err(&format!("duplicate key '{key}'")));
        }
    }
    Ok(Json::Obj(root))
}

fn is_bare_key(s: &str) -> bool {
    !s.is_empty()
        && s.chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

/// Strip a `#` comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut quote = ' ';
    for (i, c) in line.char_indices() {
        match c {
            '"' | '\'' if !in_str => {
                in_str = true;
                quote = c;
            }
            c if in_str && c == quote => in_str = false,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, Json>, String> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        match entry {
            Json::Obj(m) => cur = m,
            _ => return Err(format!("'{part}' is both a value and a table")),
        }
    }
    Ok(cur)
}

fn parse_value(s: &str) -> Result<Json, String> {
    let s = s.trim();
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return unescape(inner);
    }
    if let Some(rest) = s.strip_prefix('\'') {
        let inner = rest
            .strip_suffix('\'')
            .ok_or_else(|| "unterminated literal string".to_string())?;
        return Ok(Json::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Json::Bool(true));
    }
    if s == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part)?);
        }
        return Ok(Json::Arr(items));
    }
    // Number: allow underscores per TOML.
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Ok(v) = cleaned.parse::<f64>() {
        if v.is_finite() {
            return Ok(Json::Num(v));
        }
    }
    Err(format!("cannot parse value '{s}'"))
}

/// Split array elements on top-level commas (strings may contain commas).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut quote = ' ';
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' | '\'' if !in_str => {
                in_str = true;
                quote = c;
            }
            c if in_str && c == quote => in_str = false,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

fn unescape(s: &str) -> Result<Json, String> {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => return Err(format!("bad escape '\\{:?}'", other)),
        }
    }
    Ok(Json::Str(out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_document() {
        let doc = r#"
# serving config
title = "edge demo"
max_batch = 8
timeout_ms = 12.5
debug = false

[network]
kind = "4g"
uplink_mbps = 5.85

[partition.solver]
epsilon = 1e-9
layers = [1, 2, 3]
names = ["a", "b,c"]
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("title").unwrap().as_str(), Some("edge demo"));
        assert_eq!(v.get("max_batch").unwrap().as_u64(), Some(8));
        assert_eq!(v.get("debug").unwrap().as_bool(), Some(false));
        assert_eq!(v.path("network.uplink_mbps").unwrap().as_f64(), Some(5.85));
        assert_eq!(v.path("partition.solver.epsilon").unwrap().as_f64(), Some(1e-9));
        assert_eq!(
            v.path("partition.solver.layers").unwrap().as_u64_vec(),
            Some(vec![1, 2, 3])
        );
        let names = v.path("partition.solver.names").unwrap().as_arr().unwrap();
        assert_eq!(names[1].as_str(), Some("b,c"));
    }

    #[test]
    fn comments_inside_strings_survive() {
        let v = parse(r##"k = "a # not comment" # real comment"##).unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some("a # not comment"));
    }

    #[test]
    fn numbers_with_underscores() {
        let v = parse("n = 1_000_000").unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(1_000_000));
    }

    #[test]
    fn rejects_unsupported_and_malformed() {
        for bad in [
            "[[tables]]",
            "k =",
            "= 3",
            "k = nope",
            "[a.]",
            "k = \"unterminated",
            "k = 1\nk = 2",
        ] {
            assert!(parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn value_vs_table_conflict() {
        assert!(parse("a = 1\n[a.b]\nc = 2").is_err());
    }

    #[test]
    fn empty_and_comment_only() {
        assert_eq!(parse("").unwrap(), Json::Obj(Default::default()));
        assert_eq!(parse("# hi\n\n").unwrap(), Json::Obj(Default::default()));
    }
}
