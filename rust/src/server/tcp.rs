//! TCP accept loop + a blocking client, speaking `protocol` frames in
//! front of any [`ServeBackend`] — a single [`Coordinator`] pipeline or
//! a whole [`crate::fleet::Fleet`].

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{Coordinator, InferenceResponse};
use crate::network::encoding::WireEncoding;
use crate::runtime::HostTensor;

use super::protocol::{read_frame, write_frame, PartialSample, Request, Response};

/// What a backend returns for one INFER_PARTIAL batch: one record per
/// input sample, in order, plus the backend's compute seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct PartialOutput {
    pub samples: Vec<PartialSample>,
    pub cloud_s: f64,
}

/// What the TCP front-end needs from whatever is serving behind it.
pub trait ServeBackend: Send + Sync + 'static {
    /// Serve one inference. `class` carries the protocol's link-class
    /// tag (`None` for an untagged legacy INFER); single-pipeline
    /// backends may ignore it.
    fn serve_infer(&self, class: Option<u8>, image: HostTensor) -> Result<InferenceResponse>;

    /// Serve one INFER_PARTIAL batch: run stages `split+1..=N` on a
    /// batched activation the edge cut after stage `split`. Only
    /// cloud-stage backends ([`super::CloudStageServer`]) implement
    /// this; edge-facing backends keep the default, which answers with
    /// an ERROR frame.
    fn serve_partial(
        &self,
        split: usize,
        branch_state: u8,
        activation: HostTensor,
    ) -> Result<PartialOutput> {
        let _ = (split, branch_state, activation);
        anyhow::bail!("this backend does not serve partial inference (not a cloud-stage server)")
    }

    /// [`ServeBackend::serve_partial`] for frames that carried a wire
    /// encoding tag (pipelined kind-5 requests — the activation arrives
    /// here already dequantized). The default forwards to
    /// `serve_partial`; cloud-stage backends override to keep
    /// per-encoding served counters.
    fn serve_partial_encoded(
        &self,
        split: usize,
        branch_state: u8,
        encoding: WireEncoding,
        activation: HostTensor,
    ) -> Result<PartialOutput> {
        let _ = encoding;
        self.serve_partial(split, branch_state, activation)
    }

    /// Byte accounting hook: called by the connection loop with the
    /// framed request/response sizes (header included) after each
    /// exchange. Default: not counted.
    fn note_io(&self, bytes_received: u64, bytes_sent: u64) {
        let _ = (bytes_received, bytes_sent);
    }

    /// JSON body of the METRICS response.
    fn metrics_json(&self) -> String;
}

impl ServeBackend for Coordinator {
    fn serve_infer(&self, _class: Option<u8>, image: HostTensor) -> Result<InferenceResponse> {
        self.infer_sync(image)
    }

    fn metrics_json(&self) -> String {
        self.metrics().to_json()
    }
}

pub struct Server<B: ServeBackend> {
    backend: Arc<B>,
}

/// Handle for stopping a running server.
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the accept loop with one last connection so it re-checks.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl<B: ServeBackend> Server<B> {
    pub fn new(backend: Arc<B>) -> Server<B> {
        Server { backend }
    }

    /// Bind loopback and serve in background threads. Port 0 picks a
    /// free port. Use [`Server::start_on`] to serve other machines.
    pub fn start(self, port: u16) -> Result<ServerHandle> {
        self.start_on("127.0.0.1", port)
    }

    /// [`Server::start`] with an explicit bind address — `"0.0.0.0"`
    /// accepts connections from other hosts (a cloud-stage server
    /// fronting a remote edge needs this; loopback is the safe default
    /// for single-machine serving).
    pub fn start_on(self, bind: &str, port: u16) -> Result<ServerHandle> {
        let listener = TcpListener::bind((bind, port)).context("binding server socket")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        log::info!("serving on {addr}");

        let stop2 = stop.clone();
        let backend = self.backend;
        let accept_thread = std::thread::Builder::new()
            .name("accept-loop".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let b = backend.clone();
                            let _ = std::thread::Builder::new()
                                .name("conn".into())
                                .spawn(move || {
                                    if let Err(e) = handle_connection(stream, b.as_ref()) {
                                        log::debug!("connection ended: {e:#}");
                                    }
                                });
                        }
                        Err(e) => log::warn!("accept error: {e}"),
                    }
                }
            })?;

        Ok(ServerHandle {
            addr,
            stop,
            accept_thread: Some(accept_thread),
        })
    }
}

fn infer_response(backend: &impl ServeBackend, class: Option<u8>, image: HostTensor) -> Response {
    match backend.serve_infer(class, image) {
        Ok(r) => Response::Result {
            id: r.id,
            class: r.class as u32,
            exited_early: r.exited_early(),
            entropy: r.entropy,
            latency_s: r.latency_s,
        },
        Err(e) => Response::Error(format!("{e:#}")),
    }
}

fn handle_connection(stream: TcpStream, backend: &impl ServeBackend) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);

    loop {
        let body = match read_frame(&mut reader) {
            Ok(b) => b,
            Err(_) => return Ok(()), // peer closed
        };
        let response = match Request::decode(&body) {
            Err(e) => Response::Error(format!("{e:#}")),
            Ok(Request::Ping) => Response::Pong,
            Ok(Request::Metrics) => Response::Metrics(backend.metrics_json()),
            Ok(Request::Infer(tensor)) => infer_response(backend, None, tensor),
            Ok(Request::InferClass { class, image }) => {
                infer_response(backend, Some(class), image)
            }
            Ok(Request::InferPartial {
                split,
                branch_state,
                activation,
            }) => match backend.serve_partial_encoded(
                split as usize,
                branch_state,
                WireEncoding::Raw,
                activation,
            ) {
                Ok(out) => Response::PartialResult {
                    samples: out.samples,
                    cloud_s: out.cloud_s,
                },
                Err(e) => Response::Error(format!("{e:#}")),
            },
            // Pipelined: answers are written in arrival order on this
            // connection (the client's reader matches on the echoed
            // seq, so ordering is a non-requirement it gets for free),
            // and errors stay scoped to their seq instead of poisoning
            // the other in-flight requests.
            Ok(Request::InferPartialSeq {
                seq,
                split,
                branch_state,
                encoding,
                activation,
            }) => match backend.serve_partial_encoded(
                split as usize,
                branch_state,
                encoding,
                activation,
            ) {
                Ok(out) => Response::PartialResultSeq {
                    seq,
                    samples: out.samples,
                    cloud_s: out.cloud_s,
                },
                Err(e) => Response::ErrorSeq {
                    seq,
                    message: format!("{e:#}"),
                },
            },
        };
        let encoded = response.encode();
        write_frame(&mut writer, &encoded)?;
        // 8-byte frame headers included on both directions.
        backend.note_io(body.len() as u64 + 8, encoded.len() as u64 + 8);
    }
}

/// Blocking client for examples/tests/load generation.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting")?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    pub fn call(&mut self, req: &Request) -> Result<Response> {
        write_frame(&mut self.writer, &req.encode())?;
        let body = read_frame(&mut self.reader)?;
        Response::decode(&body)
    }

    pub fn ping(&mut self) -> Result<()> {
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => anyhow::bail!("expected PONG, got {other:?}"),
        }
    }

    pub fn infer(&mut self, image: HostTensor) -> Result<Response> {
        self.call(&Request::Infer(image))
    }

    /// Inference tagged with the client's link class (fleet routing).
    pub fn infer_class(&mut self, class: u8, image: HostTensor) -> Result<Response> {
        self.call(&Request::InferClass { class, image })
    }

    /// Partial inference against a cloud-stage server: run stages
    /// `split+1..=N` on a batched activation cut after stage `split`.
    pub fn infer_partial(
        &mut self,
        split: u32,
        branch_state: u8,
        activation: HostTensor,
    ) -> Result<Response> {
        self.call(&Request::InferPartial {
            split,
            branch_state,
            activation,
        })
    }

    /// Seq-tagged partial inference with an explicit wire encoding —
    /// still lockstep from this blocking client (one call, one answer);
    /// the pipelined demultiplexer lives in
    /// [`super::RemoteCloudEngine`].
    pub fn infer_partial_seq(
        &mut self,
        seq: u32,
        split: u32,
        branch_state: u8,
        encoding: WireEncoding,
        activation: HostTensor,
    ) -> Result<Response> {
        self.call(&Request::InferPartialSeq {
            seq,
            split,
            branch_state,
            encoding,
            activation,
        })
    }
}
