//! TCP accept loop + a blocking client, speaking `protocol` frames in
//! front of a running [`Coordinator`].

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::Coordinator;
use crate::runtime::HostTensor;

use super::protocol::{read_frame, write_frame, Request, Response};

pub struct Server {
    coordinator: Arc<Coordinator>,
}

/// Handle for stopping a running server.
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the accept loop with one last connection so it re-checks.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Server {
    pub fn new(coordinator: Arc<Coordinator>) -> Server {
        Server { coordinator }
    }

    /// Bind and serve in background threads. Port 0 picks a free port.
    pub fn start(self, port: u16) -> Result<ServerHandle> {
        let listener =
            TcpListener::bind(("127.0.0.1", port)).context("binding server socket")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        log::info!("serving on {addr}");

        let stop2 = stop.clone();
        let coordinator = self.coordinator;
        let accept_thread = std::thread::Builder::new()
            .name("accept-loop".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let c = coordinator.clone();
                            let _ = std::thread::Builder::new()
                                .name("conn".into())
                                .spawn(move || {
                                    if let Err(e) = handle_connection(stream, &c) {
                                        log::debug!("connection ended: {e:#}");
                                    }
                                });
                        }
                        Err(e) => log::warn!("accept error: {e}"),
                    }
                }
            })?;

        Ok(ServerHandle {
            addr,
            stop,
            accept_thread: Some(accept_thread),
        })
    }
}

fn handle_connection(stream: TcpStream, coordinator: &Coordinator) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);

    loop {
        let body = match read_frame(&mut reader) {
            Ok(b) => b,
            Err(_) => return Ok(()), // peer closed
        };
        let response = match Request::decode(&body) {
            Err(e) => Response::Error(format!("{e:#}")),
            Ok(Request::Ping) => Response::Pong,
            Ok(Request::Metrics) => {
                let snap = coordinator.metrics();
                Response::Metrics(format!(
                    "{{\"completed\":{},\"edge_exits\":{},\"rejected\":{},\
                     \"throughput_rps\":{:.3},\"p50_s\":{:.6},\"p99_s\":{:.6}}}",
                    snap.completed,
                    snap.edge_exits,
                    snap.rejected,
                    snap.throughput_rps,
                    snap.p50_s,
                    snap.p99_s
                ))
            }
            Ok(Request::Infer(tensor)) => match coordinator.infer_sync(tensor) {
                Ok(r) => Response::Result {
                    id: r.id,
                    class: r.class as u32,
                    exited_early: r.exited_early(),
                    entropy: r.entropy,
                    latency_s: r.latency_s,
                },
                Err(e) => Response::Error(format!("{e:#}")),
            },
        };
        write_frame(&mut writer, &response.encode())?;
    }
}

/// Blocking client for examples/tests/load generation.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting")?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    pub fn call(&mut self, req: &Request) -> Result<Response> {
        write_frame(&mut self.writer, &req.encode())?;
        let body = read_frame(&mut self.reader)?;
        Response::decode(&body)
    }

    pub fn ping(&mut self) -> Result<()> {
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => anyhow::bail!("expected PONG, got {other:?}"),
        }
    }

    pub fn infer(&mut self, image: HostTensor) -> Result<Response> {
        self.call(&Request::Infer(image))
    }
}
