//! TCP front end + a blocking client, speaking `protocol` frames in
//! front of any [`ServeBackend`] — a single [`Coordinator`] pipeline or
//! a whole [`crate::fleet::Fleet`].
//!
//! Two serving paths share the [`Server`] API and one dispatch table:
//!
//! * **Thread-per-connection** (this module): portable fallback. One
//!   blocking handler thread per accepted connection; handler threads
//!   are tracked and joined on [`ServerHandle::stop`], and accepts past
//!   `max_conns` are shed with a THROTTLE frame instead of spawning
//!   unbounded threads.
//! * **Reactor** ([`super::reactor`], Linux): one epoll readiness loop
//!   (or `reactor_threads` of them) multiplexing every connection,
//!   decode-in-place framing, bounded per-connection in-flight windows
//!   and queue-rejection backpressure as THROTTLE frames.
//!
//! Both paths answer byte-identical responses for the same request
//! stream — the reactor reuses [`respond_sync`] / [`result_response`]
//! from here, so the dispatch can't drift.

use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::{AdmitError, Coordinator, InferenceResponse, ReplyTo};
use crate::network::encoding::WireEncoding;
use crate::runtime::HostTensor;

use super::protocol::{read_frame, write_frame, PartialSample, Request, Response};

/// Retry hint carried by every server-originated THROTTLE frame, ms.
/// Small on purpose: backpressure here is queue-depth, not outage, and
/// a client that waits one batch window usually gets in.
pub const THROTTLE_RETRY_AFTER_MS: u32 = 25;

/// What a backend returns for one INFER_PARTIAL batch: one record per
/// input sample, in order, plus the backend's compute seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct PartialOutput {
    pub samples: Vec<PartialSample>,
    pub cloud_s: f64,
}

/// Outcome of a non-blocking [`ServeBackend::submit_infer`] admission.
#[derive(Debug)]
pub enum Submission {
    /// Admitted: the response will arrive at the submitted [`ReplyTo`]
    /// sink under the caller's tag. Carries the backend request id.
    Queued(u64),
    /// Completed synchronously (backends without an admission queue —
    /// the default implementation). `Err` maps to an ERROR frame.
    Ready(Result<InferenceResponse>),
    /// Transient backpressure (admission queue full) — the front end
    /// answers a THROTTLE frame and the request was *not* processed.
    Busy,
}

/// Front-end connection counters, shared by both serving paths and —
/// via [`ServeBackend::register_server_stats`] — surfaced inside the
/// backend's own metrics JSON.
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Connections accepted and handed to a handler (shed ones excluded).
    pub accepted: AtomicU64,
    /// Connections currently open.
    pub active: AtomicU64,
    /// High-water mark of `active`.
    pub conn_peak: AtomicU64,
    /// THROTTLE frames sent (window exceeded or admission queue full).
    pub throttled: AtomicU64,
    /// Connections refused at accept time by `max_conns`.
    pub conns_shed: AtomicU64,
}

/// Plain-data copy of [`ServerStats`] (one relaxed load per counter).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServerStatsSnapshot {
    pub accepted: u64,
    pub active: u64,
    pub conn_peak: u64,
    pub throttled: u64,
    pub conns_shed: u64,
}

impl ServerStats {
    /// Count one accepted connection; updates `active` and `conn_peak`.
    pub fn connection_opened(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
        let now = self.active.fetch_add(1, Ordering::Relaxed) + 1;
        self.conn_peak.fetch_max(now, Ordering::Relaxed);
    }

    pub fn connection_closed(&self) {
        self.active.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ServerStatsSnapshot {
        ServerStatsSnapshot {
            accepted: self.accepted.load(Ordering::Relaxed),
            active: self.active.load(Ordering::Relaxed),
            conn_peak: self.conn_peak.load(Ordering::Relaxed),
            throttled: self.throttled.load(Ordering::Relaxed),
            conns_shed: self.conns_shed.load(Ordering::Relaxed),
        }
    }
}

/// Decrements `active` when the connection handler exits, however it
/// exits.
struct ActiveGuard(Arc<ServerStats>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.connection_closed();
    }
}

/// Front-end tuning shared by both serving paths.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Serve with the event-driven reactor (Linux). Elsewhere the flag
    /// logs a warning and the portable thread-per-connection path runs.
    pub reactor: bool,
    /// Reactor threads (≥ 1). Thread 0 owns the listener and hands
    /// accepted connections to the others round-robin.
    pub reactor_threads: usize,
    /// Accept-time connection cap, enforced on both paths; 0 =
    /// unlimited. Over the cap a connection is answered one THROTTLE
    /// frame and closed, counted in `conns_shed`.
    pub max_conns: usize,
    /// Per-connection in-flight request window (reactor path only —
    /// the thread path is lockstep, window 1 by construction). Frames
    /// past the window are answered THROTTLE without touching
    /// admission.
    pub conn_window: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            reactor: false,
            reactor_threads: 1,
            max_conns: 0,
            conn_window: 32,
        }
    }
}

/// What the TCP front-end needs from whatever is serving behind it.
pub trait ServeBackend: Send + Sync + 'static {
    /// Serve one inference. `class` carries the protocol's link-class
    /// tag (`None` for an untagged legacy INFER); single-pipeline
    /// backends may ignore it.
    fn serve_infer(&self, class: Option<u8>, image: HostTensor) -> Result<InferenceResponse>;

    /// Non-blocking admission for multiplexing front ends: queue the
    /// request and deliver its response to `reply` later. The default
    /// computes inline via [`ServeBackend::serve_infer`] and returns
    /// [`Submission::Ready`] — correct for backends without an
    /// admission queue; queue-backed backends ([`Coordinator`],
    /// [`crate::fleet::Fleet`]) override with a true async submit so a
    /// reactor thread never blocks on inference.
    fn submit_infer(&self, class: Option<u8>, image: HostTensor, reply: ReplyTo) -> Submission {
        let _ = reply;
        Submission::Ready(self.serve_infer(class, image))
    }

    /// Serve one INFER_PARTIAL batch: run stages `split+1..=N` on a
    /// batched activation the edge cut after stage `split`. Only
    /// cloud-stage backends ([`super::CloudStageServer`]) implement
    /// this; edge-facing backends keep the default, which answers with
    /// an ERROR frame.
    fn serve_partial(
        &self,
        split: usize,
        branch_state: u8,
        activation: HostTensor,
    ) -> Result<PartialOutput> {
        let _ = (split, branch_state, activation);
        anyhow::bail!("this backend does not serve partial inference (not a cloud-stage server)")
    }

    /// [`ServeBackend::serve_partial`] for frames that carried a wire
    /// encoding tag (pipelined kind-5 requests — the activation arrives
    /// here already dequantized). The default forwards to
    /// `serve_partial`; cloud-stage backends override to keep
    /// per-encoding served counters.
    fn serve_partial_encoded(
        &self,
        split: usize,
        branch_state: u8,
        encoding: WireEncoding,
        activation: HostTensor,
    ) -> Result<PartialOutput> {
        let _ = encoding;
        self.serve_partial(split, branch_state, activation)
    }

    /// Serve one forwardable INFER_CHAIN_SEQ batch: run stages
    /// `cuts[0]+1..=cuts[1]` and ship the remainder onward (or, with a
    /// single cut, run `cuts[0]+1..=N` like
    /// [`ServeBackend::serve_partial_encoded`]). Only cloud-stage
    /// backends with a forward engine implement the multi-cut form;
    /// everything else keeps the default, which serves the single-cut
    /// degenerate case and errors on a genuine chain.
    fn serve_chain(
        &self,
        cuts: &[u32],
        branch_state: u8,
        encoding: WireEncoding,
        activation: HostTensor,
    ) -> Result<PartialOutput> {
        match cuts {
            [split] => self.serve_partial_encoded(*split as usize, branch_state, encoding, activation),
            _ => anyhow::bail!(
                "this backend does not forward chain inference (no --forward-addr)"
            ),
        }
    }

    /// Byte accounting hook: called by the connection loop with the
    /// framed request/response sizes (header included) after each
    /// exchange. Default: not counted.
    fn note_io(&self, bytes_received: u64, bytes_sent: u64) {
        let _ = (bytes_received, bytes_sent);
    }

    /// Called once by a starting [`Server`] so the backend can splice
    /// the front end's connection counters into its own metrics JSON.
    /// Default: not surfaced.
    fn register_server_stats(&self, stats: Arc<ServerStats>) {
        let _ = stats;
    }

    /// JSON body of the METRICS response.
    fn metrics_json(&self) -> String;
}

impl ServeBackend for Coordinator {
    fn serve_infer(&self, _class: Option<u8>, image: HostTensor) -> Result<InferenceResponse> {
        self.infer_sync(image)
    }

    fn submit_infer(&self, _class: Option<u8>, image: HostTensor, reply: ReplyTo) -> Submission {
        match self.submit_reply(image, None, reply) {
            Ok(id) => Submission::Queued(id),
            Err(AdmitError::Busy) => Submission::Busy,
            Err(AdmitError::Closed) => {
                Submission::Ready(Err(anyhow::anyhow!("coordinator shut down")))
            }
        }
    }

    fn metrics_json(&self) -> String {
        self.metrics().to_json()
    }
}

pub struct Server<B: ServeBackend> {
    backend: Arc<B>,
    config: ServerConfig,
}

/// One tracked thread-per-connection handler: the join handle plus a
/// second OS handle to its socket, so `stop()` can shut the socket down
/// and unblock the handler's `read_frame` before joining.
struct ConnSlot {
    handle: std::thread::JoinHandle<()>,
    stream: TcpStream,
}

enum HandleInner {
    Threads {
        stop: Arc<AtomicBool>,
        accept_thread: Option<std::thread::JoinHandle<()>>,
        conns: Arc<Mutex<Vec<ConnSlot>>>,
    },
    #[cfg(target_os = "linux")]
    Reactor(super::reactor::ReactorHandle),
}

/// Handle for stopping a running server.
pub struct ServerHandle {
    addr: SocketAddr,
    stats: Arc<ServerStats>,
    inner: HandleInner,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The front end's live connection counters.
    pub fn stats(&self) -> &Arc<ServerStats> {
        &self.stats
    }

    /// Stop accepting, unblock and join every handler thread (or the
    /// reactor threads). Returns promptly even with idle connections
    /// open: open sockets are shut down first, so no handler is left
    /// blocked in a read.
    pub fn stop(mut self) {
        match &mut self.inner {
            HandleInner::Threads {
                stop,
                accept_thread,
                conns,
            } => {
                stop.store(true, Ordering::SeqCst);
                // Poke the accept loop with one last connection so it
                // re-checks the flag.
                let _ = TcpStream::connect(self.addr);
                if let Some(t) = accept_thread.take() {
                    let _ = t.join();
                }
                let slots = std::mem::take(&mut *conns.lock().unwrap());
                // Shutdown first — every blocked read_frame returns —
                // then join; two passes so one slow handler never delays
                // another's wakeup.
                for s in &slots {
                    let _ = s.stream.shutdown(Shutdown::Both);
                }
                for s in slots {
                    let _ = s.handle.join();
                }
            }
            #[cfg(target_os = "linux")]
            HandleInner::Reactor(r) => r.stop(),
        }
    }
}

impl<B: ServeBackend> Server<B> {
    pub fn new(backend: Arc<B>) -> Server<B> {
        Server::with_config(backend, ServerConfig::default())
    }

    pub fn with_config(backend: Arc<B>, mut config: ServerConfig) -> Server<B> {
        config.reactor_threads = config.reactor_threads.max(1);
        config.conn_window = config.conn_window.max(1);
        Server { backend, config }
    }

    /// Bind loopback and serve in background threads. Port 0 picks a
    /// free port. Use [`Server::start_on`] to serve other machines.
    pub fn start(self, port: u16) -> Result<ServerHandle> {
        self.start_on("127.0.0.1", port)
    }

    /// [`Server::start`] with an explicit bind address — `"0.0.0.0"`
    /// accepts connections from other hosts (a cloud-stage server
    /// fronting a remote edge needs this; loopback is the safe default
    /// for single-machine serving).
    pub fn start_on(self, bind: &str, port: u16) -> Result<ServerHandle> {
        let listener = TcpListener::bind((bind, port)).context("binding server socket")?;
        let addr = listener.local_addr()?;
        let stats = Arc::new(ServerStats::default());
        self.backend.register_server_stats(stats.clone());

        if self.config.reactor {
            #[cfg(target_os = "linux")]
            {
                log::info!(
                    "serving on {addr} (reactor, {} thread(s))",
                    self.config.reactor_threads
                );
                let handle = super::reactor::start(
                    self.backend,
                    listener,
                    self.config,
                    stats.clone(),
                )?;
                return Ok(ServerHandle {
                    addr,
                    stats,
                    inner: HandleInner::Reactor(handle),
                });
            }
            #[cfg(not(target_os = "linux"))]
            log::warn!("--reactor needs Linux epoll; falling back to thread-per-connection");
        }

        log::info!("serving on {addr} (thread-per-connection)");
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<ConnSlot>>> = Arc::new(Mutex::new(Vec::new()));

        let stop2 = stop.clone();
        let conns2 = conns.clone();
        let stats2 = stats.clone();
        let backend = self.backend;
        let max_conns = self.config.max_conns;
        let accept_thread = std::thread::Builder::new()
            .name("accept-loop".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let mut slots = conns2.lock().unwrap();
                            // Reap finished handlers so the slot list —
                            // and the active-connection count the cap
                            // reads — tracks live connections only.
                            slots.retain(|s| !s.handle.is_finished());
                            if max_conns > 0 && slots.len() >= max_conns {
                                drop(slots);
                                shed_connection(stream, &stats2);
                                continue;
                            }
                            let Ok(shutdown_handle) = stream.try_clone() else {
                                continue;
                            };
                            stats2.connection_opened();
                            let guard = ActiveGuard(stats2.clone());
                            let b = backend.clone();
                            let spawned = std::thread::Builder::new()
                                .name("conn".into())
                                .spawn(move || {
                                    let _guard = guard;
                                    if let Err(e) = handle_connection(stream, b.as_ref()) {
                                        log::debug!("connection ended: {e:#}");
                                    }
                                });
                            match spawned {
                                Ok(handle) => slots.push(ConnSlot {
                                    handle,
                                    stream: shutdown_handle,
                                }),
                                Err(e) => log::warn!("spawning handler failed: {e}"),
                            }
                        }
                        Err(e) => log::warn!("accept error: {e}"),
                    }
                }
            })?;

        Ok(ServerHandle {
            addr,
            stats,
            inner: HandleInner::Threads {
                stop,
                accept_thread: Some(accept_thread),
                conns,
            },
        })
    }
}

/// Refuse a connection over `max_conns`: answer one best-effort
/// THROTTLE frame (the socket was just accepted, so its empty send
/// buffer takes the 13 bytes without blocking) and close.
pub(super) fn shed_connection(stream: TcpStream, stats: &ServerStats) {
    stats.conns_shed.fetch_add(1, Ordering::Relaxed);
    let mut w = BufWriter::new(stream);
    let _ = write_frame(
        &mut w,
        &Response::Throttle {
            retry_after_ms: THROTTLE_RETRY_AFTER_MS,
        }
        .encode(),
    );
    let _ = w.flush();
}

/// Convert a finished inference into its wire response. Both serving
/// paths answer through this one function, so their RESULT bytes are
/// identical by construction.
pub(super) fn result_response(r: &InferenceResponse) -> Response {
    Response::Result {
        id: r.id,
        class: r.class as u32,
        exited_early: r.exited_early(),
        entropy: r.entropy,
        latency_s: r.latency_s,
    }
}

fn infer_response(backend: &impl ServeBackend, class: Option<u8>, image: HostTensor) -> Response {
    match backend.serve_infer(class, image) {
        Ok(r) => result_response(&r),
        Err(e) => Response::Error(format!("{e:#}")),
    }
}

/// Synchronous dispatch of one decoded request — the thread path's
/// whole table, and the reactor's table for everything it does not
/// admit asynchronously (PING, METRICS, the partial-inference kinds).
pub(super) fn respond_sync(backend: &impl ServeBackend, req: Request) -> Response {
    match req {
        Request::Ping => Response::Pong,
        Request::Metrics => Response::Metrics(backend.metrics_json()),
        Request::Infer(tensor) => infer_response(backend, None, tensor),
        Request::InferClass { class, image } => infer_response(backend, Some(class), image),
        Request::InferPartial {
            split,
            branch_state,
            activation,
        } => match backend.serve_partial_encoded(
            split as usize,
            branch_state,
            WireEncoding::Raw,
            activation,
        ) {
            Ok(out) => Response::PartialResult {
                samples: out.samples,
                cloud_s: out.cloud_s,
            },
            Err(e) => Response::Error(format!("{e:#}")),
        },
        // Pipelined: answers are written in arrival order on this
        // connection (the client's reader matches on the echoed seq,
        // so ordering is a non-requirement it gets for free), and
        // errors stay scoped to their seq instead of poisoning the
        // other in-flight requests.
        Request::InferPartialSeq {
            seq,
            split,
            branch_state,
            encoding,
            activation,
        } => match backend.serve_partial_encoded(split as usize, branch_state, encoding, activation)
        {
            Ok(out) => Response::PartialResultSeq {
                seq,
                samples: out.samples,
                cloud_s: out.cloud_s,
            },
            Err(e) => Response::ErrorSeq {
                seq,
                message: format!("{e:#}"),
            },
        },
        // Chain frames answer with the same seq-scoped responses as
        // kind 5, so a pooled client needs no new reader logic.
        Request::InferChainSeq {
            seq,
            cuts,
            branch_state,
            encoding,
            activation,
        } => match backend.serve_chain(&cuts, branch_state, encoding, activation) {
            Ok(out) => Response::PartialResultSeq {
                seq,
                samples: out.samples,
                cloud_s: out.cloud_s,
            },
            Err(e) => Response::ErrorSeq {
                seq,
                message: format!("{e:#}"),
            },
        },
    }
}

fn handle_connection(stream: TcpStream, backend: &impl ServeBackend) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);

    loop {
        let body = match read_frame(&mut reader) {
            Ok(b) => b,
            Err(_) => return Ok(()), // peer closed
        };
        let response = match Request::decode(&body) {
            Err(e) => Response::Error(format!("{e:#}")),
            Ok(req) => respond_sync(backend, req),
        };
        let encoded = response.encode();
        write_frame(&mut writer, &encoded)?;
        // 8-byte frame headers included on both directions.
        backend.note_io(body.len() as u64 + 8, encoded.len() as u64 + 8);
    }
}

/// Blocking client for examples/tests/load generation.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting")?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    pub fn call(&mut self, req: &Request) -> Result<Response> {
        write_frame(&mut self.writer, &req.encode())?;
        let body = read_frame(&mut self.reader)?;
        Response::decode(&body)
    }

    pub fn ping(&mut self) -> Result<()> {
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => anyhow::bail!("expected PONG, got {other:?}"),
        }
    }

    pub fn infer(&mut self, image: HostTensor) -> Result<Response> {
        self.call(&Request::Infer(image))
    }

    /// Inference tagged with the client's link class (fleet routing).
    pub fn infer_class(&mut self, class: u8, image: HostTensor) -> Result<Response> {
        self.call(&Request::InferClass { class, image })
    }

    /// [`Client::infer`] honoring the THROTTLE contract: on a THROTTLE
    /// answer, sleep the server's `retry_after_ms` hint and resend, up
    /// to `max_retries` times before giving up with the last frame.
    pub fn infer_with_backoff(
        &mut self,
        image: HostTensor,
        max_retries: usize,
    ) -> Result<Response> {
        for _ in 0..=max_retries {
            match self.call(&Request::Infer(image.clone()))? {
                Response::Throttle { retry_after_ms } => {
                    std::thread::sleep(std::time::Duration::from_millis(retry_after_ms as u64));
                }
                other => return Ok(other),
            }
        }
        Ok(Response::Throttle {
            retry_after_ms: THROTTLE_RETRY_AFTER_MS,
        })
    }

    /// Partial inference against a cloud-stage server: run stages
    /// `split+1..=N` on a batched activation cut after stage `split`.
    pub fn infer_partial(
        &mut self,
        split: u32,
        branch_state: u8,
        activation: HostTensor,
    ) -> Result<Response> {
        self.call(&Request::InferPartial {
            split,
            branch_state,
            activation,
        })
    }

    /// Seq-tagged partial inference with an explicit wire encoding —
    /// still lockstep from this blocking client (one call, one answer);
    /// the pipelined demultiplexer lives in
    /// [`super::RemoteCloudEngine`].
    pub fn infer_partial_seq(
        &mut self,
        seq: u32,
        split: u32,
        branch_state: u8,
        encoding: WireEncoding,
        activation: HostTensor,
    ) -> Result<Response> {
        self.call(&Request::InferPartialSeq {
            seq,
            split,
            branch_state,
            encoding,
            activation,
        })
    }

    /// Chain inference against a forwarding cloud-stage server: the
    /// activation sits at `cuts[0]`; the server runs its segment and
    /// forwards the rest down the chain. Lockstep like
    /// [`Client::infer_partial_seq`].
    pub fn infer_chain_seq(
        &mut self,
        seq: u32,
        cuts: Vec<u32>,
        branch_state: u8,
        encoding: WireEncoding,
        activation: HostTensor,
    ) -> Result<Response> {
        self.call(&Request::InferChainSeq {
            seq,
            cuts,
            branch_state,
            encoding,
            activation,
        })
    }
}
