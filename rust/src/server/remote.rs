//! The remote cloud engine: the edge side of a physically partitioned
//! deployment.
//!
//! A [`RemoteCloudEngine`] turns a [`super::CloudStageServer`] across
//! the network into something the coordinator's cloud workers can call
//! like a local engine: it ships each transferred split-group as one
//! seq-tagged INFER_PARTIAL_SEQ frame and returns the server's
//! per-sample classes and compute time. It is deliberately dumb about
//! *planning* — every frame carries its own cut, so it never needs the
//! live partition plan.
//!
//! **Pipelined, not lockstep.** Each pooled connection carries up to
//! the in-flight cap of concurrent requests: callers stream frames
//! through a shared writer, and a per-connection reader thread matches
//! every response to its waiter by the echoed `seq`. A slow batch no
//! longer serializes the batches behind it — under concurrency the wire
//! stays full instead of idling for a round-trip per batch. The
//! activation payload crosses the wire in the configured
//! [`WireEncoding`] (raw f32, q8, or q4 — the server dequantizes).
//!
//! Failure posture (the edge must keep serving when the cloud is not
//! reachable — the caller falls back to local execution):
//!
//! * **Pooled connections** — persistent streams shared across calls;
//!   the least-loaded healthy connection takes the next frame, and the
//!   pool grows on demand up to `pool_capacity` connections.
//! * **Reconnect with backoff** — after a connect/IO failure the engine
//!   fast-fails every call until the backoff window expires
//!   (exponential from `backoff_initial` to `backoff_max`, reset on the
//!   first success), so a dead cloud costs the serving path one failed
//!   connect per window instead of one per batch.
//! * **In-flight cap** — at most `max_inflight` concurrent requests;
//!   calls beyond the cap fail immediately (and the caller runs the
//!   batch locally) rather than queueing behind a slow remote.
//! * **Rejection breaker** — a healthy link that keeps answering with
//!   ERROR_SEQ frames (wrong server kind, mismatched model) is a
//!   misconfiguration, not a transient: after [`REJECTION_BREAKER`]
//!   consecutive rejections the engine enters a `backoff_max` window
//!   too, so a misconfigured cloud doesn't cost a full tensor
//!   round-trip per batch forever. Rejections stay scoped to their seq:
//!   the other in-flight requests on the connection are untouched.
//!
//! Per-call deadlines are enforced by the waiter (`recv_timeout` on the
//! reply channel), not by a socket read timeout — the reader thread
//! must be allowed to block forever on an *idle* connection without
//! declaring it dead.

use std::collections::HashMap;
use std::io::BufWriter;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::network::encoding::WireEncoding;
use crate::runtime::HostTensor;

use super::protocol::{
    encode_infer_chain_seq, encode_infer_partial_seq, read_frame, write_frame, Request,
    Response,
};
use super::tcp::PartialOutput;

#[derive(Debug, Clone)]
pub struct RemoteCloudConfig {
    /// `HOST:PORT` of the cloud-stage server.
    pub addr: String,
    /// Wire encoding of the activation payload (the server dequantizes;
    /// results come back as plain classes either way).
    pub encoding: WireEncoding,
    /// Max concurrent requests; calls beyond this fail fast (the
    /// coordinator then executes the batch on the local fallback).
    pub max_inflight: usize,
    /// Connections kept in the pool (each carries many in-flight
    /// requests; more connections mainly buy TCP-level parallelism).
    pub pool_capacity: usize,
    pub connect_timeout: Duration,
    /// Per-call deadline — must cover the server's compute time for one
    /// batch plus the queueing ahead of it on the shared connection.
    pub io_timeout: Duration,
    pub backoff_initial: Duration,
    pub backoff_max: Duration,
}

impl RemoteCloudConfig {
    pub fn new(addr: impl Into<String>) -> RemoteCloudConfig {
        RemoteCloudConfig {
            addr: addr.into(),
            encoding: WireEncoding::Raw,
            max_inflight: 8,
            pool_capacity: 8,
            connect_timeout: Duration::from_secs(2),
            io_timeout: Duration::from_secs(30),
            backoff_initial: Duration::from_millis(100),
            backoff_max: Duration::from_secs(5),
        }
    }
}

/// What the reader thread hands a waiter: the server's answer for that
/// seq. `Err` is an application-level rejection (ERROR_SEQ) — the
/// connection itself is still healthy. Connection-level failures are
/// signalled by dropping the sender (the waiter sees `Disconnected`).
type Reply = std::result::Result<PartialOutput, String>;

/// One pooled connection: a shared writer callers stream frames
/// through, and a pending map the reader thread resolves by seq.
struct Conn {
    /// Kept to `shutdown()` the socket when the connection is declared
    /// broken — that is what unblocks the reader thread.
    stream: TcpStream,
    writer: Mutex<BufWriter<TcpStream>>,
    pending: Mutex<HashMap<u32, mpsc::SyncSender<Reply>>>,
    /// Requests currently in flight on *this* connection (checkout
    /// picks the least-loaded one).
    inflight: AtomicUsize,
    broken: AtomicBool,
}

impl Conn {
    /// Declare the connection dead: no new checkouts, reader unblocked
    /// (socket shutdown), every waiter released (senders dropped).
    fn mark_broken(&self) {
        self.broken.store(true, Ordering::SeqCst);
        self.stream.shutdown(std::net::Shutdown::Both).ok();
        self.pending.lock().unwrap().clear();
    }
}

/// Consecutive application-level rejections (ERROR_SEQ frames) after
/// which the engine backs off as if the link had failed — the server is
/// reachable but persistently rejecting (wrong server kind, mismatched
/// model), and shipping a full activation per batch to learn that again
/// is waste.
pub const REJECTION_BREAKER: u32 = 3;

#[derive(Debug, Default)]
struct Backoff {
    until: Option<Instant>,
    consecutive: u32,
    /// Consecutive application-level rejections (ERROR_SEQ frames).
    rejections: u32,
}

/// Counters for observability; all monotonic except `inflight_peak`
/// (a high-water mark).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RemoteCloudStats {
    /// INFER_PARTIAL_SEQ frames attempted (excludes fast-fails).
    pub requests: u64,
    /// Connect/IO/protocol failures.
    pub failures: u64,
    /// Calls rejected without touching the network (backoff window).
    pub fast_fails: u64,
    /// Calls rejected at the in-flight cap.
    pub saturated: u64,
    /// TCP connections established (reconnects included).
    pub connects: u64,
    /// Calls whose pooled connection had died idle and were retried on
    /// a freshly dialed one (not failures — the retry usually wins).
    pub stale_retries: u64,
    /// Framed bytes written to the wire (8-byte headers included).
    pub bytes_sent: u64,
    /// Framed bytes read off the wire (8-byte headers included).
    pub bytes_received: u64,
    /// High-water mark of concurrent in-flight requests — the direct
    /// measure of how much pipelining actually happened.
    pub inflight_peak: u64,
}

pub struct RemoteCloudEngine {
    cfg: RemoteCloudConfig,
    /// Administrative availability switch (default on). Off = every
    /// call fails immediately *without* touching the backoff/breaker
    /// or connection state, so flipping it back restores the wire path
    /// on the very next call. This is how the scenario harness scripts
    /// cloud brownout/outage windows deterministically — real network
    /// failure handling (backoff, reconnect) stays untouched.
    available: AtomicBool,
    pool: Mutex<Vec<Arc<Conn>>>,
    inflight: AtomicUsize,
    next_seq: AtomicU32,
    backoff: Mutex<Backoff>,
    requests: AtomicU64,
    failures: AtomicU64,
    fast_fails: AtomicU64,
    saturated: AtomicU64,
    connects: AtomicU64,
    stale_retries: AtomicU64,
    bytes_sent: AtomicU64,
    /// `Arc` so per-connection reader threads can count into it without
    /// borrowing the engine.
    bytes_received: Arc<AtomicU64>,
    inflight_peak: AtomicU64,
}

/// RAII release of one engine-level in-flight slot.
struct InflightGuard<'a>(&'a AtomicUsize);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

impl RemoteCloudEngine {
    /// Construction is lazy: no connection is attempted until the first
    /// call, so an edge node starts (and serves, via local fallback)
    /// while its cloud is still down.
    pub fn new(mut cfg: RemoteCloudConfig) -> RemoteCloudEngine {
        cfg.max_inflight = cfg.max_inflight.max(1);
        cfg.pool_capacity = cfg.pool_capacity.max(1);
        RemoteCloudEngine {
            cfg,
            available: AtomicBool::new(true),
            pool: Mutex::new(Vec::new()),
            inflight: AtomicUsize::new(0),
            next_seq: AtomicU32::new(1),
            backoff: Mutex::new(Backoff::default()),
            requests: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            fast_fails: AtomicU64::new(0),
            saturated: AtomicU64::new(0),
            connects: AtomicU64::new(0),
            stale_retries: AtomicU64::new(0),
            bytes_sent: AtomicU64::new(0),
            bytes_received: Arc::new(AtomicU64::new(0)),
            inflight_peak: AtomicU64::new(0),
        }
    }

    pub fn addr(&self) -> &str {
        &self.cfg.addr
    }

    /// Administratively mark the endpoint up/down (see the `available`
    /// field). Down = instant failure on every call, so cloud workers
    /// fall back to their local engines; up = the wire path is live
    /// again immediately (no backoff to age out).
    pub fn set_available(&self, up: bool) {
        self.available.store(up, Ordering::Relaxed);
    }

    /// Whether the endpoint is administratively up (it may still be
    /// unreachable — this switch is scripted, not probed).
    pub fn is_available(&self) -> bool {
        self.available.load(Ordering::Relaxed)
    }

    /// The wire encoding this engine ships activations in.
    pub fn encoding(&self) -> WireEncoding {
        self.cfg.encoding
    }

    pub fn stats(&self) -> RemoteCloudStats {
        RemoteCloudStats {
            requests: self.requests.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            fast_fails: self.fast_fails.load(Ordering::Relaxed),
            saturated: self.saturated.load(Ordering::Relaxed),
            connects: self.connects.load(Ordering::Relaxed),
            stale_retries: self.stale_retries.load(Ordering::Relaxed),
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            bytes_received: self.bytes_received.load(Ordering::Relaxed),
            inflight_peak: self.inflight_peak.load(Ordering::Relaxed),
        }
    }

    /// Round-trip a PING (health probe; used at startup for a loud
    /// "cloud reachable/unreachable" log line). Runs lockstep on its
    /// own short-lived connection — pooled connections' read side
    /// belongs to their reader threads. Subject to the same backoff
    /// bookkeeping as inference calls.
    pub fn ping(&self) -> Result<()> {
        match self.ping_once() {
            Ok(()) => {
                self.note_success();
                Ok(())
            }
            Err(e) => {
                self.note_failure();
                Err(e)
            }
        }
    }

    fn ping_once(&self) -> Result<()> {
        let stream = self.dial_stream()?;
        stream.set_read_timeout(Some(self.cfg.io_timeout)).ok();
        let mut reader = std::io::BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        let body = Request::Ping.encode();
        write_frame(&mut writer, &body)?;
        self.bytes_sent
            .fetch_add(body.len() as u64 + 8, Ordering::Relaxed);
        let reply = read_frame(&mut reader)?;
        self.bytes_received
            .fetch_add(reply.len() as u64 + 8, Ordering::Relaxed);
        match Response::decode(&reply)? {
            Response::Pong => Ok(()),
            other => bail!("expected PONG, got {other:?}"),
        }
    }

    /// Ship one split-group to the cloud-stage server: run stages
    /// `split+1..=N` on `activation` (a batched tensor cut after stage
    /// `split`) and return one record per sample. The payload crosses
    /// the wire in the configured encoding; concurrent calls pipeline
    /// on shared connections. Fails fast when the engine is in backoff
    /// or at the in-flight cap — the caller is expected to fall back to
    /// local execution.
    pub fn infer_partial(
        &self,
        split: usize,
        branch_state: u8,
        activation: &HostTensor,
    ) -> Result<PartialOutput> {
        self.dispatch(|seq, enc| {
            encode_infer_partial_seq(seq, split as u32, branch_state, enc, activation)
        })
    }

    /// Ship one chain frame: the server runs its own segment
    /// (`cuts[0]+1..=cuts[1]`, or the full suffix for a single cut) and
    /// forwards the remainder down the chain, so the reply's `cloud_s`
    /// covers every downstream tier. Same pooling, pipelining, backoff,
    /// and breaker behaviour as [`RemoteCloudEngine::infer_partial`] —
    /// the frames share the seq space and the response kinds.
    pub fn infer_chain(
        &self,
        cuts: &[u32],
        branch_state: u8,
        activation: &HostTensor,
    ) -> Result<PartialOutput> {
        self.dispatch(|seq, enc| {
            encode_infer_chain_seq(seq, cuts, branch_state, enc, activation)
        })
    }

    /// The shared seq-frame machinery behind both inference entry
    /// points: availability/backoff/saturation gates, checkout, and the
    /// stale-retry loop. `build` encodes the frame for a given seq —
    /// encoded once, straight from the borrowed tensor (quantized per
    /// the configured encoding, no owned Request, no activation clone
    /// on the hot path); the same body (same seq) is reused on a stale
    /// retry since the fresh connection has an empty pending map.
    fn dispatch(
        &self,
        build: impl FnOnce(u32, WireEncoding) -> Vec<u8>,
    ) -> Result<PartialOutput> {
        if !self.is_available() {
            // Before any counter or backoff bookkeeping: an
            // administrative outage is scripted, not observed, and must
            // leave the failure-handling state exactly as it found it.
            bail!(
                "cloud backend {} administratively unavailable",
                self.cfg.addr
            );
        }
        if let Some(remaining) = self.backoff_remaining() {
            self.fast_fails.fetch_add(1, Ordering::Relaxed);
            bail!(
                "cloud backend {} in backoff for another {remaining:.0?}",
                self.cfg.addr
            );
        }
        if !self.try_acquire() {
            self.saturated.fetch_add(1, Ordering::Relaxed);
            bail!(
                "cloud backend {} saturated ({} requests in flight)",
                self.cfg.addr,
                self.cfg.max_inflight
            );
        }
        let _slot = InflightGuard(&self.inflight);

        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let body = build(seq, self.cfg.encoding);

        let (mut conn, mut pooled) = match self.checkout() {
            Ok(c) => c,
            Err(e) => {
                self.note_failure();
                return Err(e);
            }
        };
        loop {
            self.requests.fetch_add(1, Ordering::Relaxed);
            match self.attempt(&conn, seq, &body) {
                Attempt::Done(out) => {
                    self.note_success();
                    return Ok(out);
                }
                // An ERROR_SEQ frame means the link is healthy but the
                // server rejected this batch (bad split, engine error):
                // the connection keeps serving its other in-flight
                // requests; report the failure up and trip the
                // rejection breaker if it keeps happening.
                Attempt::Rejected(msg) => {
                    self.note_rejection();
                    bail!("cloud server rejected partial batch: {msg}")
                }
                // A pooled stream may have died idle (server restart,
                // NAT timeout) — that says nothing about the server's
                // current health, so retry exactly once on a freshly
                // dialed connection before declaring a failure.
                Attempt::ConnDead(e) if pooled => {
                    log::debug!("pooled cloud connection was stale ({e:#}); redialing");
                    self.stale_retries.fetch_add(1, Ordering::Relaxed);
                    self.evict(&conn);
                    conn = match self.dial() {
                        Ok(c) => c,
                        Err(de) => {
                            self.note_failure();
                            return Err(de);
                        }
                    };
                    pooled = false;
                }
                Attempt::ConnDead(e) => {
                    self.evict(&conn);
                    self.note_failure();
                    return Err(
                        e.context(format!("cloud round-trip to {} failed", self.cfg.addr))
                    );
                }
            }
        }
    }

    /// One pipelined exchange on one connection: register the waiter,
    /// stream the frame through the shared writer, block on the reply
    /// channel until the reader thread resolves this seq.
    fn attempt(&self, conn: &Arc<Conn>, seq: u32, body: &[u8]) -> Attempt {
        let (tx, rx) = mpsc::sync_channel::<Reply>(1);
        conn.pending.lock().unwrap().insert(seq, tx);
        conn.inflight.fetch_add(1, Ordering::AcqRel);
        let _conn_slot = InflightGuard(&conn.inflight);

        let write_result = {
            let mut w = conn.writer.lock().unwrap();
            write_frame(&mut *w, body)
        };
        if let Err(e) = write_result {
            conn.pending.lock().unwrap().remove(&seq);
            conn.mark_broken();
            return Attempt::ConnDead(e);
        }
        self.bytes_sent
            .fetch_add(body.len() as u64 + 8, Ordering::Relaxed);

        match rx.recv_timeout(self.cfg.io_timeout) {
            Ok(Ok(out)) => Attempt::Done(out),
            Ok(Err(msg)) => Attempt::Rejected(msg),
            // Sender dropped: the reader thread declared the connection
            // dead and drained the pending map.
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Attempt::ConnDead(anyhow::anyhow!("connection closed mid-request"))
            }
            // Deadline blown with the connection still nominally up: a
            // stuck server or a half-dead link. Kill the connection so
            // its other waiters fail fast too instead of each burning a
            // full timeout.
            Err(mpsc::RecvTimeoutError::Timeout) => {
                conn.pending.lock().unwrap().remove(&seq);
                conn.mark_broken();
                Attempt::ConnDead(anyhow::anyhow!(
                    "no response within {:?}",
                    self.cfg.io_timeout
                ))
            }
        }
    }

    fn try_acquire(&self) -> bool {
        let acquired = self
            .inflight
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
                (n < self.cfg.max_inflight).then_some(n + 1)
            });
        if let Ok(prev) = acquired {
            self.inflight_peak
                .fetch_max(prev as u64 + 1, Ordering::Relaxed);
        }
        acquired.is_ok()
    }

    /// Seconds left in the backoff window, if one is active.
    fn backoff_remaining(&self) -> Option<Duration> {
        let b = self.backoff.lock().unwrap();
        let until = b.until?;
        let now = Instant::now();
        if now < until {
            Some(until - now)
        } else {
            None
        }
    }

    /// A connection to run one call on, and whether it was already in
    /// the pool (pooled streams may have died idle; the caller retries
    /// those once on a fresh dial). Policy: prune broken connections,
    /// reuse an idle one if any, grow the pool while a healthy
    /// connection is busy and there is capacity, otherwise share the
    /// least-loaded one — that is the pipelining case.
    fn checkout(&self) -> Result<(Arc<Conn>, bool)> {
        {
            let mut pool = self.pool.lock().unwrap();
            pool.retain(|c| !c.broken.load(Ordering::SeqCst));
            let best = pool
                .iter()
                .min_by_key(|c| c.inflight.load(Ordering::Acquire))
                .cloned();
            if let Some(best) = best {
                if best.inflight.load(Ordering::Acquire) == 0
                    || pool.len() >= self.cfg.pool_capacity
                {
                    return Ok((best, true));
                }
            }
        }
        Ok((self.dial()?, false))
    }

    /// Drop a dead connection from the pool (it may already be gone).
    fn evict(&self, conn: &Arc<Conn>) {
        conn.mark_broken();
        self.pool
            .lock()
            .unwrap()
            .retain(|c| !Arc::ptr_eq(c, conn));
    }

    /// Dial a raw stream, trying every resolved address until one
    /// connects — a dual-stack hostname must not strand the edge on an
    /// IPv6 address when the cloud server only listens on IPv4 (or vice
    /// versa).
    fn dial_stream(&self) -> Result<TcpStream> {
        let addrs: Vec<SocketAddr> = self
            .cfg
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolving cloud address '{}'", self.cfg.addr))?
            .collect();
        if addrs.is_empty() {
            bail!("cloud address '{}' resolved to nothing", self.cfg.addr);
        }
        let mut last_err = None;
        for addr in &addrs {
            match TcpStream::connect_timeout(addr, self.cfg.connect_timeout) {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    // No read timeout: the reader thread must block
                    // forever on an idle connection. Per-call deadlines
                    // are the waiter's recv_timeout.
                    stream.set_write_timeout(Some(self.cfg.io_timeout)).ok();
                    self.connects.fetch_add(1, Ordering::Relaxed);
                    return Ok(stream);
                }
                Err(e) => last_err = Some((*addr, e)),
            }
        }
        let (addr, e) = last_err.expect("addrs is non-empty");
        Err(anyhow::Error::new(e).context(format!(
            "connecting to cloud server {addr} ({} resolved address(es) tried)",
            addrs.len()
        )))
    }

    /// Dial a fresh pipelined connection: spawn its reader thread and
    /// add it to the pool (if there is room) so concurrent callers can
    /// share it immediately.
    fn dial(&self) -> Result<Arc<Conn>> {
        let stream = self.dial_stream()?;
        let conn = Arc::new(Conn {
            writer: Mutex::new(BufWriter::new(
                stream.try_clone().context("cloning cloud stream")?,
            )),
            pending: Mutex::new(HashMap::new()),
            inflight: AtomicUsize::new(0),
            broken: AtomicBool::new(false),
            stream,
        });
        let reader_conn = conn.clone();
        let reader_stream = conn.stream.try_clone().context("cloning cloud stream")?;
        let bytes_received = self.bytes_received.clone();
        std::thread::Builder::new()
            .name("cloud-rx".into())
            .spawn(move || reader_loop(reader_stream, reader_conn, bytes_received))
            .context("spawning cloud reader thread")?;
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < self.cfg.pool_capacity {
            pool.push(conn.clone());
        }
        Ok(conn)
    }

    fn note_success(&self) {
        let mut b = self.backoff.lock().unwrap();
        b.consecutive = 0;
        b.rejections = 0;
        b.until = None;
    }

    /// The link round-tripped but the server answered ERROR_SEQ. The
    /// connection stays pooled and the failure counters stay untouched;
    /// persistent rejection still engages a full backoff window so a
    /// misconfigured cloud isn't paid for per batch.
    fn note_rejection(&self) {
        let mut b = self.backoff.lock().unwrap();
        b.consecutive = 0;
        b.rejections = b.rejections.saturating_add(1);
        if b.rejections >= REJECTION_BREAKER {
            log::warn!(
                "cloud backend {} rejected {} consecutive batches; backing off {:?} \
                 (is it a cloud-serve instance with the same model?)",
                self.cfg.addr,
                b.rejections,
                self.cfg.backoff_max
            );
            b.until = Some(Instant::now() + self.cfg.backoff_max);
        }
    }

    fn note_failure(&self) {
        self.failures.fetch_add(1, Ordering::Relaxed);
        // A failed connection is useless to siblings too: drop the pool
        // so the next successful call starts from fresh streams.
        {
            let mut pool = self.pool.lock().unwrap();
            for c in pool.iter() {
                c.mark_broken();
            }
            pool.clear();
        }
        let mut b = self.backoff.lock().unwrap();
        b.consecutive = b.consecutive.saturating_add(1);
        // 100ms, 200ms, 400ms, ... capped at backoff_max.
        let doublings = (b.consecutive - 1).min(6);
        let delay = self
            .cfg
            .backoff_initial
            .saturating_mul(1u32 << doublings)
            .min(self.cfg.backoff_max);
        b.until = Some(Instant::now() + delay);
    }
}

enum Attempt {
    Done(PartialOutput),
    /// Application-level ERROR_SEQ: the connection is healthy.
    Rejected(String),
    /// The connection is dead (write failed, stream closed, deadline
    /// blown); retry once on a fresh one if it came from the pool.
    ConnDead(anyhow::Error),
}

/// Per-connection reader: demultiplexes seq-tagged responses to their
/// waiters. Exits — marking the connection broken and releasing every
/// waiter — on stream close, decode failure, or a protocol violation
/// (unknown seq, non-seq frame): once the response stream can't be
/// trusted to match requests, every in-flight call on the connection
/// must fail rather than risk crossed answers.
fn reader_loop(stream: TcpStream, conn: Arc<Conn>, bytes_received: Arc<AtomicU64>) {
    let mut reader = std::io::BufReader::new(stream);
    loop {
        let reply = match read_frame(&mut reader) {
            Ok(r) => r,
            Err(_) => break, // closed or shut down
        };
        bytes_received.fetch_add(reply.len() as u64 + 8, Ordering::Relaxed);
        match Response::decode(&reply) {
            Ok(Response::PartialResultSeq {
                seq,
                samples,
                cloud_s,
            }) => match conn.pending.lock().unwrap().remove(&seq) {
                Some(tx) => {
                    let _ = tx.send(Ok(PartialOutput { samples, cloud_s }));
                }
                None => {
                    log::warn!("cloud server answered unknown seq {seq}; dropping connection");
                    break;
                }
            },
            Ok(Response::ErrorSeq { seq, message }) => {
                match conn.pending.lock().unwrap().remove(&seq) {
                    Some(tx) => {
                        let _ = tx.send(Err(message));
                    }
                    None => {
                        log::warn!(
                            "cloud server rejected unknown seq {seq}; dropping connection"
                        );
                        break;
                    }
                }
            }
            Ok(other) => {
                log::warn!("unexpected response on pipelined connection: {other:?}");
                break;
            }
            Err(e) => {
                log::warn!("undecodable response on pipelined connection: {e:#}");
                break;
            }
        }
    }
    conn.mark_broken();
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::io::BufReader;

    use crate::model::Manifest;
    use crate::runtime::InferenceEngine;
    use crate::server::cloud::CloudStageServer;
    use crate::server::tcp::Server;

    fn unreachable_engine() -> RemoteCloudEngine {
        // Port 1 on loopback: connection refused immediately.
        RemoteCloudEngine::new(RemoteCloudConfig {
            backoff_initial: Duration::from_millis(50),
            ..RemoteCloudConfig::new("127.0.0.1:1")
        })
    }

    fn live_server() -> (crate::server::tcp::ServerHandle, Arc<CloudStageServer>) {
        let manifest =
            Manifest::synthetic_sim("sim-stale", vec![4], &[16, 8, 2], 1, 2, vec![1, 2]).unwrap();
        let css = Arc::new(CloudStageServer::new(
            InferenceEngine::open_sim(manifest, "stale-srv").unwrap(),
        ));
        let handle = Server::new(css.clone()).start(0).unwrap();
        (handle, css)
    }

    #[test]
    fn dead_server_fails_then_backs_off() {
        let eng = unreachable_engine();
        let act = HostTensor::zeros(vec![1, 4]);
        assert!(eng.infer_partial(0, 0, &act).is_err());
        let s = eng.stats();
        assert_eq!(s.failures, 1);
        assert_eq!(s.requests, 0, "connect failed before any frame went out");

        // Within the backoff window: fast-fail without touching the net.
        assert!(eng.infer_partial(0, 0, &act).is_err());
        assert_eq!(eng.stats().fast_fails, 1);
        assert_eq!(eng.stats().failures, 1, "no second connect attempt");

        // After the window expires the engine tries (and fails) again,
        // doubling the backoff.
        std::thread::sleep(Duration::from_millis(60));
        assert!(eng.infer_partial(0, 0, &act).is_err());
        assert_eq!(eng.stats().failures, 2);
    }

    #[test]
    fn unresolvable_host_is_an_error_not_a_panic() {
        let eng = RemoteCloudEngine::new(RemoteCloudConfig::new("no.such.host.invalid:7879"));
        let act = HostTensor::zeros(vec![1, 4]);
        assert!(eng.infer_partial(0, 0, &act).is_err());
        assert!(eng.stats().failures >= 1);
    }

    #[test]
    fn stale_pooled_connection_retries_on_a_fresh_dial() {
        let (handle, _css) = live_server();
        let eng = RemoteCloudEngine::new(RemoteCloudConfig {
            // Bound the worst case if the stale write lands in an OS
            // buffer instead of erroring outright.
            io_timeout: Duration::from_secs(2),
            ..RemoteCloudConfig::new(handle.addr().to_string())
        });

        // Poison the pool with a connection whose stream has already
        // died (the server-restart / NAT-timeout scenario). No reader
        // thread: a NAT-dead stream looks healthy until it's used.
        {
            let dead = TcpStream::connect(handle.addr()).unwrap();
            dead.shutdown(std::net::Shutdown::Both).ok();
            let conn = Arc::new(Conn {
                writer: Mutex::new(BufWriter::new(dead.try_clone().unwrap())),
                pending: Mutex::new(HashMap::new()),
                inflight: AtomicUsize::new(0),
                broken: AtomicBool::new(false),
                stream: dead,
            });
            eng.pool.lock().unwrap().push(conn);
        }

        // The call must survive via one fresh dial — no failure, no
        // backoff, no fallback signal to the caller.
        let act = HostTensor::zeros(vec![1, 4]);
        let out = eng.infer_partial(0, 0, &act).unwrap();
        assert_eq!(out.samples.len(), 1);
        let s = eng.stats();
        assert_eq!(s.stale_retries, 1);
        assert_eq!(s.failures, 0, "a stale pooled stream is not a server failure");
        assert_eq!(s.requests, 2, "one attempt on the stale conn, one fresh");
        handle.stop();
    }

    #[test]
    fn inflight_cap_rejects_excess_without_blocking() {
        let eng = RemoteCloudEngine::new(RemoteCloudConfig {
            max_inflight: 1,
            ..RemoteCloudConfig::new("127.0.0.1:1")
        });
        // Hold the only slot, then observe the saturated fast-path.
        assert!(eng.try_acquire());
        let act = HostTensor::zeros(vec![1, 4]);
        let err = eng.infer_partial(0, 0, &act).unwrap_err().to_string();
        assert!(err.contains("saturated"), "{err}");
        assert_eq!(eng.stats().saturated, 1);
        eng.inflight.fetch_sub(1, Ordering::AcqRel);
        // Slot released: the next call reaches the (dead) network path.
        assert!(eng.infer_partial(0, 0, &act).is_err());
        assert_eq!(eng.stats().failures, 1);
        assert_eq!(eng.stats().inflight_peak, 1);
    }

    #[test]
    fn concurrent_calls_pipeline_on_one_connection() {
        let (handle, css) = live_server();
        let eng = Arc::new(RemoteCloudEngine::new(RemoteCloudConfig {
            pool_capacity: 1, // force every call onto the same stream
            encoding: WireEncoding::Q8,
            ..RemoteCloudConfig::new(handle.addr().to_string())
        }));

        // Warm the pool with one lockstep call so every concurrent
        // worker below finds (and shares) the same established
        // connection instead of racing to dial.
        eng.infer_partial(0, 0, &HostTensor::zeros(vec![1, 4]))
            .unwrap();

        // Each worker ships a batch of a distinct size; getting its own
        // batch size back proves the seq demultiplexer didn't cross
        // answers between in-flight requests.
        let workers: Vec<_> = (1..=4usize)
            .map(|n| {
                let eng = eng.clone();
                std::thread::spawn(move || {
                    let act = HostTensor::zeros(vec![n, 4]);
                    let out = eng.infer_partial(0, 0, &act).unwrap();
                    assert_eq!(out.samples.len(), n, "answer crossed to a different seq");
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }

        let s = eng.stats();
        assert_eq!(s.requests, 5);
        assert_eq!(s.failures, 0);
        assert_eq!(s.connects, 1, "pool_capacity 1: one shared connection");
        assert!(s.bytes_sent > 0 && s.bytes_received > 0);
        // The server saw every batch tagged q8.
        assert_eq!(css.served_by_encoding(), [0, 5, 0]);
        handle.stop();
    }

    #[test]
    fn rejections_stay_scoped_to_their_seq_then_trip_the_breaker() {
        let (handle, _css) = live_server();
        let eng = RemoteCloudEngine::new(RemoteCloudConfig::new(handle.addr().to_string()));
        let good = HostTensor::zeros(vec![1, 4]);
        let bad_split = 3; // split = N: the server rejects (no suffix)

        for i in 0..REJECTION_BREAKER {
            let err = eng
                .infer_partial(bad_split, 0, &good)
                .unwrap_err()
                .to_string();
            assert!(err.contains("rejected"), "rejection {i}: {err}");
        }
        // The breaker is now open: calls fast-fail without the network.
        let before = eng.stats();
        assert!(eng.infer_partial(0, 0, &good).is_err());
        let after = eng.stats();
        assert_eq!(after.fast_fails, before.fast_fails + 1);
        assert_eq!(after.requests, before.requests, "no frame went out");
        assert_eq!(after.failures, 0, "rejections are not failures");
        handle.stop();
    }

    #[test]
    fn misbehaving_server_with_unknown_seq_errors_instead_of_hanging() {
        use std::io::Write;
        use std::net::TcpListener;

        // A fake cloud server that answers every request with a
        // response tagged with a seq nobody sent.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = std::io::BufWriter::new(stream);
            let _ = read_frame(&mut reader).unwrap();
            let bogus = Response::PartialResultSeq {
                seq: 0xDEAD_BEEF,
                samples: vec![],
                cloud_s: 0.0,
            }
            .encode();
            write_frame(&mut writer, &bogus).unwrap();
            writer.flush().ok();
        });

        let eng = RemoteCloudEngine::new(RemoteCloudConfig {
            io_timeout: Duration::from_secs(5),
            ..RemoteCloudConfig::new(addr.to_string())
        });
        let act = HostTensor::zeros(vec![1, 4]);
        let t0 = Instant::now();
        assert!(eng.infer_partial(0, 0, &act).is_err());
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "must fail via connection teardown, not sit out the deadline"
        );
        assert_eq!(eng.stats().failures, 1);
        srv.join().unwrap();
    }
}
