//! The remote cloud engine: the edge side of a physically partitioned
//! deployment.
//!
//! A [`RemoteCloudEngine`] turns a [`super::CloudStageServer`] across
//! the network into something the coordinator's cloud workers can call
//! like a local engine: it ships each transferred split-group as one
//! INFER_PARTIAL frame and returns the server's per-sample classes and
//! compute time. It is deliberately dumb about *planning* — every frame
//! carries its own cut, so it never needs the live partition plan.
//!
//! Failure posture (the edge must keep serving when the cloud is not
//! reachable — the caller falls back to local execution):
//!
//! * **Pooled connections** — idle `TcpStream`s are reused across
//!   batches (one in-flight request per connection; the pool grows on
//!   demand up to `pool_capacity` idle entries).
//! * **Reconnect with backoff** — after a connect/IO failure the engine
//!   fast-fails every call until the backoff window expires
//!   (exponential from `backoff_initial` to `backoff_max`, reset on the
//!   first success), so a dead cloud costs the serving path one failed
//!   connect per window instead of one per batch.
//! * **In-flight cap** — at most `max_inflight` concurrent requests;
//!   calls beyond the cap fail immediately (and the caller runs the
//!   batch locally) rather than queueing behind a slow remote.
//! * **Rejection breaker** — a healthy link that keeps answering with
//!   application ERROR frames (wrong server kind, mismatched model) is
//!   a misconfiguration, not a transient: after
//!   [`REJECTION_BREAKER`] consecutive rejections the engine enters a
//!   `backoff_max` window too, so a misconfigured cloud doesn't cost a
//!   full tensor round-trip per batch forever.

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::runtime::HostTensor;

use super::protocol::{encode_infer_partial, read_frame, write_frame, Request, Response};
use super::tcp::PartialOutput;

#[derive(Debug, Clone)]
pub struct RemoteCloudConfig {
    /// `HOST:PORT` of the cloud-stage server.
    pub addr: String,
    /// Max concurrent requests; calls beyond this fail fast (the
    /// coordinator then executes the batch on the local fallback).
    pub max_inflight: usize,
    /// Idle connections kept for reuse.
    pub pool_capacity: usize,
    pub connect_timeout: Duration,
    /// Per-call read/write timeout — must cover the server's compute
    /// time for one batch.
    pub io_timeout: Duration,
    pub backoff_initial: Duration,
    pub backoff_max: Duration,
}

impl RemoteCloudConfig {
    pub fn new(addr: impl Into<String>) -> RemoteCloudConfig {
        RemoteCloudConfig {
            addr: addr.into(),
            max_inflight: 8,
            pool_capacity: 8,
            connect_timeout: Duration::from_secs(2),
            io_timeout: Duration::from_secs(30),
            backoff_initial: Duration::from_millis(100),
            backoff_max: Duration::from_secs(5),
        }
    }
}

/// One pooled connection. The reader/writer pair persists with the
/// stream: the protocol is strict request/response with a single
/// outstanding call per connection, so buffered read-ahead can never
/// swallow another call's bytes.
struct PooledConn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

/// Consecutive application-level ERROR frames after which the engine
/// backs off as if the link had failed — the server is reachable but
/// persistently rejecting (wrong server kind, mismatched model), and
/// shipping a full activation per batch to learn that again is waste.
pub const REJECTION_BREAKER: u32 = 3;

#[derive(Debug, Default)]
struct Backoff {
    until: Option<Instant>,
    consecutive: u32,
    /// Consecutive application-level rejections (ERROR frames).
    rejections: u32,
}

/// Counters for observability; all monotonic.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RemoteCloudStats {
    /// INFER_PARTIAL round-trips attempted (excludes fast-fails).
    pub requests: u64,
    /// Connect/IO/protocol failures.
    pub failures: u64,
    /// Calls rejected without touching the network (backoff window).
    pub fast_fails: u64,
    /// Calls rejected at the in-flight cap.
    pub saturated: u64,
    /// TCP connections established (reconnects included).
    pub connects: u64,
    /// Calls whose pooled connection had died idle and were retried on
    /// a freshly dialed one (not failures — the retry usually wins).
    pub stale_retries: u64,
}

pub struct RemoteCloudEngine {
    cfg: RemoteCloudConfig,
    pool: Mutex<Vec<PooledConn>>,
    inflight: AtomicUsize,
    backoff: Mutex<Backoff>,
    requests: AtomicU64,
    failures: AtomicU64,
    fast_fails: AtomicU64,
    saturated: AtomicU64,
    connects: AtomicU64,
    stale_retries: AtomicU64,
}

/// RAII release of one in-flight slot.
struct InflightGuard<'a>(&'a AtomicUsize);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

impl RemoteCloudEngine {
    /// Construction is lazy: no connection is attempted until the first
    /// call, so an edge node starts (and serves, via local fallback)
    /// while its cloud is still down.
    pub fn new(mut cfg: RemoteCloudConfig) -> RemoteCloudEngine {
        cfg.max_inflight = cfg.max_inflight.max(1);
        cfg.pool_capacity = cfg.pool_capacity.max(1);
        RemoteCloudEngine {
            cfg,
            pool: Mutex::new(Vec::new()),
            inflight: AtomicUsize::new(0),
            backoff: Mutex::new(Backoff::default()),
            requests: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            fast_fails: AtomicU64::new(0),
            saturated: AtomicU64::new(0),
            connects: AtomicU64::new(0),
            stale_retries: AtomicU64::new(0),
        }
    }

    pub fn addr(&self) -> &str {
        &self.cfg.addr
    }

    pub fn stats(&self) -> RemoteCloudStats {
        RemoteCloudStats {
            requests: self.requests.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            fast_fails: self.fast_fails.load(Ordering::Relaxed),
            saturated: self.saturated.load(Ordering::Relaxed),
            connects: self.connects.load(Ordering::Relaxed),
            stale_retries: self.stale_retries.load(Ordering::Relaxed),
        }
    }

    /// Round-trip a PING (health probe; used at startup for a loud
    /// "cloud reachable/unreachable" log line). Subject to the same
    /// backoff bookkeeping as inference calls.
    pub fn ping(&self) -> Result<()> {
        let (mut conn, _pooled) = match self.checkout() {
            Ok(c) => c,
            Err(e) => {
                self.note_failure();
                return Err(e);
            }
        };
        match Self::call(&mut conn, &Request::Ping) {
            Ok(Response::Pong) => {
                self.note_success();
                self.checkin(conn);
                Ok(())
            }
            Ok(other) => {
                self.note_failure();
                bail!("expected PONG, got {other:?}")
            }
            Err(e) => {
                self.note_failure();
                Err(e)
            }
        }
    }

    /// Ship one split-group to the cloud-stage server: run stages
    /// `split+1..=N` on `activation` (a batched tensor cut after stage
    /// `split`) and return one record per sample. Fails fast when the
    /// engine is in backoff or at the in-flight cap — the caller is
    /// expected to fall back to local execution.
    pub fn infer_partial(
        &self,
        split: usize,
        branch_state: u8,
        activation: &HostTensor,
    ) -> Result<PartialOutput> {
        if let Some(remaining) = self.backoff_remaining() {
            self.fast_fails.fetch_add(1, Ordering::Relaxed);
            bail!(
                "cloud backend {} in backoff for another {remaining:.0?}",
                self.cfg.addr
            );
        }
        if !self.try_acquire() {
            self.saturated.fetch_add(1, Ordering::Relaxed);
            bail!(
                "cloud backend {} saturated ({} requests in flight)",
                self.cfg.addr,
                self.cfg.max_inflight
            );
        }
        let _slot = InflightGuard(&self.inflight);

        let (mut conn, mut pooled) = match self.checkout() {
            Ok(c) => c,
            Err(e) => {
                self.note_failure();
                return Err(e);
            }
        };
        // Encoded once, straight from the borrowed tensor — no owned
        // Request, no activation clone on the hot path.
        let body = encode_infer_partial(split as u32, branch_state, activation);
        loop {
            self.requests.fetch_add(1, Ordering::Relaxed);
            match Self::call_raw(&mut conn, &body) {
                Ok(Response::PartialResult { samples, cloud_s }) => {
                    self.note_success();
                    self.checkin(conn);
                    return Ok(PartialOutput { samples, cloud_s });
                }
                // An ERROR frame means the link is healthy but the
                // server rejected the batch (bad split, engine error):
                // keep the connection, report the failure up, and trip
                // the rejection breaker if it keeps happening.
                Ok(Response::Error(msg)) => {
                    self.checkin(conn);
                    self.note_rejection();
                    bail!("cloud server rejected partial batch: {msg}")
                }
                Ok(other) => {
                    self.note_failure();
                    bail!("unexpected response to INFER_PARTIAL: {other:?}")
                }
                // A pooled stream may have died idle (server restart,
                // NAT timeout) — that says nothing about the server's
                // current health, so retry exactly once on a freshly
                // dialed connection before declaring a failure.
                Err(e) if pooled => {
                    log::debug!("pooled cloud connection was stale ({e:#}); redialing");
                    self.stale_retries.fetch_add(1, Ordering::Relaxed);
                    drop(conn);
                    conn = match self.dial() {
                        Ok(c) => c,
                        Err(de) => {
                            self.note_failure();
                            return Err(de);
                        }
                    };
                    pooled = false;
                }
                Err(e) => {
                    self.note_failure();
                    return Err(
                        e.context(format!("cloud round-trip to {} failed", self.cfg.addr))
                    );
                }
            }
        }
    }

    fn call(conn: &mut PooledConn, req: &Request) -> Result<Response> {
        Self::call_raw(conn, &req.encode())
    }

    fn call_raw(conn: &mut PooledConn, body: &[u8]) -> Result<Response> {
        write_frame(&mut conn.writer, body)?;
        let reply = read_frame(&mut conn.reader)?;
        Response::decode(&reply)
    }

    fn try_acquire(&self) -> bool {
        self.inflight
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
                (n < self.cfg.max_inflight).then_some(n + 1)
            })
            .is_ok()
    }

    /// Seconds left in the backoff window, if one is active.
    fn backoff_remaining(&self) -> Option<Duration> {
        let b = self.backoff.lock().unwrap();
        let until = b.until?;
        let now = Instant::now();
        if now < until {
            Some(until - now)
        } else {
            None
        }
    }

    /// A connection to run one call on, and whether it came from the
    /// idle pool (pooled streams may have died idle; the caller retries
    /// those once on a fresh dial).
    fn checkout(&self) -> Result<(PooledConn, bool)> {
        if let Some(conn) = self.pool.lock().unwrap().pop() {
            return Ok((conn, true));
        }
        Ok((self.dial()?, false))
    }

    /// Dial a fresh connection, trying every resolved address until one
    /// connects — a dual-stack hostname must not strand the edge on an
    /// IPv6 address when the cloud server only listens on IPv4 (or vice
    /// versa).
    fn dial(&self) -> Result<PooledConn> {
        let addrs: Vec<SocketAddr> = self
            .cfg
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolving cloud address '{}'", self.cfg.addr))?
            .collect();
        if addrs.is_empty() {
            bail!("cloud address '{}' resolved to nothing", self.cfg.addr);
        }
        let mut last_err = None;
        for addr in &addrs {
            match TcpStream::connect_timeout(addr, self.cfg.connect_timeout) {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    stream.set_read_timeout(Some(self.cfg.io_timeout)).ok();
                    stream.set_write_timeout(Some(self.cfg.io_timeout)).ok();
                    self.connects.fetch_add(1, Ordering::Relaxed);
                    return Ok(PooledConn {
                        reader: BufReader::new(
                            stream.try_clone().context("cloning cloud stream")?,
                        ),
                        writer: BufWriter::new(stream),
                    });
                }
                Err(e) => last_err = Some((*addr, e)),
            }
        }
        let (addr, e) = last_err.expect("addrs is non-empty");
        Err(anyhow::Error::new(e).context(format!(
            "connecting to cloud server {addr} ({} resolved address(es) tried)",
            addrs.len()
        )))
    }

    fn checkin(&self, conn: PooledConn) {
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < self.cfg.pool_capacity {
            pool.push(conn);
        }
        // Beyond capacity: drop, closing the stream.
    }

    fn note_success(&self) {
        let mut b = self.backoff.lock().unwrap();
        b.consecutive = 0;
        b.rejections = 0;
        b.until = None;
    }

    /// The link round-tripped but the server answered ERROR. The
    /// connection stays pooled and the failure counters stay untouched;
    /// persistent rejection still engages a full backoff window so a
    /// misconfigured cloud isn't paid for per batch.
    fn note_rejection(&self) {
        let mut b = self.backoff.lock().unwrap();
        b.consecutive = 0;
        b.rejections = b.rejections.saturating_add(1);
        if b.rejections >= REJECTION_BREAKER {
            log::warn!(
                "cloud backend {} rejected {} consecutive batches; backing off {:?} \
                 (is it a cloud-serve instance with the same model?)",
                self.cfg.addr,
                b.rejections,
                self.cfg.backoff_max
            );
            b.until = Some(Instant::now() + self.cfg.backoff_max);
        }
    }

    fn note_failure(&self) {
        self.failures.fetch_add(1, Ordering::Relaxed);
        // A failed connection is useless to siblings too: drop the idle
        // pool so the next successful call starts from fresh streams.
        self.pool.lock().unwrap().clear();
        let mut b = self.backoff.lock().unwrap();
        b.consecutive = b.consecutive.saturating_add(1);
        // 100ms, 200ms, 400ms, ... capped at backoff_max.
        let doublings = (b.consecutive - 1).min(6);
        let delay = self
            .cfg
            .backoff_initial
            .saturating_mul(1u32 << doublings)
            .min(self.cfg.backoff_max);
        b.until = Some(Instant::now() + delay);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::sync::Arc;

    use crate::model::Manifest;
    use crate::runtime::InferenceEngine;
    use crate::server::cloud::CloudStageServer;
    use crate::server::tcp::Server;

    fn unreachable_engine() -> RemoteCloudEngine {
        // Port 1 on loopback: connection refused immediately.
        RemoteCloudEngine::new(RemoteCloudConfig {
            backoff_initial: Duration::from_millis(50),
            ..RemoteCloudConfig::new("127.0.0.1:1")
        })
    }

    #[test]
    fn dead_server_fails_then_backs_off() {
        let eng = unreachable_engine();
        let act = HostTensor::zeros(vec![1, 4]);
        assert!(eng.infer_partial(0, 0, &act).is_err());
        let s = eng.stats();
        assert_eq!(s.failures, 1);
        assert_eq!(s.requests, 0, "connect failed before any round-trip");

        // Within the backoff window: fast-fail without touching the net.
        assert!(eng.infer_partial(0, 0, &act).is_err());
        assert_eq!(eng.stats().fast_fails, 1);
        assert_eq!(eng.stats().failures, 1, "no second connect attempt");

        // After the window expires the engine tries (and fails) again,
        // doubling the backoff.
        std::thread::sleep(Duration::from_millis(60));
        assert!(eng.infer_partial(0, 0, &act).is_err());
        assert_eq!(eng.stats().failures, 2);
    }

    #[test]
    fn unresolvable_host_is_an_error_not_a_panic() {
        let eng = RemoteCloudEngine::new(RemoteCloudConfig::new("no.such.host.invalid:7879"));
        let act = HostTensor::zeros(vec![1, 4]);
        assert!(eng.infer_partial(0, 0, &act).is_err());
        assert!(eng.stats().failures >= 1);
    }

    #[test]
    fn stale_pooled_connection_retries_on_a_fresh_dial() {
        let manifest =
            Manifest::synthetic_sim("sim-stale", vec![4], &[16, 8, 2], 1, 2, vec![1, 2]).unwrap();
        let css = Arc::new(CloudStageServer::new(
            InferenceEngine::open_sim(manifest, "stale-srv").unwrap(),
        ));
        let handle = Server::new(css).start(0).unwrap();
        let eng = RemoteCloudEngine::new(RemoteCloudConfig::new(handle.addr().to_string()));

        // Poison the idle pool with a connection that has already died
        // (the server-restart / NAT-timeout scenario).
        {
            let dead = TcpStream::connect(handle.addr()).unwrap();
            dead.shutdown(std::net::Shutdown::Both).ok();
            let conn = PooledConn {
                reader: BufReader::new(dead.try_clone().unwrap()),
                writer: BufWriter::new(dead),
            };
            eng.pool.lock().unwrap().push(conn);
        }

        // The call must survive via one fresh dial — no failure, no
        // backoff, no fallback signal to the caller.
        let act = HostTensor::zeros(vec![1, 4]);
        let out = eng.infer_partial(0, 0, &act).unwrap();
        assert_eq!(out.samples.len(), 1);
        let s = eng.stats();
        assert_eq!(s.stale_retries, 1);
        assert_eq!(s.failures, 0, "a stale pooled stream is not a server failure");
        assert_eq!(s.requests, 2, "one attempt on the stale conn, one fresh");
        handle.stop();
    }

    #[test]
    fn inflight_cap_rejects_excess_without_blocking() {
        let eng = RemoteCloudEngine::new(RemoteCloudConfig {
            max_inflight: 1,
            ..RemoteCloudConfig::new("127.0.0.1:1")
        });
        // Hold the only slot, then observe the saturated fast-path.
        assert!(eng.try_acquire());
        let act = HostTensor::zeros(vec![1, 4]);
        let err = eng.infer_partial(0, 0, &act).unwrap_err().to_string();
        assert!(err.contains("saturated"), "{err}");
        assert_eq!(eng.stats().saturated, 1);
        eng.inflight.fetch_sub(1, Ordering::AcqRel);
        // Slot released: the next call reaches the (dead) network path.
        assert!(eng.infer_partial(0, 0, &act).is_err());
        assert_eq!(eng.stats().failures, 1);
    }
}
