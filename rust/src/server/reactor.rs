//! Event-driven serving front end: a small number of reactor threads
//! drive every client connection through one epoll readiness loop each,
//! instead of one blocked OS thread per connection.
//!
//! ```text
//!            ┌────────────── reactor thread 0 ───────────────┐
//!  accept ──►│ epoll: listener | eventfd | conns…            │
//!            │   read → decode-in-place → submit_infer ──────┼──► shard
//!            │   completions (tag, resp) ◄── eventfd wake ───┼─── queues
//!            │   ordered slots → write buffer → EPOLLOUT     │
//!            └───────────────────────────────────────────────┘
//!              (threads 1..N: same loop, conns handed off
//!               round-robin over an mpsc + eventfd doorbell)
//! ```
//!
//! Design points:
//!
//! * **Decode-in-place framing.** Each connection owns a grow-only read
//!   buffer; frames are parsed at an offset without re-allocating per
//!   request, and tensor payloads are collected straight into the
//!   sample's shared `Arc<[f32]>` (see `protocol::take_f32_payload`) so
//!   admission and every coordinator hop clone a refcount, not floats.
//! * **Never block the loop.** INFER/INFER_CLASS go through
//!   [`ServeBackend::submit_infer`] — a queue admission returning
//!   immediately — and finished inferences come back as `(tag,
//!   response)` completions through a lock-guarded queue plus an
//!   eventfd doorbell. PING/METRICS and the partial-inference kinds are
//!   answered inline via [`super::tcp::respond_sync`] (cloud-stage
//!   suffix compute is the server's whole job; fleets answer partials
//!   with the same ERROR the thread path sends).
//! * **Responses stay ordered per connection.** Each connection keeps a
//!   FIFO of slots (ready bytes or a pending tag); the write buffer
//!   only ever consumes the ready prefix, so out-of-order shard
//!   completions cannot reorder answers on the wire.
//! * **Backpressure is explicit.** A frame past the connection's
//!   in-flight window, or one the shard admission queue rejects, is
//!   answered with a THROTTLE frame (kind 5, retry-after hint) — never
//!   silently queued or dropped. Accepts past `max_conns` are shed the
//!   same way, with one THROTTLE before close.

#![cfg(target_os = "linux")]

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::Result;

use crate::coordinator::{CompletionSink, InferenceResponse, ReplyTo};

use super::protocol::{Request, Response, MAGIC, MAX_BODY};
use super::sys::{Epoll, EpollEvent, EventFd, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP};
use super::tcp::{
    respond_sync, result_response, shed_connection, ServeBackend, ServerConfig, ServerStats,
    Submission, THROTTLE_RETRY_AFTER_MS,
};

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const TOKEN_FIRST_CONN: u64 = 2;
/// Per-readiness read granularity. Level-triggered epoll re-fires while
/// bytes remain, so a short chunk costs another loop pass, not a stall.
const READ_CHUNK: usize = 64 * 1024;
const EVENT_BATCH: usize = 256;

/// The completion funnel of one reactor thread: shard workers push
/// `(tag, response)` and ring the thread's doorbell; the loop drains on
/// the next wakeup. One of these exists per thread so a completion
/// never crosses reactor threads.
struct Completions {
    queue: Mutex<VecDeque<(u64, InferenceResponse)>>,
    waker: Arc<EventFd>,
}

impl CompletionSink for Completions {
    fn complete(&self, tag: u64, resp: InferenceResponse) {
        self.queue.lock().unwrap().push_back((tag, resp));
        self.waker.wake();
    }
}

/// One per-connection answer slot, in request order. The writer only
/// consumes the ready prefix.
enum Slot {
    /// Framed response bytes, ready to ship.
    Ready(Vec<u8>),
    /// Waiting on the completion carrying this tag.
    Pending(u64),
}

struct Conn {
    stream: TcpStream,
    /// Grow-only read buffer; `rpos` is the parse offset into it.
    rbuf: Vec<u8>,
    rpos: usize,
    /// Pending output (already framed); `wpos` is the flush offset.
    wbuf: Vec<u8>,
    wpos: usize,
    /// Ordered answers: ready bytes or in-flight tags.
    slots: VecDeque<Slot>,
    /// Async submissions awaiting completion (ready slots excluded).
    inflight: usize,
    /// Whether EPOLLOUT is currently part of the registered interest.
    wants_out: bool,
    /// Peer sent EOF but answers are still owed: read interest is
    /// dropped (a level-triggered EOF would spin the loop) and the
    /// connection closes once everything owed has flushed.
    read_closed: bool,
    /// Forces one interest re-registration on the next flush.
    interest_dirty: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            rpos: 0,
            wbuf: Vec::new(),
            wpos: 0,
            slots: VecDeque::new(),
            inflight: 0,
            wants_out: false,
            read_closed: false,
            interest_dirty: false,
        }
    }
}

fn frame_bytes(body: &[u8]) -> Vec<u8> {
    let mut f = Vec::with_capacity(8 + body.len());
    f.extend_from_slice(&MAGIC.to_le_bytes());
    f.extend_from_slice(&(body.len() as u32).to_le_bytes());
    f.extend_from_slice(body);
    f
}

fn throttle_frame() -> Vec<u8> {
    frame_bytes(
        &Response::Throttle {
            retry_after_ms: THROTTLE_RETRY_AFTER_MS,
        }
        .encode(),
    )
}

pub(super) struct ReactorHandle {
    stop: Arc<AtomicBool>,
    wakers: Vec<Arc<EventFd>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ReactorHandle {
    pub(super) fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for w in &self.wakers {
            w.wake();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start `cfg.reactor_threads` readiness loops over `listener`. Thread
/// 0 owns the listener and deals accepted connections round-robin;
/// every thread serves its own connection set to completion.
pub(super) fn start<B: ServeBackend>(
    backend: Arc<B>,
    listener: TcpListener,
    cfg: ServerConfig,
    stats: Arc<ServerStats>,
) -> Result<ReactorHandle> {
    listener.set_nonblocking(true)?;
    let n = cfg.reactor_threads;
    let stop = Arc::new(AtomicBool::new(false));
    let wakers: Vec<Arc<EventFd>> = (0..n)
        .map(|_| EventFd::new().map(Arc::new))
        .collect::<std::io::Result<_>>()?;

    // Handoff lanes into threads 1..n (thread 0 registers directly).
    let mut senders: Vec<mpsc::Sender<TcpStream>> = Vec::new();
    let mut receivers: Vec<mpsc::Receiver<TcpStream>> = Vec::new();
    for _ in 1..n {
        let (tx, rx) = mpsc::channel();
        senders.push(tx);
        receivers.push(rx);
    }

    let mut threads = Vec::with_capacity(n);
    for i in (0..n).rev() {
        // Reverse order so thread 0 (which needs every waker for
        // handoff doorbells) is built last, after the workers took
        // their receivers.
        let worker = Worker {
            backend: backend.clone(),
            stats: stats.clone(),
            stop: stop.clone(),
            waker: wakers[i].clone(),
            listener: if i == 0 { Some(listener.try_clone()?) } else { None },
            handoff: if i == 0 { None } else { Some(receivers.remove(i - 1)) },
            lanes: if i == 0 {
                senders
                    .iter()
                    .cloned()
                    .zip(wakers[1..].iter().cloned())
                    .collect()
            } else {
                Vec::new()
            },
            max_conns: cfg.max_conns,
            conn_window: cfg.conn_window,
        };
        threads.push(
            std::thread::Builder::new()
                .name(format!("reactor-{i}"))
                .spawn(move || worker.run())?,
        );
    }

    Ok(ReactorHandle {
        stop,
        wakers,
        threads,
    })
}

struct Worker<B: ServeBackend> {
    backend: Arc<B>,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    waker: Arc<EventFd>,
    /// Thread 0 only.
    listener: Option<TcpListener>,
    /// Threads 1..n only: connections handed over by thread 0.
    handoff: Option<mpsc::Receiver<TcpStream>>,
    /// Thread 0 only: handoff senders + doorbells of threads 1..n.
    lanes: Vec<(mpsc::Sender<TcpStream>, Arc<EventFd>)>,
    max_conns: usize,
    conn_window: usize,
}

/// What one connection event amounted to.
enum ConnFate {
    Alive,
    Closed,
}

impl<B: ServeBackend> Worker<B> {
    fn run(self) {
        if let Err(e) = self.run_inner() {
            log::error!("reactor thread failed: {e:#}");
        }
    }

    fn run_inner(&self) -> Result<()> {
        let epoll = Epoll::new()?;
        epoll.add(self.waker.as_raw_fd(), EPOLLIN, TOKEN_WAKER)?;
        if let Some(l) = &self.listener {
            epoll.add(l.as_raw_fd(), EPOLLIN, TOKEN_LISTENER)?;
        }
        let sink = Arc::new(Completions {
            queue: Mutex::new(VecDeque::new()),
            waker: self.waker.clone(),
        });

        let mut conns: HashMap<u64, Conn> = HashMap::new();
        // tag -> connection token, for routing completions. Tags are
        // thread-local and never reused; a tag whose connection died is
        // simply absent.
        let mut tags: HashMap<u64, u64> = HashMap::new();
        let mut next_token = TOKEN_FIRST_CONN;
        let mut next_tag: u64 = 0;
        let mut rr: usize = 0;
        let mut events = [EpollEvent::zeroed(); EVENT_BATCH];

        while !self.stop.load(Ordering::SeqCst) {
            let n = epoll.wait(&mut events, -1)?;
            for ev in &events[..n] {
                let token = { ev.token };
                let fired = { ev.events };
                match token {
                    TOKEN_LISTENER => {
                        self.accept_ready(&epoll, &mut conns, &mut next_token, &mut rr)
                    }
                    TOKEN_WAKER => {
                        self.waker.drain();
                        if self.stop.load(Ordering::SeqCst) {
                            break;
                        }
                        // Connections handed over by thread 0.
                        if let Some(rx) = &self.handoff {
                            while let Ok(stream) = rx.try_recv() {
                                register_conn(&epoll, &mut conns, &mut next_token, stream);
                            }
                        }
                        // Finished inferences.
                        loop {
                            let item = sink.queue.lock().unwrap().pop_front();
                            let Some((tag, resp)) = item else { break };
                            self.deliver(&epoll, &mut conns, &mut tags, tag, resp);
                        }
                    }
                    token => {
                        let fate = match conns.get_mut(&token) {
                            None => continue, // closed earlier this batch
                            Some(conn) => {
                                if fired & (EPOLLERR | EPOLLHUP) != 0 {
                                    ConnFate::Closed
                                } else {
                                    self.conn_ready(
                                        &epoll, conn, fired, &sink, &mut tags, token,
                                        &mut next_tag,
                                    )
                                }
                            }
                        };
                        if matches!(fate, ConnFate::Closed) {
                            close_conn(&epoll, &mut conns, &mut tags, token, &self.stats);
                        }
                    }
                }
            }
        }
        // Teardown: every live connection closes with the server.
        for _ in conns.values() {
            self.stats.connection_closed();
        }
        Ok(())
    }

    /// Drain the (nonblocking) listener: shed over `max_conns`, deal
    /// the rest round-robin across the reactor threads.
    fn accept_ready(
        &self,
        epoll: &Epoll,
        conns: &mut HashMap<u64, Conn>,
        next_token: &mut u64,
        rr: &mut usize,
    ) {
        loop {
            match self.listener.as_ref().expect("listener thread").accept() {
                Ok((stream, _)) => {
                    if self.max_conns > 0
                        && self.stats.active.load(Ordering::Relaxed) >= self.max_conns as u64
                    {
                        shed_connection(stream, &self.stats);
                        continue;
                    }
                    self.stats.connection_opened();
                    stream.set_nodelay(true).ok();
                    if stream.set_nonblocking(true).is_err() {
                        self.stats.connection_closed();
                        continue;
                    }
                    let lane = *rr % (self.lanes.len() + 1);
                    *rr += 1;
                    if lane == 0 {
                        register_conn(epoll, conns, next_token, stream);
                    } else {
                        let (tx, doorbell) = &self.lanes[lane - 1];
                        if tx.send(stream).is_ok() {
                            doorbell.wake();
                        } else {
                            self.stats.connection_closed();
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    log::warn!("accept error: {e}");
                    break;
                }
            }
        }
    }

    /// Readiness on one connection: read + parse everything available,
    /// then flush what became writable.
    #[allow(clippy::too_many_arguments)]
    fn conn_ready(
        &self,
        epoll: &Epoll,
        conn: &mut Conn,
        fired: u32,
        sink: &Arc<Completions>,
        tags: &mut HashMap<u64, u64>,
        token: u64,
        next_tag: &mut u64,
    ) -> ConnFate {
        if fired & (EPOLLIN | EPOLLRDHUP) != 0 {
            match self.read_and_parse(conn, sink, tags, token, next_tag) {
                ConnFate::Closed => return ConnFate::Closed,
                ConnFate::Alive => {}
            }
        }
        flush_conn(epoll, conn, token)
    }

    /// Pull bytes into the grow-only buffer and parse every complete
    /// frame at the current offset.
    fn read_and_parse(
        &self,
        conn: &mut Conn,
        sink: &Arc<Completions>,
        tags: &mut HashMap<u64, u64>,
        token: u64,
        next_tag: &mut u64,
    ) -> ConnFate {
        let mut saw_eof = false;
        loop {
            let old = conn.rbuf.len();
            conn.rbuf.resize(old + READ_CHUNK, 0);
            match conn.stream.read(&mut conn.rbuf[old..]) {
                Ok(0) => {
                    conn.rbuf.truncate(old);
                    saw_eof = true;
                    break;
                }
                Ok(n) => {
                    conn.rbuf.truncate(old + n);
                    if n < READ_CHUNK {
                        break;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    conn.rbuf.truncate(old);
                    break;
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                    conn.rbuf.truncate(old);
                }
                Err(_) => {
                    conn.rbuf.truncate(old);
                    return ConnFate::Closed;
                }
            }
        }

        // Parse frames in place at the offset.
        while conn.rbuf.len() - conn.rpos >= 8 {
            let head = &conn.rbuf[conn.rpos..conn.rpos + 8];
            let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
            let len = u32::from_le_bytes(head[4..8].try_into().unwrap());
            if magic != MAGIC || len > MAX_BODY {
                return ConnFate::Closed; // hostile/garbled peer
            }
            let len = len as usize;
            if conn.rbuf.len() - conn.rpos < 8 + len {
                break; // frame incomplete — wait for more bytes
            }
            let start = conn.rpos + 8;
            // Request::decode returns an owned Request (tensors collect
            // into their shared Arc here), so no borrow of rbuf
            // outlives this statement.
            let decoded = Request::decode(&conn.rbuf[start..start + len]);
            conn.rpos += 8 + len;
            self.backend.note_io(len as u64 + 8, 0);
            self.handle_request(conn, decoded, sink, tags, token, next_tag);
        }
        // Compact the consumed prefix; capacity is retained, so the
        // buffer stays grow-only across the connection's lifetime.
        if conn.rpos > 0 {
            conn.rbuf.drain(..conn.rpos);
            conn.rpos = 0;
        }

        if saw_eof {
            if conn.inflight == 0 && conn.slots.is_empty() && conn.wbuf.is_empty() {
                return ConnFate::Closed;
            }
            // EOF with answers still owed: stop watching reads (a
            // level-triggered EOF stays readable and would spin the
            // loop) and let the flush path close once everything owed
            // is on the wire.
            conn.read_closed = true;
            conn.interest_dirty = true;
        }
        ConnFate::Alive
    }

    fn handle_request(
        &self,
        conn: &mut Conn,
        decoded: Result<Request>,
        sink: &Arc<Completions>,
        tags: &mut HashMap<u64, u64>,
        token: u64,
        next_tag: &mut u64,
    ) {
        let req = match decoded {
            Err(e) => {
                self.push_ready(conn, &Response::Error(format!("{e:#}")).encode());
                return;
            }
            Ok(r) => r,
        };
        let (class, image) = match req {
            Request::Infer(t) => (None, t),
            Request::InferClass { class, image } => (Some(class), image),
            other => {
                // PING / METRICS / partial kinds: answered inline via
                // the same dispatch the thread path uses.
                self.push_ready(conn, &respond_sync(self.backend.as_ref(), other).encode());
                return;
            }
        };
        if conn.inflight >= self.conn_window {
            self.push_throttle(conn);
            return;
        }
        let tag = *next_tag;
        *next_tag += 1;
        let reply = ReplyTo::Sink {
            sink: sink.clone() as Arc<dyn CompletionSink>,
            tag,
        };
        match self.backend.submit_infer(class, image, reply) {
            Submission::Queued(_id) => {
                tags.insert(tag, token);
                conn.inflight += 1;
                conn.slots.push_back(Slot::Pending(tag));
            }
            Submission::Ready(Ok(r)) => self.push_ready(conn, &result_response(&r).encode()),
            Submission::Ready(Err(e)) => {
                self.push_ready(conn, &Response::Error(format!("{e:#}")).encode())
            }
            Submission::Busy => self.push_throttle(conn),
        }
    }

    fn push_ready(&self, conn: &mut Conn, body: &[u8]) {
        self.backend.note_io(0, body.len() as u64 + 8);
        conn.slots.push_back(Slot::Ready(frame_bytes(body)));
    }

    fn push_throttle(&self, conn: &mut Conn) {
        self.stats.throttled.fetch_add(1, Ordering::Relaxed);
        let frame = throttle_frame();
        self.backend.note_io(0, frame.len() as u64);
        conn.slots.push_back(Slot::Ready(frame));
    }

    /// Route one completion to its connection's pending slot and flush.
    fn deliver(
        &self,
        epoll: &Epoll,
        conns: &mut HashMap<u64, Conn>,
        tags: &mut HashMap<u64, u64>,
        tag: u64,
        resp: InferenceResponse,
    ) {
        let Some(token) = tags.remove(&tag) else {
            return; // connection closed while the request was in flight
        };
        let Some(conn) = conns.get_mut(&token) else {
            return;
        };
        if let Some(slot) = conn
            .slots
            .iter_mut()
            .find(|s| matches!(s, Slot::Pending(t) if *t == tag))
        {
            let body = result_response(&resp).encode();
            self.backend.note_io(0, body.len() as u64 + 8);
            *slot = Slot::Ready(frame_bytes(&body));
        }
        conn.inflight = conn.inflight.saturating_sub(1);
        if matches!(flush_conn(epoll, conn, token), ConnFate::Closed) {
            close_conn(epoll, conns, tags, token, &self.stats);
        }
    }
}

fn register_conn(
    epoll: &Epoll,
    conns: &mut HashMap<u64, Conn>,
    next_token: &mut u64,
    stream: TcpStream,
) {
    let token = *next_token;
    *next_token += 1;
    if epoll
        .add(stream.as_raw_fd(), EPOLLIN | EPOLLRDHUP, token)
        .is_ok()
    {
        conns.insert(token, Conn::new(stream));
    }
}

fn close_conn(
    epoll: &Epoll,
    conns: &mut HashMap<u64, Conn>,
    tags: &mut HashMap<u64, u64>,
    token: u64,
    stats: &ServerStats,
) {
    if let Some(conn) = conns.remove(&token) {
        let _ = epoll.delete(conn.stream.as_raw_fd());
        // Orphan this connection's in-flight tags: late completions
        // will find no route and be dropped.
        for slot in &conn.slots {
            if let Slot::Pending(tag) = slot {
                tags.remove(tag);
            }
        }
        stats.connection_closed();
    }
}

/// Move the ready slot prefix into the write buffer, write as much as
/// the socket takes, and keep EPOLLOUT registered exactly while bytes
/// remain.
fn flush_conn(epoll: &Epoll, conn: &mut Conn, token: u64) -> ConnFate {
    while let Some(Slot::Ready(_)) = conn.slots.front() {
        let Some(Slot::Ready(bytes)) = conn.slots.pop_front() else {
            unreachable!("front checked above");
        };
        conn.wbuf.extend_from_slice(&bytes);
    }
    while conn.wpos < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => return ConnFate::Closed,
            Ok(n) => conn.wpos += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return ConnFate::Closed,
        }
    }
    if conn.wpos == conn.wbuf.len() {
        conn.wbuf.clear();
        conn.wpos = 0;
    }
    if conn.read_closed && conn.inflight == 0 && conn.slots.is_empty() && conn.wbuf.is_empty() {
        return ConnFate::Closed; // half-closed peer, nothing owed
    }
    let wants_out = !conn.wbuf.is_empty();
    if wants_out != conn.wants_out || conn.interest_dirty {
        let mut interest = if conn.read_closed {
            0 // ERR/HUP still fire with an empty interest set
        } else {
            EPOLLIN | EPOLLRDHUP
        };
        if wants_out {
            interest |= EPOLLOUT;
        }
        if epoll
            .modify(conn.stream.as_raw_fd(), interest, token)
            .is_err()
        {
            return ConnFate::Closed;
        }
        conn.wants_out = wants_out;
        conn.interest_dirty = false;
    }
    ConnFate::Alive
}
