//! Raw Linux epoll/eventfd bindings for the reactor front end.
//!
//! The vendor set is frozen (no `libc`/`mio` crates), so the handful of
//! syscalls the readiness loop needs are declared here directly against
//! the C library std already links. Everything is wrapped in two small
//! RAII types — [`Epoll`] and [`EventFd`] — so the `unsafe` surface
//! stays inside this file; errno is read via
//! `std::io::Error::last_os_error()` like std itself does.
//!
//! Level-triggered only: the reactor re-arms nothing and never misses a
//! wakeup, at the cost of spurious readiness — which its
//! read-until-`WouldBlock` loops absorb.

#![cfg(target_os = "linux")]

use std::io;
use std::os::fd::RawFd;
use std::os::raw::{c_int, c_uint, c_void};

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
/// Peer shut down its write half — the read loop will see EOF.
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;
const EPOLL_CLOEXEC: c_int = 0x80000;
const EFD_CLOEXEC: c_int = 0x80000;
const EFD_NONBLOCK: c_int = 0x800;

/// `struct epoll_event`. The kernel ABI packs it on x86-64 (12 bytes);
/// other architectures use natural alignment (16 bytes).
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    /// Caller-chosen token identifying the registered fd.
    pub token: u64,
}

impl EpollEvent {
    pub fn zeroed() -> EpollEvent {
        EpollEvent { events: 0, token: 0 }
    }
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int)
        -> c_int;
    fn eventfd(initval: c_uint, flags: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
}

fn cvt(ret: c_int) -> io::Result<c_int> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// An epoll instance (closed on drop).
pub struct Epoll {
    fd: RawFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, token };
        cvt(unsafe { epoll_ctl(self.fd, op, fd, &mut ev) })?;
        Ok(())
    }

    /// Register `fd` under `token` for `events` (level-triggered).
    pub fn add(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    /// Change the interest set of an already-registered fd.
    pub fn modify(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        // The event argument is ignored for DEL (non-NULL only for
        // pre-2.6.9 kernels, which std does not support either).
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Block up to `timeout_ms` (-1 = forever) for readiness; fills
    /// `events` and returns how many fired. EINTR retries internally.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let n = unsafe {
                epoll_wait(
                    self.fd,
                    events.as_mut_ptr(),
                    events.len() as c_int,
                    timeout_ms,
                )
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            close(self.fd);
        }
    }
}

/// A nonblocking eventfd: the reactor's cross-thread doorbell. Any
/// thread may [`EventFd::wake`]; the owning reactor thread registers it
/// in its epoll set and [`EventFd::drain`]s on readiness.
pub struct EventFd {
    fd: RawFd,
}

impl EventFd {
    pub fn new() -> io::Result<EventFd> {
        let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
        Ok(EventFd { fd })
    }

    pub fn as_raw_fd(&self) -> RawFd {
        self.fd
    }

    /// Add 1 to the counter, waking any epoll_wait watching this fd.
    /// Best-effort: an EAGAIN (counter at u64::MAX − 1, impossible in
    /// practice) still leaves the fd readable, so the wakeup is never
    /// lost.
    pub fn wake(&self) {
        let one: u64 = 1;
        unsafe {
            write(self.fd, (&one as *const u64).cast::<c_void>(), 8);
        }
    }

    /// Reset the counter so the (level-triggered) fd goes quiet until
    /// the next wake.
    pub fn drain(&self) {
        let mut buf: u64 = 0;
        unsafe {
            read(self.fd, (&mut buf as *mut u64).cast::<c_void>(), 8);
        }
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        unsafe {
            close(self.fd);
        }
    }
}

// eventfd wakes cross threads by design; the fd is just an integer.
unsafe impl Send for EventFd {}
unsafe impl Sync for EventFd {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eventfd_wakes_epoll_and_drains_quiet() {
        let ep = Epoll::new().unwrap();
        let efd = EventFd::new().unwrap();
        ep.add(efd.as_raw_fd(), EPOLLIN, 7).unwrap();
        // Nothing pending: a zero-timeout wait sees nothing.
        let mut events = [EpollEvent::zeroed(); 4];
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        // A wake (from any thread) makes it readable under our token.
        let waker = std::thread::spawn(move || efd.wake());
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        assert_eq!({ events[0].token }, 7);
        assert_ne!({ events[0].events } & EPOLLIN, 0);
        waker.join().unwrap();
    }

    #[test]
    fn epoll_tracks_socket_readiness() {
        use std::io::Write;
        use std::net::{TcpListener, TcpStream};
        use std::os::fd::AsRawFd;

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let ep = Epoll::new().unwrap();
        ep.add(server.as_raw_fd(), EPOLLIN | EPOLLRDHUP, 42).unwrap();
        let mut events = [EpollEvent::zeroed(); 4];
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0, "idle socket is quiet");

        client.write_all(b"x").unwrap();
        client.flush().unwrap();
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        assert_eq!({ events[0].token }, 42);
        assert_ne!({ events[0].events } & EPOLLIN, 0);

        // Interest can be switched to write readiness (MOD) and back.
        ep.modify(server.as_raw_fd(), EPOLLOUT, 42).unwrap();
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        assert_ne!({ events[0].events } & EPOLLOUT, 0);
        ep.delete(server.as_raw_fd()).unwrap();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0, "deleted fd is gone");
    }
}
