//! TCP serving front-end: a length-prefixed binary protocol over std
//! TcpListener (tokio is unavailable offline; a thread-per-connection
//! accept loop in front of the coordinator's own batching pipeline is
//! fully adequate for this workload). The accept loop is generic over
//! [`ServeBackend`], so the same wire front-end serves a single
//! coordinator pipeline or a multi-class fleet.

pub mod protocol;
pub mod tcp;

pub use protocol::{Request, Response};
pub use tcp::{Client, ServeBackend, Server, ServerHandle};
