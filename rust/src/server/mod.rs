//! The wire layer: a length-prefixed binary protocol over std
//! `TcpListener` (tokio is unavailable offline; a thread-per-connection
//! accept loop in front of the coordinator's own batching pipeline is
//! fully adequate for this workload), and both halves of a physically
//! partitioned deployment speaking it.
//!
//! * [`protocol`] — the frame format: PING / INFER / INFER_CLASS /
//!   METRICS plus the partial-inference pair (INFER_PARTIAL →
//!   PARTIAL_RESULT) that carries cut activations between machines.
//! * [`tcp`] — the accept loop, generic over [`ServeBackend`], so the
//!   same front-end serves a single coordinator pipeline, a multi-class
//!   fleet, or a cloud-stage server; plus the blocking [`Client`].
//! * [`cloud`] — [`CloudStageServer`]: executes only the suffix stages
//!   `split+1..=N` of each INFER_PARTIAL frame. Every frame carries its
//!   own cut, so the server never needs the live partition plan.
//! * [`remote`] — [`RemoteCloudEngine`]: the edge-side client the
//!   coordinator's cloud workers call instead of an in-process engine
//!   (pooled connections, reconnect with backoff, in-flight cap; the
//!   coordinator falls back to local execution when it fails).
//!
//! One binary plays either role: `branchyserve serve --cloud-addr
//! HOST:PORT` runs the edge half against `branchyserve cloud-serve` on
//! another machine.

pub mod cloud;
pub mod protocol;
pub mod remote;
pub mod tcp;

pub use cloud::CloudStageServer;
pub use protocol::{PartialSample, Request, Response};
pub use remote::{RemoteCloudConfig, RemoteCloudEngine, RemoteCloudStats};
pub use tcp::{Client, PartialOutput, ServeBackend, Server, ServerHandle};
