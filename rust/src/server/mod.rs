//! The wire layer: a length-prefixed binary protocol over std TCP
//! (tokio is unavailable offline), two serving front ends behind one
//! [`Server`] API, and both halves of a physically partitioned
//! deployment speaking the protocol.
//!
//! * [`protocol`] — the frame format: PING / INFER / INFER_CLASS /
//!   METRICS plus the partial-inference pair (INFER_PARTIAL →
//!   PARTIAL_RESULT) that carries cut activations between machines, and
//!   the THROTTLE backpressure frame (kind 5).
//! * [`tcp`] — the [`Server`] API, generic over [`ServeBackend`], so
//!   the same front end serves a single coordinator pipeline, a
//!   multi-class fleet, or a cloud-stage server; plus the blocking
//!   [`Client`]. Its own serving path is the portable
//!   thread-per-connection loop (handler threads tracked and joined on
//!   stop, accepts past `max_conns` shed with THROTTLE).
//! * [`reactor`] (Linux) — the event-driven path behind
//!   `ServerConfig::reactor`: one epoll readiness loop per reactor
//!   thread multiplexing every connection, decode-in-place framing into
//!   shared-buffer samples, non-blocking shard admission with
//!   completions delivered through an eventfd doorbell, and bounded
//!   per-connection in-flight windows answered with THROTTLE when
//!   exceeded. Built on [`sys`], raw epoll/eventfd bindings (the vendor
//!   set is frozen — no `libc`/`mio`).
//! * [`cloud`] — [`CloudStageServer`]: executes only the suffix stages
//!   `split+1..=N` of each INFER_PARTIAL frame. Every frame carries its
//!   own cut, so the server never needs the live partition plan.
//! * [`remote`] — [`RemoteCloudEngine`]: the edge-side client the
//!   coordinator's cloud workers call instead of an in-process engine
//!   (pooled connections, reconnect with backoff, in-flight cap; the
//!   coordinator falls back to local execution when it fails).
//!
//! One binary plays either role: `branchyserve serve --cloud-addr
//! HOST:PORT` runs the edge half against `branchyserve cloud-serve` on
//! another machine.

pub mod cloud;
pub mod protocol;
#[cfg(target_os = "linux")]
pub(crate) mod reactor;
pub mod remote;
#[cfg(target_os = "linux")]
pub mod sys;
pub mod tcp;

pub use cloud::CloudStageServer;
pub use protocol::{PartialSample, Request, Response};
pub use remote::{RemoteCloudConfig, RemoteCloudEngine, RemoteCloudStats};
pub use tcp::{
    Client, PartialOutput, ServeBackend, Server, ServerConfig, ServerHandle, ServerStats,
    ServerStatsSnapshot, Submission, THROTTLE_RETRY_AFTER_MS,
};
