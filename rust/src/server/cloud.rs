//! The remote cloud-stage server: the *other half* of a physically
//! partitioned BranchyNet.
//!
//! A [`CloudStageServer`] owns one [`InferenceEngine`] over the full
//! manifest but executes only what each INFER_PARTIAL frame asks for:
//! the suffix stages `split+1..=N` of an activation batch the edge cut
//! after stage `split`. It never needs the live partition plan — every
//! frame carries its own cut (the same invariant the in-process
//! coordinator relies on: transferred samples are stamped with the
//! split they were cut at), so edge-side replanning, per-request
//! overrides and mid-flight plan switches all work unchanged across
//! machines.
//!
//! The side-branch gate stays on the edge: samples that exited early
//! were answered there and never cross the wire, so this server runs
//! main-branch stages only and reports `exited = false` for every
//! sample. The `branch_state` byte it receives is recorded (gated vs
//! ungated batches) for observability.
//!
//! Serve it behind the ordinary accept loop: it implements
//! [`ServeBackend`], so `Server::new(Arc::new(css)).start_on(...)`
//! gives you the wire front-end, and plain `INFER` frames still work
//! (served as full cloud-only inference — a partial cut at `split = 0`
//! in one hop).
//!
//! **Chain forwarding.** With [`CloudStageServer::with_forward`] the
//! server becomes a *middle tier* of a K-tier partition chain: an
//! INFER_CHAIN_SEQ frame carrying cuts `[c0, c1, ...]` makes it run
//! stages `c0+1..=c1` (zero stages for a pass-through `c0 == c1`) and
//! ship the remainder onward through its own pooled
//! [`RemoteCloudEngine`] — the same pipelining, backoff, and breaker
//! machinery the edge uses. The reply's `cloud_s` is this tier's wall
//! time (own compute + the whole downstream round-trip), so the
//! caller's measured transfer stays its *own* hop's wire time only.
//! Without a forward engine, chain frames with a genuine tail are
//! rejected; single-cut frames (and tails ending at this tier) are
//! served as ordinary partials.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::request::ExitPoint;
use crate::coordinator::InferenceResponse;
use crate::network::encoding::WireEncoding;
use crate::runtime::{HostTensor, InferenceEngine};

use super::protocol::{BRANCH_GATED, PartialSample};
use super::remote::RemoteCloudEngine;
use super::tcp::{PartialOutput, ServeBackend};

/// A wire-facing backend that executes only the cloud suffix of the
/// partition. See the [module docs](self) for the contract.
pub struct CloudStageServer {
    engine: InferenceEngine,
    /// Next tier of the partition chain, if this server is a middle
    /// tier (`--forward-addr`). `None` = terminal server: chain frames
    /// with a genuine tail are rejected.
    forward: Option<Arc<RemoteCloudEngine>>,
    /// Partial batches served, indexed by the split they were cut at
    /// (`0..N-1`; a cut at `N` is edge-only and never transfers).
    /// Chain batches count at their *incoming* cut `cuts[0]` — the
    /// loopback tests key on this to prove per-hop transfers happen
    /// exactly at the planned cuts.
    splits_served: Vec<AtomicU64>,
    partial_batches: AtomicU64,
    partial_samples: AtomicU64,
    /// Batches whose samples already passed the edge's branch gate.
    gated_batches: AtomicU64,
    /// Full (non-partial) INFER requests served.
    full_infers: AtomicU64,
    /// Multi-cut INFER_CHAIN_SEQ batches served (runs this tier's
    /// segment and forwards the tail).
    chain_batches: AtomicU64,
    /// Batches handed to the next-tier engine (`>= chain_batches`;
    /// the excess are downstream failures).
    forwarded_batches: AtomicU64,
    /// Rejected partial requests (bad split, empty batch, engine error).
    errors: AtomicU64,
    /// Partial batches served per wire encoding, indexed raw/q8/q4 —
    /// the cloud-side view of the compression win.
    enc_served: [AtomicU64; 3],
    /// Framed bytes in/out of this backend (8-byte headers included),
    /// counted by the connection loop via [`ServeBackend::note_io`].
    bytes_received: AtomicU64,
    bytes_sent: AtomicU64,
    next_id: AtomicU64,
    started: Instant,
}

impl CloudStageServer {
    pub fn new(engine: InferenceEngine) -> CloudStageServer {
        let n = engine.manifest().num_stages();
        CloudStageServer {
            splits_served: (0..n).map(|_| AtomicU64::new(0)).collect(),
            engine,
            forward: None,
            partial_batches: AtomicU64::new(0),
            partial_samples: AtomicU64::new(0),
            gated_batches: AtomicU64::new(0),
            full_infers: AtomicU64::new(0),
            chain_batches: AtomicU64::new(0),
            forwarded_batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            enc_served: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)],
            bytes_received: AtomicU64::new(0),
            bytes_sent: AtomicU64::new(0),
            next_id: AtomicU64::new(1),
            started: Instant::now(),
        }
    }

    /// Make this server a middle tier: multi-cut chain frames run
    /// their segment here and forward the tail through `forward`.
    pub fn with_forward(mut self, forward: Arc<RemoteCloudEngine>) -> CloudStageServer {
        self.forward = Some(forward);
        self
    }

    pub fn engine(&self) -> &InferenceEngine {
        &self.engine
    }

    /// The next-tier engine, if this is a forwarding middle tier.
    pub fn forward_engine(&self) -> Option<&Arc<RemoteCloudEngine>> {
        self.forward.as_ref()
    }

    /// (chain_batches, forwarded_batches).
    pub fn chain_counters(&self) -> (u64, u64) {
        (
            self.chain_batches.load(Ordering::Relaxed),
            self.forwarded_batches.load(Ordering::Relaxed),
        )
    }

    /// Per-split partial-batch counts: `counts[s]` is how many batches
    /// arrived cut after stage `s`. The loopback integration test keys
    /// on this to prove transfers happen exactly at the planned split.
    pub fn splits_served(&self) -> Vec<u64> {
        self.splits_served
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// (partial_batches, partial_samples, gated_batches, full_infers,
    /// errors).
    pub fn counters(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.partial_batches.load(Ordering::Relaxed),
            self.partial_samples.load(Ordering::Relaxed),
            self.gated_batches.load(Ordering::Relaxed),
            self.full_infers.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
        )
    }

    /// Partial batches served per wire encoding: `[raw, q8, q4]`
    /// (sparse q8 counts as q8 — it is an opportunistic sub-mode).
    pub fn served_by_encoding(&self) -> [u64; 3] {
        [
            self.enc_served[0].load(Ordering::Relaxed),
            self.enc_served[1].load(Ordering::Relaxed),
            self.enc_served[2].load(Ordering::Relaxed),
        ]
    }

    /// Framed bytes (received, sent) across all connections.
    pub fn bytes_io(&self) -> (u64, u64) {
        (
            self.bytes_received.load(Ordering::Relaxed),
            self.bytes_sent.load(Ordering::Relaxed),
        )
    }

    /// The fallible body of [`ServeBackend::serve_partial`]; the trait
    /// method wraps it to count rejections.
    fn partial(
        &self,
        split: usize,
        branch_state: u8,
        activation: &HostTensor,
    ) -> Result<PartialOutput> {
        let num_stages = self.engine.manifest().num_stages();
        if split >= num_stages {
            bail!(
                "split {split} leaves no cloud suffix (model has {num_stages} stages; \
                 an edge-only cut never transfers)"
            );
        }
        let n = activation.batch();
        if n == 0 {
            bail!("empty INFER_PARTIAL batch");
        }
        let t0 = Instant::now();
        let classes = self.run_suffix(split + 1, activation)?;
        let cloud_s = t0.elapsed().as_secs_f64();
        self.partial_batches.fetch_add(1, Ordering::Relaxed);
        self.partial_samples.fetch_add(n as u64, Ordering::Relaxed);
        self.splits_served[split].fetch_add(1, Ordering::Relaxed);
        if branch_state == BRANCH_GATED {
            self.gated_batches.fetch_add(1, Ordering::Relaxed);
        }
        Ok(PartialOutput {
            samples: classes
                .into_iter()
                .map(|class| PartialSample {
                    class: class as u32,
                    exited: false,
                    entropy: 0.0,
                })
                .collect(),
            cloud_s,
        })
    }

    /// Run `from..=N` on a batch and return one argmax class per input
    /// sample — a thin front for [`InferenceEngine::run_suffix_classes`]
    /// (pad + chunk + argmax), shared with the in-process cloud worker.
    fn run_suffix(&self, from: usize, activation: &HostTensor) -> Result<Vec<usize>> {
        self.engine
            .run_suffix_classes(from, activation, activation.batch())
    }

    /// The fallible middle-tier body of [`ServeBackend::serve_chain`]:
    /// run stages `cuts[0]+1..=cuts[1]` (zero stages for a pass-through
    /// `cuts[0] == cuts[1]`) and forward the tail `cuts[1..]` to the
    /// next tier. Only called with a genuine tail (`cuts.len() >= 2`
    /// and `cuts[1] < N` — the terminal cases delegate to the partial
    /// path before reaching here).
    fn chain(
        &self,
        cuts: &[u32],
        branch_state: u8,
        activation: &HostTensor,
    ) -> Result<PartialOutput> {
        let num_stages = self.engine.manifest().num_stages();
        if cuts.windows(2).any(|pair| pair[0] > pair[1]) {
            bail!("chain cuts {cuts:?} are not non-decreasing");
        }
        let from = cuts[0] as usize;
        let to = cuts[1] as usize;
        debug_assert!(from <= to && to < num_stages);
        let Some(forward) = &self.forward else {
            bail!(
                "this server is a terminal tier (no --forward-addr) but received a \
                 {}-cut chain frame; point the edge's chain at a forwarding tier",
                cuts.len()
            );
        };
        let n = activation.batch();
        if n == 0 {
            bail!("empty INFER_CHAIN_SEQ batch");
        }
        let t0 = Instant::now();
        // This tier's segment. A pass-through relays the activation
        // exactly as received — zero stages, bit-identical payload.
        let ran;
        let acts = if from == to {
            activation
        } else {
            ran = self.engine.run_segment_acts(from + 1, to, activation, n)?;
            &ran
        };
        self.forwarded_batches.fetch_add(1, Ordering::Relaxed);
        let down = forward.infer_chain(&cuts[1..], branch_state, acts)?;
        if down.samples.len() != n {
            bail!(
                "downstream tier answered {} samples for a batch of {n}",
                down.samples.len()
            );
        }
        // Wall time here covers own compute plus the entire downstream
        // round-trip, so the caller's measured transfer is its own
        // hop's wire time only.
        let cloud_s = t0.elapsed().as_secs_f64();
        self.chain_batches.fetch_add(1, Ordering::Relaxed);
        self.partial_samples.fetch_add(n as u64, Ordering::Relaxed);
        self.splits_served[from].fetch_add(1, Ordering::Relaxed);
        if branch_state == BRANCH_GATED {
            self.gated_batches.fetch_add(1, Ordering::Relaxed);
        }
        Ok(PartialOutput {
            samples: down.samples,
            cloud_s,
        })
    }

    /// Shared outcome bookkeeping for the wire-facing entry points:
    /// served batches count under their wire encoding, rejections
    /// under `errors`.
    fn note_served(&self, encoding: WireEncoding, result: &Result<PartialOutput>) {
        match result {
            Ok(_) => {
                let idx = match encoding {
                    WireEncoding::Raw => 0,
                    WireEncoding::Q8 => 1,
                    WireEncoding::Q4 => 2,
                };
                self.enc_served[idx].fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                self.errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

impl ServeBackend for CloudStageServer {
    /// A plain INFER against the cloud-stage server is full cloud-only
    /// inference: the degenerate `split = 0` partial in one hop.
    fn serve_infer(&self, _class: Option<u8>, image: HostTensor) -> Result<InferenceResponse> {
        let t0 = Instant::now();
        let batched = HostTensor::stack(std::slice::from_ref(&image))?;
        let classes = self.run_suffix(1, &batched)?;
        self.full_infers.fetch_add(1, Ordering::Relaxed);
        let cloud_s = t0.elapsed().as_secs_f64();
        Ok(InferenceResponse {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            class: classes[0],
            exit: ExitPoint::MainOutput,
            entropy: f32::NAN,
            latency_s: cloud_s,
            edge_s: 0.0,
            transfer_s: 0.0,
            cloud_s,
        })
    }

    fn serve_partial(
        &self,
        split: usize,
        branch_state: u8,
        activation: HostTensor,
    ) -> Result<PartialOutput> {
        self.serve_partial_encoded(split, branch_state, WireEncoding::Raw, activation)
    }

    fn serve_partial_encoded(
        &self,
        split: usize,
        branch_state: u8,
        encoding: WireEncoding,
        activation: HostTensor,
    ) -> Result<PartialOutput> {
        let result = self.partial(split, branch_state, &activation);
        self.note_served(encoding, &result);
        result
    }

    fn serve_chain(
        &self,
        cuts: &[u32],
        branch_state: u8,
        encoding: WireEncoding,
        activation: HostTensor,
    ) -> Result<PartialOutput> {
        if cuts.is_empty() {
            self.errors.fetch_add(1, Ordering::Relaxed);
            bail!("INFER_CHAIN_SEQ with no cuts");
        }
        // Terminal cases — a single cut, or a tail whose next cut
        // already covers the whole model (nothing left downstream) —
        // are ordinary partials: run `cuts[0]+1..=N` here and answer.
        let num_stages = self.engine.manifest().num_stages();
        if cuts.len() == 1 || cuts[1] as usize >= num_stages {
            return self.serve_partial_encoded(
                cuts[0] as usize,
                branch_state,
                encoding,
                activation,
            );
        }
        let result = self.chain(cuts, branch_state, &activation);
        self.note_served(encoding, &result);
        result
    }

    fn note_io(&self, bytes_received: u64, bytes_sent: u64) {
        self.bytes_received.fetch_add(bytes_received, Ordering::Relaxed);
        self.bytes_sent.fetch_add(bytes_sent, Ordering::Relaxed);
    }

    fn metrics_json(&self) -> String {
        let (batches, samples, gated, full, errors) = self.counters();
        let (chain, forwarded) = self.chain_counters();
        let splits = self
            .splits_served()
            .iter()
            .map(u64::to_string)
            .collect::<Vec<_>>()
            .join(",");
        let [enc_raw, enc_q8, enc_q4] = self.served_by_encoding();
        let (rx, tx) = self.bytes_io();
        format!(
            "{{\"partial_batches\":{batches},\"partial_samples\":{samples},\
             \"gated_batches\":{gated},\"full_infers\":{full},\
             \"chain_batches\":{chain},\"forwarded_batches\":{forwarded},\
             \"errors\":{errors},\
             \"splits_served\":[{splits}],\
             \"served_by_encoding\":{{\"raw\":{enc_raw},\"q8\":{enc_q8},\"q4\":{enc_q4}}},\
             \"bytes_received\":{rx},\"bytes_sent\":{tx},\"uptime_s\":{:.3}}}",
            self.started.elapsed().as_secs_f64()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;

    fn server() -> CloudStageServer {
        let manifest =
            Manifest::synthetic_sim("sim-cloud", vec![4], &[16, 8, 2], 1, 2, vec![1, 2, 4])
                .unwrap();
        let engine = InferenceEngine::open_sim(manifest, "cloud-test").unwrap();
        CloudStageServer::new(engine)
    }

    #[test]
    fn partial_suffix_matches_direct_engine_run() {
        let srv = server();
        // A batch of 3 (not an exported size: exercises pad + truncate)
        // cut after stage 1: activations are stage-1 outputs, shape [3, 16].
        let input = HostTensor::new(
            vec![3, 4],
            vec![0.1, -0.2, 0.3, 0.4, 1.0, 0.0, -1.0, 0.5, 0.7, 0.7, 0.7, 0.7],
        )
        .unwrap();
        let padded = input.pad_batch(4);
        let acts = srv.engine().run_stages(1, 1, &padded).unwrap().take_batch(3);

        let out = srv.serve_partial(1, BRANCH_GATED, acts.clone()).unwrap();
        assert_eq!(out.samples.len(), 3);
        assert!(out.samples.iter().all(|s| !s.exited));

        // Oracle: the engine run straight through.
        let full = srv.engine().run_stages(2, 3, &acts.pad_batch(4)).unwrap();
        let want = InferenceEngine::argmax_classes(&full);
        for (s, w) in out.samples.iter().zip(&want) {
            assert_eq!(s.class as usize, *w);
        }

        assert_eq!(srv.splits_served(), vec![0, 1, 0]);
        let (batches, samples, gated, _, errors) = srv.counters();
        assert_eq!((batches, samples, gated, errors), (1, 3, 1, 0));
    }

    #[test]
    fn rejects_edge_only_and_empty_batches() {
        let srv = server();
        // split = N: nothing left to run in the cloud.
        let t = HostTensor::zeros(vec![1, 2]);
        assert!(srv.serve_partial(3, BRANCH_GATED, t).is_err());
        // Out-of-range split.
        let t = HostTensor::zeros(vec![1, 2]);
        assert!(srv.serve_partial(9, BRANCH_GATED, t).is_err());
        // Empty batch.
        let t = HostTensor::zeros(vec![0, 4]);
        assert!(srv.serve_partial(0, BRANCH_GATED, t).is_err());
        let (_, _, _, _, errors) = srv.counters();
        assert_eq!(errors, 3);
        assert_eq!(srv.splits_served(), vec![0, 0, 0]);
    }

    #[test]
    fn per_encoding_counters_and_byte_accounting_reach_the_metrics_json() {
        let srv = server();
        let acts = HostTensor::zeros(vec![2, 16]);
        srv.serve_partial_encoded(1, BRANCH_GATED, WireEncoding::Raw, acts.clone())
            .unwrap();
        srv.serve_partial_encoded(1, BRANCH_GATED, WireEncoding::Q8, acts.clone())
            .unwrap();
        srv.serve_partial_encoded(1, BRANCH_GATED, WireEncoding::Q8, acts.clone())
            .unwrap();
        srv.serve_partial_encoded(1, BRANCH_GATED, WireEncoding::Q4, acts.clone())
            .unwrap();
        // A rejected request counts as an error, not a served encoding.
        assert!(srv
            .serve_partial_encoded(3, BRANCH_GATED, WireEncoding::Q8, acts)
            .is_err());
        assert_eq!(srv.served_by_encoding(), [1, 2, 1]);
        let (_, _, _, _, errors) = srv.counters();
        assert_eq!(errors, 1);

        srv.note_io(1000, 250);
        srv.note_io(24, 8);
        assert_eq!(srv.bytes_io(), (1024, 258));

        let json = srv.metrics_json();
        assert!(json.contains("\"served_by_encoding\":{\"raw\":1,\"q8\":2,\"q4\":1}"));
        assert!(json.contains("\"bytes_received\":1024"));
        assert!(json.contains("\"bytes_sent\":258"));
    }

    /// A live terminal tier behind a real listener, plus a middle tier
    /// whose forward engine points at it. Both engines share the same
    /// synthetic manifest (same name → same deterministic weights), so
    /// segment composition across the two servers must match one full
    /// run on either engine.
    fn forwarding_pair() -> (
        crate::server::tcp::ServerHandle,
        Arc<CloudStageServer>,
        CloudStageServer,
    ) {
        use crate::server::remote::RemoteCloudConfig;
        use crate::server::tcp::Server;
        let terminal = Arc::new(server());
        let handle = Server::new(terminal.clone()).start(0).unwrap();
        let forward = Arc::new(RemoteCloudEngine::new(RemoteCloudConfig::new(
            handle.addr().to_string(),
        )));
        let middle = server().with_forward(forward);
        (handle, terminal, middle)
    }

    #[test]
    fn middle_tier_runs_its_segment_and_forwards_the_tail() {
        let (handle, terminal, middle) = forwarding_pair();
        let input = HostTensor::new(
            vec![2, 4],
            vec![0.1, -0.2, 0.3, 0.4, 1.0, 0.0, -1.0, 0.5],
        )
        .unwrap();
        // The edge cut after stage 1; the middle runs 2..=2, the
        // terminal runs 3..=3.
        let acts = middle.engine().run_stages(1, 1, &input).unwrap();
        let out = middle
            .serve_chain(&[1, 2], BRANCH_GATED, WireEncoding::Raw, acts.clone())
            .unwrap();
        assert_eq!(out.samples.len(), 2);

        // Oracle: the suffix 2..=3 in one go.
        let full = middle.engine().run_stages(2, 3, &acts).unwrap();
        let want = InferenceEngine::argmax_classes(&full);
        for (s, w) in out.samples.iter().zip(&want) {
            assert_eq!(s.class as usize, *w);
        }

        // Per-hop accounting: the middle observed the frame at cut 1,
        // the terminal at cut 2 — and nowhere else.
        assert_eq!(middle.chain_counters(), (1, 1));
        assert_eq!(middle.splits_served(), vec![0, 1, 0]);
        assert_eq!(terminal.splits_served(), vec![0, 0, 1]);
        let (term_batches, ..) = terminal.counters();
        assert_eq!(term_batches, 1);
        handle.stop();
    }

    #[test]
    fn pass_through_middle_relays_the_activation_untouched() {
        let (handle, terminal, middle) = forwarding_pair();
        let acts = HostTensor::new(vec![1, 16], (0..16).map(|i| i as f32 * 0.25 - 2.0).collect())
            .unwrap();
        // cuts [1, 1]: zero stages here, the terminal does all the work.
        let via_chain = middle
            .serve_chain(&[1, 1], BRANCH_GATED, WireEncoding::Raw, acts.clone())
            .unwrap();
        // Oracle: the same activation served directly as a partial.
        let direct = terminal.serve_partial(1, BRANCH_GATED, acts).unwrap();
        assert_eq!(via_chain.samples.len(), 1);
        assert_eq!(via_chain.samples[0].class, direct.samples[0].class);
        assert_eq!(middle.chain_counters(), (1, 1));
        assert_eq!(middle.splits_served(), vec![0, 1, 0]);
        assert_eq!(terminal.splits_served(), vec![0, 2, 0]);
        handle.stop();
    }

    #[test]
    fn chain_tails_are_rejected_without_a_forward_engine() {
        let srv = server();
        let acts = HostTensor::zeros(vec![1, 16]);
        let err = srv
            .serve_chain(&[1, 2], BRANCH_GATED, WireEncoding::Raw, acts.clone())
            .unwrap_err()
            .to_string();
        assert!(err.contains("terminal tier"), "{err}");
        // Non-monotone cuts are rejected even with the right shape.
        let (handle, _terminal, middle) = forwarding_pair();
        assert!(middle
            .serve_chain(&[2, 1, 2], BRANCH_GATED, WireEncoding::Raw, acts.clone())
            .is_err());
        // A single-cut chain frame is an ordinary partial.
        let out = srv
            .serve_chain(&[1], BRANCH_GATED, WireEncoding::Raw, acts)
            .unwrap();
        assert_eq!(out.samples.len(), 1);
        assert_eq!(srv.chain_counters(), (0, 0), "no forwarding happened");
        assert_eq!(srv.splits_served(), vec![0, 1, 0]);
        handle.stop();
    }

    #[test]
    fn serve_infer_is_cloud_only_full_inference() {
        let srv = server();
        let img = HostTensor::new(vec![4], vec![0.3, -0.1, 0.8, 0.2]).unwrap();
        let r = srv.serve_infer(None, img.clone()).unwrap();
        assert!(r.class < 2);
        // Oracle: full run on a batch of one.
        let batched = HostTensor::stack(&[img]).unwrap();
        let out = srv.engine().run_stages(1, 3, &batched).unwrap();
        assert_eq!(r.class, InferenceEngine::argmax_classes(&out)[0]);
        let (_, _, _, full, _) = srv.counters();
        assert_eq!(full, 1);
        assert!(srv.metrics_json().contains("\"full_infers\":1"));
    }
}
