//! Wire protocol: little-endian, length-prefixed frames.
//!
//! ```text
//! Frame layout (both directions):
//!   u32 magic "BSV1" (0x31565342) | u32 body_len | body
//!
//! Request body:  u8 kind | payload
//!   kind 0 PING          — empty payload
//!   kind 1 INFER         — u32 ndims | u32 dims[ndims] | f32 data[prod(dims)]
//!   kind 2 METRICS       — empty payload
//!   kind 3 INFER_CLASS   — u8 link_class | u32 ndims | u32 dims[ndims] |
//!                          f32 data[prod(dims)]
//!                          (link_class indexes the fleet's class registry;
//!                          kind 1 is equivalent to class 0)
//!   kind 4 INFER_PARTIAL — u32 split | u8 branch_state | u32 ndims |
//!                          u32 dims[ndims] | f32 data[prod(dims)]
//!                          (edge→cloud offload: the tensor is a batched
//!                          activation cut after stage `split`; the server
//!                          runs stages split+1..=N. branch_state: 0 = the
//!                          side-branch gate never ran for these samples
//!                          (inactive under the cut plan), 1 = it ran on
//!                          the edge and every sample here survived)
//!   kind 5 INFER_PARTIAL_SEQ — u32 seq | u32 split | u8 branch_state |
//!                          encoded tensor (below)
//!                          (pipelined variant of kind 4: `seq` is echoed
//!                          in the matching PARTIAL_RESULT_SEQ/ERROR_SEQ
//!                          so a client may stream many frames per
//!                          connection and match answers out of lockstep;
//!                          the activation payload carries a one-byte
//!                          encoding tag for quantized transfer)
//!   kind 6 INFER_CHAIN_SEQ — u32 seq | u32 ncuts | u32 cuts[ncuts] |
//!                          u8 branch_state | encoded tensor (below)
//!                          (forwardable kind 5 for K-tier chains: the
//!                          activation was cut after stage cuts[0]; the
//!                          receiving server runs cuts[0]+1..=cuts[1] and
//!                          forwards the remainder with cuts[1..], or —
//!                          when ncuts == 1 — runs cuts[0]+1..=N and
//!                          answers like kind 5. cuts must be
//!                          non-decreasing, ncuts in 1..=16; answered by
//!                          PARTIAL_RESULT_SEQ/ERROR_SEQ like kind 5)
//!
//! Encoded tensor (kind 5/6 payloads): u8 encoding | u32 ndims |
//! u32 dims[ndims] | payload, where payload is
//!   encoding 0 raw — f32 data[n]                        (bit-exact)
//!   encoding 1 q8  — f32 scale | f32 zero | u8 q[n]
//!   encoding 2 q4  — f32 scale | f32 zero | u8 packed[⌈n/2⌉]
//!                    (low nibble first; a final odd high nibble is padding)
//!   encoding 3 q8s — u32 nnz | f32 scale | f32 zero | bitmap[⌈n/8⌉] |
//!                    u8 q[nnz]
//!                    (sparse q8 for post-ReLU activations: bit i set ⇔
//!                    element i is nonzero and quantized; clear ⇔ exactly
//!                    0.0. The encoder substitutes this for q8 when it is
//!                    strictly smaller; decoders treat it as q8.)
//! Dequantization is `zero + q·scale` per element (see
//! [`crate::network::encoding`] for the size identities the planner
//! shares).
//!
//! Response body: u8 kind | payload
//!   kind 0 PONG           — empty
//!   kind 1 RESULT         — u64 id | u32 class | u8 exited | f32 entropy |
//!                           f64 latency_s
//!   kind 2 METRICS        — u32 len | JSON bytes
//!   kind 3 PARTIAL_RESULT — u32 n | n × (u32 class | u8 exited |
//!                           f32 entropy) | f64 cloud_s
//!                           (one record per sample of the INFER_PARTIAL
//!                           batch, in order; cloud_s is the server-side
//!                           compute time for the whole batch)
//!   kind 4 PARTIAL_RESULT_SEQ — u32 seq | u32 n | n × (u32 class |
//!                           u8 exited | f32 entropy) | f64 cloud_s
//!                           (kind 3 with the request's seq echoed first)
//!   kind 5 THROTTLE       — u32 retry_after_ms
//!                           (explicit backpressure: the request it answers
//!                           was NOT processed — the connection exceeded its
//!                           in-flight window, the server is over
//!                           --max-conns, or the shard admission queue
//!                           rejected. The client should back off at least
//!                           retry_after_ms before resending; the
//!                           connection itself stays healthy)
//!   kind 254 ERROR_SEQ    — u32 seq | u32 len | UTF-8 message
//!                           (an ERROR bound to one in-flight kind-5
//!                           request instead of the whole connection)
//!   kind 255 ERROR        — u32 len | UTF-8 message
//! ```

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::network::encoding::WireEncoding;
use crate::runtime::HostTensor;

pub const MAGIC: u32 = 0x3156_5342; // "BSV1" LE
/// Sanity cap on frame size (64 MiB) — rejects garbage/hostile lengths.
pub const MAX_BODY: u32 = 64 << 20;

/// `branch_state`: the side-branch gate has not been evaluated for the
/// samples in this INFER_PARTIAL frame (the cut plan kept it inactive).
pub const BRANCH_PENDING: u8 = 0;
/// `branch_state`: the gate ran on the edge and every sample survived
/// (exited samples were answered there and never cross the wire).
pub const BRANCH_GATED: u8 = 1;

/// Sanity cap on PARTIAL_RESULT record counts (a batch never remotely
/// approaches this; rejects hostile lengths before allocation).
const MAX_PARTIAL_SAMPLES: usize = 65_536;

/// Sanity cap on the cut count of an INFER_CHAIN_SEQ frame — a real
/// chain has a handful of tiers; rejects hostile counts before
/// allocation.
pub const MAX_CHAIN_TIERS: usize = 16;

/// Encoded-tensor tag bytes (kind-5 activation payloads).
pub const ENC_RAW: u8 = 0;
pub const ENC_Q8: u8 = 1;
pub const ENC_Q4: u8 = 2;
/// Sparse q8: zero bitmap + quantized nonzeros. Never requested
/// directly — the encoder substitutes it for [`ENC_Q8`] when the
/// activation is mostly zeros and the sparse form is strictly smaller.
pub const ENC_Q8_SPARSE: u8 = 3;

/// One sample's outcome in a PARTIAL_RESULT frame. `exited`/`entropy`
/// are meaningful only when the server itself gated the sample (today's
/// suffix-only [`super::CloudStageServer`] never does: `exited` is
/// always false and `entropy` 0.0 — the edge keeps the authoritative
/// entropy it measured at the gate).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartialSample {
    pub class: u32,
    pub exited: bool,
    pub entropy: f32,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Ping,
    /// Untagged inference — served as link class 0.
    Infer(HostTensor),
    Metrics,
    /// Inference tagged with the client's link class (fleet routing).
    InferClass { class: u8, image: HostTensor },
    /// Partial inference (edge→cloud offload): `activation` is a batched
    /// tensor cut after stage `split`; the server runs the suffix
    /// `split+1..=N`. `branch_state` is [`BRANCH_PENDING`] or
    /// [`BRANCH_GATED`].
    InferPartial {
        split: u32,
        branch_state: u8,
        activation: HostTensor,
    },
    /// Pipelined partial inference: [`Request::InferPartial`] plus a
    /// client-chosen `seq` the server echoes in its answer, and a
    /// wire-encoded (possibly quantized) activation payload. On decode
    /// `activation` is already dequantized; `encoding` records what
    /// crossed the wire (the sparse q8 form decodes as
    /// [`WireEncoding::Q8`]). Quantized round-trips are lossy, so only
    /// raw frames re-encode to identical bytes.
    InferPartialSeq {
        seq: u32,
        split: u32,
        branch_state: u8,
        encoding: WireEncoding,
        activation: HostTensor,
    },
    /// Forwardable chain inference ([`Request::InferPartialSeq`] for a
    /// K-tier chain): the activation was cut after stage `cuts[0]`; the
    /// receiving tier runs `cuts[0]+1..=cuts[1]` and forwards onward
    /// with `cuts[1..]`, or — when only one cut remains — runs
    /// `cuts[0]+1..=N` and answers exactly like kind 5. `cuts` is
    /// non-decreasing with 1..=[`MAX_CHAIN_TIERS`] entries; a
    /// pass-through tier (`cuts[0] == cuts[1]`) runs nothing and
    /// forwards the activation as received.
    InferChainSeq {
        seq: u32,
        cuts: Vec<u32>,
        branch_state: u8,
        encoding: WireEncoding,
        activation: HostTensor,
    },
}

#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Pong,
    Result {
        id: u64,
        class: u32,
        exited_early: bool,
        entropy: f32,
        latency_s: f64,
    },
    Metrics(String),
    /// One record per sample of an INFER_PARTIAL batch, in order, plus
    /// the server-side compute seconds for the whole batch.
    PartialResult {
        samples: Vec<PartialSample>,
        cloud_s: f64,
    },
    /// [`Response::PartialResult`] answering a pipelined kind-5 request,
    /// with that request's `seq` echoed so the client can match it to
    /// one of its in-flight waiters.
    PartialResultSeq {
        seq: u32,
        samples: Vec<PartialSample>,
        cloud_s: f64,
    },
    /// An error bound to one in-flight kind-5 request (the connection —
    /// and its other in-flight requests — stay healthy).
    ErrorSeq { seq: u32, message: String },
    /// Explicit backpressure: the request this frame answers was **not**
    /// processed (connection over its in-flight window, server over
    /// `--max-conns`, or shard admission queue full). The client should
    /// wait at least `retry_after_ms` before resending; the connection
    /// stays healthy.
    Throttle { retry_after_ms: u32 },
    Error(String),
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn write_frame(w: &mut impl Write, body: &[u8]) -> Result<()> {
    if body.len() > MAX_BODY as usize {
        bail!("frame too large: {}", body.len());
    }
    let mut head = Vec::with_capacity(8);
    put_u32(&mut head, MAGIC);
    put_u32(&mut head, body.len() as u32);
    w.write_all(&head)?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut head = [0u8; 8];
    r.read_exact(&mut head).context("reading frame header")?;
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    if magic != MAGIC {
        bail!("bad magic {magic:#x}");
    }
    let len = u32::from_le_bytes(head[4..8].try_into().unwrap());
    if len > MAX_BODY {
        bail!("frame length {len} exceeds cap");
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body).context("reading frame body")?;
    Ok(body)
}

fn put_dims(b: &mut Vec<u8>, t: &HostTensor) {
    put_u32(b, t.shape().len() as u32);
    for &d in t.shape() {
        put_u32(b, d as u32);
    }
}

fn put_tensor(b: &mut Vec<u8>, t: &HostTensor) {
    put_dims(b, t);
    for v in t.data() {
        b.extend_from_slice(&v.to_le_bytes());
    }
}

/// Parse the shared `u32 ndims | u32 dims[]` header; returns the shape,
/// its element count, and the remaining payload bytes.
fn take_dims(rest: &[u8]) -> Result<(Vec<usize>, usize, &[u8])> {
    if rest.len() < 4 {
        bail!("truncated INFER header");
    }
    let ndims = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
    if ndims > 8 {
        bail!("too many dims: {ndims}");
    }
    let need = 4 + ndims * 4;
    if rest.len() < need {
        bail!("truncated INFER dims");
    }
    let mut shape = Vec::with_capacity(ndims);
    for i in 0..ndims {
        shape.push(u32::from_le_bytes(rest[4 + i * 4..8 + i * 4].try_into().unwrap()) as usize);
    }
    let n: usize = shape.iter().product();
    Ok((shape, n, &rest[need..]))
}

fn take_f32_payload(shape: Vec<usize>, n: usize, data_bytes: &[u8]) -> Result<HostTensor> {
    if data_bytes.len() != n * 4 {
        bail!(
            "INFER payload {} bytes, shape {:?} wants {}",
            data_bytes.len(),
            shape,
            n * 4
        );
    }
    // Decode-in-place contract: parse straight out of the read buffer
    // into the tensor's shared allocation. `ChunksExact` sizes the
    // collect exactly, so this is the one and only f32 buffer the
    // sample ever owns — admission and coordinator hops clone the
    // `Arc`, not the data.
    let data: std::sync::Arc<[f32]> = data_bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    HostTensor::from_shared(shape, data)
}

fn take_tensor(rest: &[u8]) -> Result<HostTensor> {
    let (shape, n, data_bytes) = take_dims(rest)?;
    take_f32_payload(shape, n, data_bytes)
}

/// Per-tensor linear quantization range. `None` when the data contains
/// a non-finite value (the encoder then falls back to a raw payload —
/// a NaN must cross the wire bit-exactly, not be clamped into a level).
fn finite_minmax(data: &[f32]) -> Option<(f32, f32)> {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in data {
        if !v.is_finite() {
            return None;
        }
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if data.is_empty() {
        Some((0.0, 0.0))
    } else {
        Some((lo, hi))
    }
}

/// Quantize one value onto `0..=levels` with the *stored* (f32) scale,
/// so encode and decode agree on the grid exactly.
fn quantize(v: f32, zero: f32, scale: f32, levels: u32) -> u8 {
    if scale <= 0.0 {
        return 0;
    }
    (((v - zero) / scale).round().clamp(0.0, levels as f32)) as u8
}

fn push_levels_header(b: &mut Vec<u8>, lo: f32, hi: f32, levels: u32) -> f32 {
    let scale = (hi - lo) / levels as f32;
    b.extend_from_slice(&scale.to_le_bytes());
    b.extend_from_slice(&lo.to_le_bytes());
    scale
}

/// Append an encoded-tensor payload (`u8 encoding | dims | payload`).
/// Under [`WireEncoding::Q8`] the encoder substitutes the sparse form
/// when it is strictly smaller; non-finite data always ships raw.
pub fn put_tensor_encoded(b: &mut Vec<u8>, t: &HostTensor, enc: WireEncoding) {
    let data = t.data();
    let range = if enc == WireEncoding::Raw {
        None
    } else {
        finite_minmax(data)
    };
    let Some((lo, hi)) = range else {
        b.push(ENC_RAW);
        put_tensor(b, t);
        return;
    };
    let n = data.len();
    match enc {
        WireEncoding::Raw => unreachable!("raw handled above"),
        WireEncoding::Q8 => {
            let nnz = data.iter().filter(|v| **v != 0.0).count();
            // Sparse: 12-byte header + bitmap + nnz vs dense 8 + n.
            if 12 + n.div_ceil(8) + nnz < 8 + n {
                b.push(ENC_Q8_SPARSE);
                put_dims(b, t);
                put_u32(b, nnz as u32);
                let (nlo, nhi) = finite_minmax(
                    &data.iter().copied().filter(|v| *v != 0.0).collect::<Vec<_>>(),
                )
                .expect("finite checked above");
                let scale = push_levels_header(b, nlo, nhi, 255);
                let mut bitmap = vec![0u8; n.div_ceil(8)];
                for (i, &v) in data.iter().enumerate() {
                    if v != 0.0 {
                        bitmap[i / 8] |= 1 << (i % 8);
                    }
                }
                b.extend_from_slice(&bitmap);
                for &v in data.iter().filter(|v| **v != 0.0) {
                    b.push(quantize(v, nlo, scale, 255));
                }
            } else {
                b.push(ENC_Q8);
                put_dims(b, t);
                let scale = push_levels_header(b, lo, hi, 255);
                for &v in data {
                    b.push(quantize(v, lo, scale, 255));
                }
            }
        }
        WireEncoding::Q4 => {
            b.push(ENC_Q4);
            put_dims(b, t);
            let scale = push_levels_header(b, lo, hi, 15);
            for pair in data.chunks(2) {
                let lo_nib = quantize(pair[0], lo, scale, 15);
                let hi_nib = pair.get(1).map_or(0, |v| quantize(*v, lo, scale, 15));
                b.push(lo_nib | (hi_nib << 4));
            }
        }
    }
}

/// Decode an encoded-tensor payload into a dequantized [`HostTensor`]
/// plus the [`WireEncoding`] that crossed the wire (sparse q8 reports
/// as [`WireEncoding::Q8`]).
pub fn take_tensor_encoded(rest: &[u8]) -> Result<(HostTensor, WireEncoding)> {
    let (&enc, rest) = rest.split_first().context("truncated encoded tensor")?;
    if enc == ENC_RAW {
        return Ok((take_tensor(rest)?, WireEncoding::Raw));
    }
    let (shape, n, payload) = take_dims(rest)?;
    match enc {
        ENC_Q8 => {
            if payload.len() != 8 + n {
                bail!("bad q8 payload {} bytes for {n} elems", payload.len());
            }
            let scale = f32::from_le_bytes(payload[0..4].try_into().unwrap());
            let zero = f32::from_le_bytes(payload[4..8].try_into().unwrap());
            let data = payload[8..].iter().map(|&q| zero + q as f32 * scale).collect();
            Ok((HostTensor::new(shape, data)?, WireEncoding::Q8))
        }
        ENC_Q4 => {
            if payload.len() != 8 + n.div_ceil(2) {
                bail!("bad q4 payload {} bytes for {n} elems", payload.len());
            }
            let scale = f32::from_le_bytes(payload[0..4].try_into().unwrap());
            let zero = f32::from_le_bytes(payload[4..8].try_into().unwrap());
            let mut data = Vec::with_capacity(n);
            for (i, &byte) in payload[8..].iter().enumerate() {
                data.push(zero + (byte & 0x0F) as f32 * scale);
                if 2 * i + 1 < n {
                    data.push(zero + (byte >> 4) as f32 * scale);
                }
            }
            Ok((HostTensor::new(shape, data)?, WireEncoding::Q4))
        }
        ENC_Q8_SPARSE => {
            let bitmap_len = n.div_ceil(8);
            if payload.len() < 12 + bitmap_len {
                bail!("truncated sparse q8 payload");
            }
            let nnz = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
            if nnz > n {
                bail!("sparse q8 claims {nnz} nonzeros in {n} elems");
            }
            if payload.len() != 12 + bitmap_len + nnz {
                bail!(
                    "bad sparse q8 payload {} bytes for {n} elems / {nnz} nonzeros",
                    payload.len()
                );
            }
            let scale = f32::from_le_bytes(payload[4..8].try_into().unwrap());
            let zero = f32::from_le_bytes(payload[8..12].try_into().unwrap());
            let bitmap = &payload[12..12 + bitmap_len];
            let qs = &payload[12 + bitmap_len..];
            let mut data = Vec::with_capacity(n);
            let mut taken = 0usize;
            for i in 0..n {
                if bitmap[i / 8] & (1 << (i % 8)) != 0 {
                    if taken >= nnz {
                        bail!("sparse q8 bitmap has more bits set than nnz {nnz}");
                    }
                    data.push(zero + qs[taken] as f32 * scale);
                    taken += 1;
                } else {
                    data.push(0.0);
                }
            }
            if taken != nnz {
                bail!("sparse q8 bitmap has {taken} bits set, header says {nnz}");
            }
            // Padding bits past element n-1 must be clear.
            for i in n..bitmap_len * 8 {
                if bitmap[i / 8] & (1 << (i % 8)) != 0 {
                    bail!("sparse q8 bitmap sets padding bit {i}");
                }
            }
            Ok((HostTensor::new(shape, data)?, WireEncoding::Q8))
        }
        k => bail!("unknown tensor encoding {k}"),
    }
}

/// Encode an INFER_PARTIAL request body straight from a borrowed
/// tensor. The remote cloud client's hot path uses this to avoid
/// cloning the batched activation into an owned [`Request`] first;
/// `Request::encode` delegates here so the two can't drift.
pub fn encode_infer_partial(split: u32, branch_state: u8, activation: &HostTensor) -> Vec<u8> {
    let mut b = vec![4u8];
    put_u32(&mut b, split);
    b.push(branch_state);
    put_tensor(&mut b, activation);
    b
}

/// Encode a pipelined INFER_PARTIAL_SEQ request body straight from a
/// borrowed tensor — the remote engine's hot path, same no-clone
/// contract as [`encode_infer_partial`]; `Request::encode` delegates
/// here so the two can't drift.
pub fn encode_infer_partial_seq(
    seq: u32,
    split: u32,
    branch_state: u8,
    encoding: WireEncoding,
    activation: &HostTensor,
) -> Vec<u8> {
    let mut b = vec![5u8];
    put_u32(&mut b, seq);
    put_u32(&mut b, split);
    b.push(branch_state);
    put_tensor_encoded(&mut b, activation, encoding);
    b
}

/// Encode an INFER_CHAIN_SEQ request body straight from a borrowed
/// tensor — the forwarding hot path, same no-clone contract as
/// [`encode_infer_partial_seq`]; `Request::encode` delegates here so
/// the two can't drift. `cuts` carries the cut the activation sits at
/// plus every remaining downstream cut.
pub fn encode_infer_chain_seq(
    seq: u32,
    cuts: &[u32],
    branch_state: u8,
    encoding: WireEncoding,
    activation: &HostTensor,
) -> Vec<u8> {
    let mut b = vec![6u8];
    put_u32(&mut b, seq);
    put_u32(&mut b, cuts.len() as u32);
    for &c in cuts {
        put_u32(&mut b, c);
    }
    b.push(branch_state);
    put_tensor_encoded(&mut b, activation, encoding);
    b
}

/// Shared body of PARTIAL_RESULT (kind 3) and PARTIAL_RESULT_SEQ
/// (kind 4, after the seq): `u32 n | n records | f64 cloud_s`.
fn put_partial_body(b: &mut Vec<u8>, samples: &[PartialSample], cloud_s: f64) {
    put_u32(b, samples.len() as u32);
    for s in samples {
        put_u32(b, s.class);
        b.push(u8::from(s.exited));
        b.extend_from_slice(&s.entropy.to_le_bytes());
    }
    b.extend_from_slice(&cloud_s.to_le_bytes());
}

fn take_partial_body(rest: &[u8]) -> Result<(Vec<PartialSample>, f64)> {
    if rest.len() < 4 {
        bail!("truncated PARTIAL_RESULT header");
    }
    let n = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
    if n > MAX_PARTIAL_SAMPLES {
        bail!("PARTIAL_RESULT sample count {n} exceeds cap");
    }
    // 9 bytes per record (u32 class | u8 exited | f32 entropy)
    // plus the trailing f64 cloud_s.
    if rest.len() != 4 + n * 9 + 8 {
        bail!("bad PARTIAL_RESULT length {} for {n} samples", rest.len());
    }
    let mut samples = Vec::with_capacity(n);
    for r in rest[4..4 + n * 9].chunks_exact(9) {
        let exited = match r[4] {
            0 => false,
            1 => true,
            v => bail!("invalid exited flag {v}"),
        };
        samples.push(PartialSample {
            class: u32::from_le_bytes(r[0..4].try_into().unwrap()),
            exited,
            entropy: f32::from_le_bytes(r[5..9].try_into().unwrap()),
        });
    }
    let cloud_s = f64::from_le_bytes(rest[4 + n * 9..].try_into().unwrap());
    Ok((samples, cloud_s))
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            Request::Ping => b.push(0),
            Request::Infer(t) => {
                b.push(1);
                put_tensor(&mut b, t);
            }
            Request::Metrics => b.push(2),
            Request::InferClass { class, image } => {
                b.push(3);
                b.push(*class);
                put_tensor(&mut b, image);
            }
            Request::InferPartial {
                split,
                branch_state,
                activation,
            } => {
                return encode_infer_partial(*split, *branch_state, activation);
            }
            Request::InferPartialSeq {
                seq,
                split,
                branch_state,
                encoding,
                activation,
            } => {
                return encode_infer_partial_seq(
                    *seq,
                    *split,
                    *branch_state,
                    *encoding,
                    activation,
                );
            }
            Request::InferChainSeq {
                seq,
                cuts,
                branch_state,
                encoding,
                activation,
            } => {
                return encode_infer_chain_seq(*seq, cuts, *branch_state, *encoding, activation);
            }
        }
        b
    }

    pub fn decode(body: &[u8]) -> Result<Request> {
        let (&kind, rest) = body.split_first().context("empty request body")?;
        match kind {
            0 => Ok(Request::Ping),
            1 => Ok(Request::Infer(take_tensor(rest)?)),
            2 => Ok(Request::Metrics),
            3 => {
                let (&class, rest) = rest
                    .split_first()
                    .context("truncated INFER_CLASS tag")?;
                Ok(Request::InferClass {
                    class,
                    image: take_tensor(rest)?,
                })
            }
            4 => {
                if rest.len() < 5 {
                    bail!("truncated INFER_PARTIAL header");
                }
                let split = u32::from_le_bytes(rest[0..4].try_into().unwrap());
                let branch_state = rest[4];
                if branch_state > BRANCH_GATED {
                    bail!("invalid branch_state {branch_state}");
                }
                Ok(Request::InferPartial {
                    split,
                    branch_state,
                    activation: take_tensor(&rest[5..])?,
                })
            }
            5 => {
                if rest.len() < 9 {
                    bail!("truncated INFER_PARTIAL_SEQ header");
                }
                let seq = u32::from_le_bytes(rest[0..4].try_into().unwrap());
                let split = u32::from_le_bytes(rest[4..8].try_into().unwrap());
                let branch_state = rest[8];
                if branch_state > BRANCH_GATED {
                    bail!("invalid branch_state {branch_state}");
                }
                let (activation, encoding) = take_tensor_encoded(&rest[9..])?;
                Ok(Request::InferPartialSeq {
                    seq,
                    split,
                    branch_state,
                    encoding,
                    activation,
                })
            }
            6 => {
                if rest.len() < 8 {
                    bail!("truncated INFER_CHAIN_SEQ header");
                }
                let seq = u32::from_le_bytes(rest[0..4].try_into().unwrap());
                let ncuts = u32::from_le_bytes(rest[4..8].try_into().unwrap()) as usize;
                if ncuts == 0 {
                    bail!("INFER_CHAIN_SEQ with no cuts");
                }
                if ncuts > MAX_CHAIN_TIERS {
                    bail!("INFER_CHAIN_SEQ cut count {ncuts} exceeds cap");
                }
                if rest.len() < 8 + ncuts * 4 + 1 {
                    bail!("truncated INFER_CHAIN_SEQ cuts");
                }
                let cuts: Vec<u32> = rest[8..8 + ncuts * 4]
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                for pair in cuts.windows(2) {
                    if pair[0] > pair[1] {
                        bail!("INFER_CHAIN_SEQ cuts {cuts:?} are not non-decreasing");
                    }
                }
                let branch_state = rest[8 + ncuts * 4];
                if branch_state > BRANCH_GATED {
                    bail!("invalid branch_state {branch_state}");
                }
                let (activation, encoding) = take_tensor_encoded(&rest[8 + ncuts * 4 + 1..])?;
                Ok(Request::InferChainSeq {
                    seq,
                    cuts,
                    branch_state,
                    encoding,
                    activation,
                })
            }
            k => bail!("unknown request kind {k}"),
        }
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            Response::Pong => b.push(0),
            Response::Result {
                id,
                class,
                exited_early,
                entropy,
                latency_s,
            } => {
                b.push(1);
                b.extend_from_slice(&id.to_le_bytes());
                put_u32(&mut b, *class);
                b.push(u8::from(*exited_early));
                b.extend_from_slice(&entropy.to_le_bytes());
                b.extend_from_slice(&latency_s.to_le_bytes());
            }
            Response::Metrics(json) => {
                b.push(2);
                put_u32(&mut b, json.len() as u32);
                b.extend_from_slice(json.as_bytes());
            }
            Response::PartialResult { samples, cloud_s } => {
                b.push(3);
                put_partial_body(&mut b, samples, *cloud_s);
            }
            Response::PartialResultSeq {
                seq,
                samples,
                cloud_s,
            } => {
                b.push(4);
                put_u32(&mut b, *seq);
                put_partial_body(&mut b, samples, *cloud_s);
            }
            Response::Throttle { retry_after_ms } => {
                b.push(5);
                put_u32(&mut b, *retry_after_ms);
            }
            Response::ErrorSeq { seq, message } => {
                b.push(254);
                put_u32(&mut b, *seq);
                put_u32(&mut b, message.len() as u32);
                b.extend_from_slice(message.as_bytes());
            }
            Response::Error(msg) => {
                b.push(255);
                put_u32(&mut b, msg.len() as u32);
                b.extend_from_slice(msg.as_bytes());
            }
        }
        b
    }

    pub fn decode(body: &[u8]) -> Result<Response> {
        let (&kind, rest) = body.split_first().context("empty response body")?;
        match kind {
            0 => Ok(Response::Pong),
            1 => {
                if rest.len() != 8 + 4 + 1 + 4 + 8 {
                    bail!("bad RESULT length {}", rest.len());
                }
                Ok(Response::Result {
                    id: u64::from_le_bytes(rest[0..8].try_into().unwrap()),
                    class: u32::from_le_bytes(rest[8..12].try_into().unwrap()),
                    exited_early: rest[12] != 0,
                    entropy: f32::from_le_bytes(rest[13..17].try_into().unwrap()),
                    latency_s: f64::from_le_bytes(rest[17..25].try_into().unwrap()),
                })
            }
            3 => {
                let (samples, cloud_s) = take_partial_body(rest)?;
                Ok(Response::PartialResult { samples, cloud_s })
            }
            4 => {
                if rest.len() < 4 {
                    bail!("truncated PARTIAL_RESULT_SEQ header");
                }
                let seq = u32::from_le_bytes(rest[0..4].try_into().unwrap());
                let (samples, cloud_s) = take_partial_body(&rest[4..])?;
                Ok(Response::PartialResultSeq {
                    seq,
                    samples,
                    cloud_s,
                })
            }
            5 => {
                if rest.len() != 4 {
                    bail!("bad THROTTLE length {}", rest.len());
                }
                Ok(Response::Throttle {
                    retry_after_ms: u32::from_le_bytes(rest[0..4].try_into().unwrap()),
                })
            }
            254 => {
                if rest.len() < 8 {
                    bail!("truncated ERROR_SEQ header");
                }
                let seq = u32::from_le_bytes(rest[0..4].try_into().unwrap());
                let len = u32::from_le_bytes(rest[4..8].try_into().unwrap()) as usize;
                if rest.len() != 8 + len {
                    bail!("ERROR_SEQ length mismatch");
                }
                let message =
                    String::from_utf8(rest[8..].to_vec()).context("invalid UTF-8")?;
                Ok(Response::ErrorSeq { seq, message })
            }
            2 | 255 => {
                if rest.len() < 4 {
                    bail!("truncated string frame");
                }
                let len = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
                if rest.len() != 4 + len {
                    bail!("string frame length mismatch");
                }
                let s = String::from_utf8(rest[4..].to_vec()).context("invalid UTF-8")?;
                Ok(if kind == 2 {
                    Response::Metrics(s)
                } else {
                    Response::Error(s)
                })
            }
            k => bail!("unknown response kind {k}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(r: &Request) -> Request {
        Request::decode(&r.encode()).unwrap()
    }

    fn roundtrip_resp(r: &Response) -> Response {
        Response::decode(&r.encode()).unwrap()
    }

    #[test]
    fn request_roundtrips() {
        assert_eq!(roundtrip_req(&Request::Ping), Request::Ping);
        assert_eq!(roundtrip_req(&Request::Metrics), Request::Metrics);
        let t = HostTensor::new(vec![2, 3], vec![1., -2., 3.5, 0., 5., 6.]).unwrap();
        assert_eq!(roundtrip_req(&Request::Infer(t.clone())), Request::Infer(t));
    }

    #[test]
    fn classed_request_roundtrips() {
        let t = HostTensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        for class in [0u8, 1, 2, 255] {
            let req = Request::InferClass {
                class,
                image: t.clone(),
            };
            assert_eq!(roundtrip_req(&req), req);
        }
        // The class tag must change the wire bytes (it is not implied).
        let tagged = Request::InferClass {
            class: 2,
            image: t.clone(),
        };
        assert_ne!(tagged.encode(), Request::Infer(t).encode());
        // Truncated tag / tensor rejected.
        assert!(Request::decode(&[3]).is_err());
        assert!(Request::decode(&[3, 1, 4, 0]).is_err());
    }

    #[test]
    fn response_roundtrips() {
        assert_eq!(roundtrip_resp(&Response::Pong), Response::Pong);
        let r = Response::Result {
            id: 42,
            class: 1,
            exited_early: true,
            entropy: 0.25,
            latency_s: 0.0123,
        };
        assert_eq!(roundtrip_resp(&r), r);
        assert_eq!(
            roundtrip_resp(&Response::Metrics("{\"a\":1}".into())),
            Response::Metrics("{\"a\":1}".into())
        );
        assert_eq!(
            roundtrip_resp(&Response::Error("boom".into())),
            Response::Error("boom".into())
        );
    }

    #[test]
    fn frame_roundtrip_and_validation() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        let mut cursor = std::io::Cursor::new(buf.clone());
        assert_eq!(read_frame(&mut cursor).unwrap(), b"hello");

        // Corrupt magic:
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(read_frame(&mut std::io::Cursor::new(bad)).is_err());

        // Hostile length:
        let mut hostile = Vec::new();
        put_u32(&mut hostile, MAGIC);
        put_u32(&mut hostile, u32::MAX);
        assert!(read_frame(&mut std::io::Cursor::new(hostile)).is_err());
    }

    #[test]
    fn partial_request_roundtrips() {
        let t = HostTensor::new(vec![2, 3], vec![1., -2., 3.5, 0., 5., 6.]).unwrap();
        for (split, state) in [(0u32, BRANCH_PENDING), (3, BRANCH_GATED), (17, BRANCH_GATED)] {
            let req = Request::InferPartial {
                split,
                branch_state: state,
                activation: t.clone(),
            };
            assert_eq!(roundtrip_req(&req), req);
        }
        // The split and branch state must change the wire bytes.
        let a = Request::InferPartial {
            split: 1,
            branch_state: BRANCH_PENDING,
            activation: t.clone(),
        };
        let b = Request::InferPartial {
            split: 2,
            branch_state: BRANCH_PENDING,
            activation: t.clone(),
        };
        let c = Request::InferPartial {
            split: 1,
            branch_state: BRANCH_GATED,
            activation: t.clone(),
        };
        assert_ne!(a.encode(), b.encode());
        assert_ne!(a.encode(), c.encode());

        // Truncated header / invalid branch state / truncated tensor.
        assert!(Request::decode(&[4]).is_err());
        assert!(Request::decode(&[4, 1, 0, 0, 0]).is_err());
        assert!(Request::decode(&[4, 1, 0, 0, 0, 2, 1, 0, 0, 0]).is_err());
        let mut trunc = a.encode();
        trunc.truncate(trunc.len() - 1);
        assert!(Request::decode(&trunc).is_err());
    }

    #[test]
    fn partial_result_roundtrips() {
        let empty = Response::PartialResult {
            samples: vec![],
            cloud_s: 0.0,
        };
        assert_eq!(roundtrip_resp(&empty), empty);
        let r = Response::PartialResult {
            samples: vec![
                PartialSample {
                    class: 1,
                    exited: false,
                    entropy: 0.0,
                },
                PartialSample {
                    class: 0,
                    exited: true,
                    entropy: 0.125,
                },
            ],
            cloud_s: 0.0042,
        };
        assert_eq!(roundtrip_resp(&r), r);
    }

    #[test]
    fn partial_result_rejects_malformed_bodies() {
        // Truncated header.
        assert!(Response::decode(&[3]).is_err());
        assert!(Response::decode(&[3, 1, 0]).is_err());
        // Count/body length mismatch (claims 2 samples, carries 1).
        let one = Response::PartialResult {
            samples: vec![PartialSample {
                class: 7,
                exited: false,
                entropy: 0.5,
            }],
            cloud_s: 1.0,
        };
        let mut body = one.encode();
        body[1..5].copy_from_slice(&2u32.to_le_bytes());
        assert!(Response::decode(&body).is_err());
        // Hostile sample count: rejected before allocation.
        let mut hostile = vec![3u8];
        hostile.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Response::decode(&hostile).is_err());
        // Invalid exited flag.
        let mut bad = one.encode();
        bad[9] = 7; // kind | u32 n | u32 class | exited byte
        assert!(Response::decode(&bad).is_err());
        // Truncated tail (missing part of cloud_s).
        let mut trunc = one.encode();
        trunc.truncate(trunc.len() - 3);
        assert!(Response::decode(&trunc).is_err());
    }

    #[test]
    fn throttle_roundtrips() {
        for retry_after_ms in [0u32, 1, 25, 60_000, u32::MAX] {
            let r = Response::Throttle { retry_after_ms };
            assert_eq!(roundtrip_resp(&r), r);
        }
        // The hint must change the wire bytes.
        let a = Response::Throttle { retry_after_ms: 10 };
        let b = Response::Throttle { retry_after_ms: 20 };
        assert_ne!(a.encode(), b.encode());
        // THROTTLE must be distinguishable from every other kind byte.
        assert_eq!(a.encode()[0], 5);
    }

    #[test]
    fn throttle_rejects_malformed_bodies() {
        // Truncated hint.
        assert!(Response::decode(&[5]).is_err());
        assert!(Response::decode(&[5, 1]).is_err());
        assert!(Response::decode(&[5, 1, 0, 0]).is_err());
        // Trailing garbage after the hint.
        assert!(Response::decode(&[5, 1, 0, 0, 0, 9]).is_err());
    }

    #[test]
    fn malformed_bodies_rejected() {
        assert!(Request::decode(&[]).is_err());
        assert!(Request::decode(&[9]).is_err());
        assert!(Request::decode(&[1, 1, 0, 0, 0]).is_err()); // truncated dims
        // INFER with mismatched payload:
        let mut b = vec![1u8];
        put_u32(&mut b, 1);
        put_u32(&mut b, 4); // shape [4] -> wants 16 payload bytes
        b.extend_from_slice(&[0u8; 8]);
        assert!(Request::decode(&b).is_err());
        assert!(Response::decode(&[1, 0, 0]).is_err());
    }

    fn encoded_roundtrip(t: &HostTensor, enc: WireEncoding) -> (HostTensor, WireEncoding, usize) {
        let mut b = Vec::new();
        put_tensor_encoded(&mut b, t, enc);
        let size = b.len();
        let (back, wire_enc) = take_tensor_encoded(&b).unwrap();
        assert_eq!(back.shape(), t.shape());
        (back, wire_enc, size)
    }

    #[test]
    fn raw_encoding_is_bit_exact() {
        let t = HostTensor::new(
            vec![2, 3],
            vec![1.0, -2.5, f32::NAN, f32::INFINITY, 0.0, 1e-30],
        )
        .unwrap();
        let (back, enc, _) = encoded_roundtrip(&t, WireEncoding::Raw);
        assert_eq!(enc, WireEncoding::Raw);
        for (a, b) in t.data().iter().zip(back.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn q8_roundtrip_error_is_within_1_255_of_range() {
        // A spread of values over [-3, 5]: range 8.
        let data: Vec<f32> = (0..257).map(|i| -3.0 + (i as f32) * 8.0 / 256.0).collect();
        let t = HostTensor::new(vec![257], data).unwrap();
        let (back, enc, size) = encoded_roundtrip(&t, WireEncoding::Q8);
        assert_eq!(enc, WireEncoding::Q8);
        // Dense q8: encoding byte + dims header + 8 + n.
        assert_eq!(size, 1 + 4 + 4 + 8 + 257);
        let bound = 8.0 / 255.0;
        for (a, b) in t.data().iter().zip(back.data()) {
            assert!((a - b).abs() <= bound, "{a} -> {b}");
        }
        // Extremes land exactly on grid points (zero-point is min).
        assert!((back.data()[0] - -3.0).abs() < 1e-6);
    }

    #[test]
    fn q4_roundtrip_error_is_within_1_15_of_range() {
        let data: Vec<f32> = (0..33).map(|i| (i as f32) * 0.125 - 2.0).collect(); // range 4
        let t = HostTensor::new(vec![33], data).unwrap();
        let (back, enc, size) = encoded_roundtrip(&t, WireEncoding::Q4);
        assert_eq!(enc, WireEncoding::Q4);
        // Odd element count: 17 packed bytes.
        assert_eq!(size, 1 + 4 + 4 + 8 + 17);
        let bound = 4.0 / 15.0;
        for (a, b) in t.data().iter().zip(back.data()) {
            assert!((a - b).abs() <= bound, "{a} -> {b}");
        }
    }

    #[test]
    fn constant_tensor_quantizes_exactly() {
        // Degenerate range (max == min): scale 0, every value decodes
        // to the zero-point exactly.
        let t = HostTensor::new(vec![4], vec![2.5; 4]).unwrap();
        for enc in [WireEncoding::Q8, WireEncoding::Q4] {
            let (back, _, _) = encoded_roundtrip(&t, enc);
            assert_eq!(back.data(), t.data());
        }
    }

    #[test]
    fn sparse_q8_kicks_in_for_post_relu_zeros_and_is_smaller() {
        // 90% exact zeros, nonzeros in [1, 2]: the ReLU shape.
        let data: Vec<f32> = (0..400)
            .map(|i| if i % 10 == 0 { 1.0 + (i as f32) / 400.0 } else { 0.0 })
            .collect();
        let t = HostTensor::new(vec![400], data).unwrap();
        let mut sparse = Vec::new();
        put_tensor_encoded(&mut sparse, &t, WireEncoding::Q8);
        assert_eq!(sparse[0], ENC_Q8_SPARSE, "mostly-zero tensor should ship sparse");
        // Strictly smaller than the dense q8 form would have been.
        assert!(sparse.len() < 1 + 4 + 4 + 8 + 400);
        let (back, enc) = take_tensor_encoded(&sparse).unwrap();
        assert_eq!(enc, WireEncoding::Q8, "sparse decodes as q8");
        let range = 1.0; // nonzero range [1, 2]
        for (a, b) in t.data().iter().zip(back.data()) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0, "zeros must decode exactly");
            } else {
                assert!((a - b).abs() <= range / 255.0, "{a} -> {b}");
            }
        }
        // A dense tensor must NOT pick the sparse form.
        let dense = HostTensor::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let mut b = Vec::new();
        put_tensor_encoded(&mut b, &dense, WireEncoding::Q8);
        assert_eq!(b[0], ENC_Q8);
    }

    #[test]
    fn non_finite_data_falls_back_to_raw() {
        let t = HostTensor::new(vec![2], vec![f32::NAN, 1.0]).unwrap();
        for enc in [WireEncoding::Q8, WireEncoding::Q4] {
            let mut b = Vec::new();
            put_tensor_encoded(&mut b, &t, enc);
            assert_eq!(b[0], ENC_RAW);
            let (back, got) = take_tensor_encoded(&b).unwrap();
            assert_eq!(got, WireEncoding::Raw);
            assert!(back.data()[0].is_nan());
            assert_eq!(back.data()[1], 1.0);
        }
    }

    #[test]
    fn seq_request_roundtrips_raw_and_decodes_quantized() {
        let t = HostTensor::new(vec![2, 3], vec![1., -2., 3.5, 0., 5., 6.]).unwrap();
        // Raw: lossless, full equality.
        let req = Request::InferPartialSeq {
            seq: 9,
            split: 2,
            branch_state: BRANCH_GATED,
            encoding: WireEncoding::Raw,
            activation: t.clone(),
        };
        assert_eq!(roundtrip_req(&req), req);
        // The seq must change the wire bytes.
        let other = Request::InferPartialSeq {
            seq: 10,
            split: 2,
            branch_state: BRANCH_GATED,
            encoding: WireEncoding::Raw,
            activation: t.clone(),
        };
        assert_ne!(req.encode(), other.encode());
        // Quantized: seq/split/state/encoding survive; data within bound.
        let q = Request::InferPartialSeq {
            seq: 77,
            split: 1,
            branch_state: BRANCH_PENDING,
            encoding: WireEncoding::Q8,
            activation: t.clone(),
        };
        match roundtrip_req(&q) {
            Request::InferPartialSeq {
                seq,
                split,
                branch_state,
                encoding,
                activation,
            } => {
                assert_eq!((seq, split, branch_state), (77, 1, BRANCH_PENDING));
                assert_eq!(encoding, WireEncoding::Q8);
                let range = 8.0; // [-2, 6]
                for (a, b) in t.data().iter().zip(activation.data()) {
                    assert!((a - b).abs() <= range / 255.0);
                }
            }
            other => panic!("unexpected {other:?}"),
        }
        // The quantized frame is genuinely smaller than the raw one.
        let big = HostTensor::new(vec![256], (0..256).map(|i| i as f32).collect()).unwrap();
        let raw_len = encode_infer_partial_seq(0, 1, 0, WireEncoding::Raw, &big).len();
        let q8_len = encode_infer_partial_seq(0, 1, 0, WireEncoding::Q8, &big).len();
        let q4_len = encode_infer_partial_seq(0, 1, 0, WireEncoding::Q4, &big).len();
        assert!(q8_len < raw_len / 3, "{q8_len} vs {raw_len}");
        assert!(q4_len < q8_len);
    }

    #[test]
    fn seq_frames_reject_malformed_bodies() {
        // Truncated seq header (needs 9 bytes + tensor).
        assert!(Request::decode(&[5]).is_err());
        assert!(Request::decode(&[5, 1, 0, 0, 0]).is_err());
        assert!(Request::decode(&[5, 1, 0, 0, 0, 2, 0, 0, 0]).is_err());
        // Invalid branch state.
        assert!(Request::decode(&[5, 1, 0, 0, 0, 2, 0, 0, 0, 9, 0]).is_err());
        // Unknown encoding byte (kind | seq | split | state | enc tag).
        let t = HostTensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        let mut body = encode_infer_partial_seq(1, 1, 0, WireEncoding::Raw, &t);
        body[10] = 200; // the encoding tag
        assert!(Request::decode(&body).is_err());
        // Truncated quantized payload.
        let mut trunc = encode_infer_partial_seq(1, 1, 0, WireEncoding::Q8, &t);
        trunc.truncate(trunc.len() - 1);
        assert!(Request::decode(&trunc).is_err());
        // Sparse q8 with a lying nnz header.
        let zeros =
            HostTensor::new(vec![64], vec![0.0; 64]).unwrap();
        let mut sparse = encode_infer_partial_seq(1, 1, 0, WireEncoding::Q8, &zeros);
        assert_eq!(sparse[10], ENC_Q8_SPARSE);
        // nnz lives right after the encoding byte + dims (1 dim here).
        let nnz_at = 10 + 1 + 4 + 4;
        sparse[nnz_at..nnz_at + 4].copy_from_slice(&200u32.to_le_bytes());
        assert!(Request::decode(&sparse).is_err());
    }

    #[test]
    fn seq_responses_roundtrip_and_reject_malformed() {
        let r = Response::PartialResultSeq {
            seq: 41,
            samples: vec![PartialSample {
                class: 1,
                exited: false,
                entropy: 0.25,
            }],
            cloud_s: 0.5,
        };
        assert_eq!(roundtrip_resp(&r), r);
        let e = Response::ErrorSeq {
            seq: 41,
            message: "nope".into(),
        };
        assert_eq!(roundtrip_resp(&e), e);
        // Seq responses must differ from their unsequenced twins on the
        // wire (the demultiplexer depends on it).
        let plain = Response::PartialResult {
            samples: vec![PartialSample {
                class: 1,
                exited: false,
                entropy: 0.25,
            }],
            cloud_s: 0.5,
        };
        assert_ne!(r.encode(), plain.encode());
        // Truncated / mismatched lengths.
        assert!(Response::decode(&[4]).is_err());
        assert!(Response::decode(&[4, 1, 0, 0, 0]).is_err());
        assert!(Response::decode(&[254, 1, 0, 0, 0]).is_err());
        let mut bad = e.encode();
        bad.truncate(bad.len() - 1);
        assert!(Response::decode(&bad).is_err());
        let mut wrong = r.encode();
        // Claim 2 samples while carrying 1.
        wrong[5..9].copy_from_slice(&2u32.to_le_bytes());
        assert!(Response::decode(&wrong).is_err());
    }
}
