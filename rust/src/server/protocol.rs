//! Wire protocol: little-endian, length-prefixed frames.
//!
//! ```text
//! Frame layout (both directions):
//!   u32 magic "BSV1" (0x31565342) | u32 body_len | body
//!
//! Request body:  u8 kind | payload
//!   kind 0 PING          — empty payload
//!   kind 1 INFER         — u32 ndims | u32 dims[ndims] | f32 data[prod(dims)]
//!   kind 2 METRICS       — empty payload
//!   kind 3 INFER_CLASS   — u8 link_class | u32 ndims | u32 dims[ndims] |
//!                          f32 data[prod(dims)]
//!                          (link_class indexes the fleet's class registry;
//!                          kind 1 is equivalent to class 0)
//!   kind 4 INFER_PARTIAL — u32 split | u8 branch_state | u32 ndims |
//!                          u32 dims[ndims] | f32 data[prod(dims)]
//!                          (edge→cloud offload: the tensor is a batched
//!                          activation cut after stage `split`; the server
//!                          runs stages split+1..=N. branch_state: 0 = the
//!                          side-branch gate never ran for these samples
//!                          (inactive under the cut plan), 1 = it ran on
//!                          the edge and every sample here survived)
//! Response body: u8 kind | payload
//!   kind 0 PONG           — empty
//!   kind 1 RESULT         — u64 id | u32 class | u8 exited | f32 entropy |
//!                           f64 latency_s
//!   kind 2 METRICS        — u32 len | JSON bytes
//!   kind 3 PARTIAL_RESULT — u32 n | n × (u32 class | u8 exited |
//!                           f32 entropy) | f64 cloud_s
//!                           (one record per sample of the INFER_PARTIAL
//!                           batch, in order; cloud_s is the server-side
//!                           compute time for the whole batch)
//!   kind 255 ERROR        — u32 len | UTF-8 message
//! ```

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::runtime::HostTensor;

pub const MAGIC: u32 = 0x3156_5342; // "BSV1" LE
/// Sanity cap on frame size (64 MiB) — rejects garbage/hostile lengths.
pub const MAX_BODY: u32 = 64 << 20;

/// `branch_state`: the side-branch gate has not been evaluated for the
/// samples in this INFER_PARTIAL frame (the cut plan kept it inactive).
pub const BRANCH_PENDING: u8 = 0;
/// `branch_state`: the gate ran on the edge and every sample survived
/// (exited samples were answered there and never cross the wire).
pub const BRANCH_GATED: u8 = 1;

/// Sanity cap on PARTIAL_RESULT record counts (a batch never remotely
/// approaches this; rejects hostile lengths before allocation).
const MAX_PARTIAL_SAMPLES: usize = 65_536;

/// One sample's outcome in a PARTIAL_RESULT frame. `exited`/`entropy`
/// are meaningful only when the server itself gated the sample (today's
/// suffix-only [`super::CloudStageServer`] never does: `exited` is
/// always false and `entropy` 0.0 — the edge keeps the authoritative
/// entropy it measured at the gate).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartialSample {
    pub class: u32,
    pub exited: bool,
    pub entropy: f32,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Ping,
    /// Untagged inference — served as link class 0.
    Infer(HostTensor),
    Metrics,
    /// Inference tagged with the client's link class (fleet routing).
    InferClass { class: u8, image: HostTensor },
    /// Partial inference (edge→cloud offload): `activation` is a batched
    /// tensor cut after stage `split`; the server runs the suffix
    /// `split+1..=N`. `branch_state` is [`BRANCH_PENDING`] or
    /// [`BRANCH_GATED`].
    InferPartial {
        split: u32,
        branch_state: u8,
        activation: HostTensor,
    },
}

#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Pong,
    Result {
        id: u64,
        class: u32,
        exited_early: bool,
        entropy: f32,
        latency_s: f64,
    },
    Metrics(String),
    /// One record per sample of an INFER_PARTIAL batch, in order, plus
    /// the server-side compute seconds for the whole batch.
    PartialResult {
        samples: Vec<PartialSample>,
        cloud_s: f64,
    },
    Error(String),
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn write_frame(w: &mut impl Write, body: &[u8]) -> Result<()> {
    if body.len() > MAX_BODY as usize {
        bail!("frame too large: {}", body.len());
    }
    let mut head = Vec::with_capacity(8);
    put_u32(&mut head, MAGIC);
    put_u32(&mut head, body.len() as u32);
    w.write_all(&head)?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut head = [0u8; 8];
    r.read_exact(&mut head).context("reading frame header")?;
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    if magic != MAGIC {
        bail!("bad magic {magic:#x}");
    }
    let len = u32::from_le_bytes(head[4..8].try_into().unwrap());
    if len > MAX_BODY {
        bail!("frame length {len} exceeds cap");
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body).context("reading frame body")?;
    Ok(body)
}

fn put_tensor(b: &mut Vec<u8>, t: &HostTensor) {
    put_u32(b, t.shape().len() as u32);
    for &d in t.shape() {
        put_u32(b, d as u32);
    }
    for v in t.data() {
        b.extend_from_slice(&v.to_le_bytes());
    }
}

fn take_tensor(rest: &[u8]) -> Result<HostTensor> {
    if rest.len() < 4 {
        bail!("truncated INFER header");
    }
    let ndims = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
    if ndims > 8 {
        bail!("too many dims: {ndims}");
    }
    let need = 4 + ndims * 4;
    if rest.len() < need {
        bail!("truncated INFER dims");
    }
    let mut shape = Vec::with_capacity(ndims);
    for i in 0..ndims {
        shape.push(u32::from_le_bytes(rest[4 + i * 4..8 + i * 4].try_into().unwrap()) as usize);
    }
    let n: usize = shape.iter().product();
    let data_bytes = &rest[need..];
    if data_bytes.len() != n * 4 {
        bail!(
            "INFER payload {} bytes, shape {:?} wants {}",
            data_bytes.len(),
            shape,
            n * 4
        );
    }
    let data: Vec<f32> = data_bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    HostTensor::new(shape, data)
}

/// Encode an INFER_PARTIAL request body straight from a borrowed
/// tensor. The remote cloud client's hot path uses this to avoid
/// cloning the batched activation into an owned [`Request`] first;
/// `Request::encode` delegates here so the two can't drift.
pub fn encode_infer_partial(split: u32, branch_state: u8, activation: &HostTensor) -> Vec<u8> {
    let mut b = vec![4u8];
    put_u32(&mut b, split);
    b.push(branch_state);
    put_tensor(&mut b, activation);
    b
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            Request::Ping => b.push(0),
            Request::Infer(t) => {
                b.push(1);
                put_tensor(&mut b, t);
            }
            Request::Metrics => b.push(2),
            Request::InferClass { class, image } => {
                b.push(3);
                b.push(*class);
                put_tensor(&mut b, image);
            }
            Request::InferPartial {
                split,
                branch_state,
                activation,
            } => {
                return encode_infer_partial(*split, *branch_state, activation);
            }
        }
        b
    }

    pub fn decode(body: &[u8]) -> Result<Request> {
        let (&kind, rest) = body.split_first().context("empty request body")?;
        match kind {
            0 => Ok(Request::Ping),
            1 => Ok(Request::Infer(take_tensor(rest)?)),
            2 => Ok(Request::Metrics),
            3 => {
                let (&class, rest) = rest
                    .split_first()
                    .context("truncated INFER_CLASS tag")?;
                Ok(Request::InferClass {
                    class,
                    image: take_tensor(rest)?,
                })
            }
            4 => {
                if rest.len() < 5 {
                    bail!("truncated INFER_PARTIAL header");
                }
                let split = u32::from_le_bytes(rest[0..4].try_into().unwrap());
                let branch_state = rest[4];
                if branch_state > BRANCH_GATED {
                    bail!("invalid branch_state {branch_state}");
                }
                Ok(Request::InferPartial {
                    split,
                    branch_state,
                    activation: take_tensor(&rest[5..])?,
                })
            }
            k => bail!("unknown request kind {k}"),
        }
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            Response::Pong => b.push(0),
            Response::Result {
                id,
                class,
                exited_early,
                entropy,
                latency_s,
            } => {
                b.push(1);
                b.extend_from_slice(&id.to_le_bytes());
                put_u32(&mut b, *class);
                b.push(u8::from(*exited_early));
                b.extend_from_slice(&entropy.to_le_bytes());
                b.extend_from_slice(&latency_s.to_le_bytes());
            }
            Response::Metrics(json) => {
                b.push(2);
                put_u32(&mut b, json.len() as u32);
                b.extend_from_slice(json.as_bytes());
            }
            Response::PartialResult { samples, cloud_s } => {
                b.push(3);
                put_u32(&mut b, samples.len() as u32);
                for s in samples {
                    put_u32(&mut b, s.class);
                    b.push(u8::from(s.exited));
                    b.extend_from_slice(&s.entropy.to_le_bytes());
                }
                b.extend_from_slice(&cloud_s.to_le_bytes());
            }
            Response::Error(msg) => {
                b.push(255);
                put_u32(&mut b, msg.len() as u32);
                b.extend_from_slice(msg.as_bytes());
            }
        }
        b
    }

    pub fn decode(body: &[u8]) -> Result<Response> {
        let (&kind, rest) = body.split_first().context("empty response body")?;
        match kind {
            0 => Ok(Response::Pong),
            1 => {
                if rest.len() != 8 + 4 + 1 + 4 + 8 {
                    bail!("bad RESULT length {}", rest.len());
                }
                Ok(Response::Result {
                    id: u64::from_le_bytes(rest[0..8].try_into().unwrap()),
                    class: u32::from_le_bytes(rest[8..12].try_into().unwrap()),
                    exited_early: rest[12] != 0,
                    entropy: f32::from_le_bytes(rest[13..17].try_into().unwrap()),
                    latency_s: f64::from_le_bytes(rest[17..25].try_into().unwrap()),
                })
            }
            3 => {
                if rest.len() < 4 {
                    bail!("truncated PARTIAL_RESULT header");
                }
                let n = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
                if n > MAX_PARTIAL_SAMPLES {
                    bail!("PARTIAL_RESULT sample count {n} exceeds cap");
                }
                // 9 bytes per record (u32 class | u8 exited | f32 entropy)
                // plus the trailing f64 cloud_s.
                if rest.len() != 4 + n * 9 + 8 {
                    bail!("bad PARTIAL_RESULT length {} for {n} samples", rest.len());
                }
                let mut samples = Vec::with_capacity(n);
                for r in rest[4..4 + n * 9].chunks_exact(9) {
                    let exited = match r[4] {
                        0 => false,
                        1 => true,
                        v => bail!("invalid exited flag {v}"),
                    };
                    samples.push(PartialSample {
                        class: u32::from_le_bytes(r[0..4].try_into().unwrap()),
                        exited,
                        entropy: f32::from_le_bytes(r[5..9].try_into().unwrap()),
                    });
                }
                let cloud_s =
                    f64::from_le_bytes(rest[4 + n * 9..].try_into().unwrap());
                Ok(Response::PartialResult { samples, cloud_s })
            }
            2 | 255 => {
                if rest.len() < 4 {
                    bail!("truncated string frame");
                }
                let len = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
                if rest.len() != 4 + len {
                    bail!("string frame length mismatch");
                }
                let s = String::from_utf8(rest[4..].to_vec()).context("invalid UTF-8")?;
                Ok(if kind == 2 {
                    Response::Metrics(s)
                } else {
                    Response::Error(s)
                })
            }
            k => bail!("unknown response kind {k}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(r: &Request) -> Request {
        Request::decode(&r.encode()).unwrap()
    }

    fn roundtrip_resp(r: &Response) -> Response {
        Response::decode(&r.encode()).unwrap()
    }

    #[test]
    fn request_roundtrips() {
        assert_eq!(roundtrip_req(&Request::Ping), Request::Ping);
        assert_eq!(roundtrip_req(&Request::Metrics), Request::Metrics);
        let t = HostTensor::new(vec![2, 3], vec![1., -2., 3.5, 0., 5., 6.]).unwrap();
        assert_eq!(roundtrip_req(&Request::Infer(t.clone())), Request::Infer(t));
    }

    #[test]
    fn classed_request_roundtrips() {
        let t = HostTensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        for class in [0u8, 1, 2, 255] {
            let req = Request::InferClass {
                class,
                image: t.clone(),
            };
            assert_eq!(roundtrip_req(&req), req);
        }
        // The class tag must change the wire bytes (it is not implied).
        let tagged = Request::InferClass {
            class: 2,
            image: t.clone(),
        };
        assert_ne!(tagged.encode(), Request::Infer(t).encode());
        // Truncated tag / tensor rejected.
        assert!(Request::decode(&[3]).is_err());
        assert!(Request::decode(&[3, 1, 4, 0]).is_err());
    }

    #[test]
    fn response_roundtrips() {
        assert_eq!(roundtrip_resp(&Response::Pong), Response::Pong);
        let r = Response::Result {
            id: 42,
            class: 1,
            exited_early: true,
            entropy: 0.25,
            latency_s: 0.0123,
        };
        assert_eq!(roundtrip_resp(&r), r);
        assert_eq!(
            roundtrip_resp(&Response::Metrics("{\"a\":1}".into())),
            Response::Metrics("{\"a\":1}".into())
        );
        assert_eq!(
            roundtrip_resp(&Response::Error("boom".into())),
            Response::Error("boom".into())
        );
    }

    #[test]
    fn frame_roundtrip_and_validation() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        let mut cursor = std::io::Cursor::new(buf.clone());
        assert_eq!(read_frame(&mut cursor).unwrap(), b"hello");

        // Corrupt magic:
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(read_frame(&mut std::io::Cursor::new(bad)).is_err());

        // Hostile length:
        let mut hostile = Vec::new();
        put_u32(&mut hostile, MAGIC);
        put_u32(&mut hostile, u32::MAX);
        assert!(read_frame(&mut std::io::Cursor::new(hostile)).is_err());
    }

    #[test]
    fn partial_request_roundtrips() {
        let t = HostTensor::new(vec![2, 3], vec![1., -2., 3.5, 0., 5., 6.]).unwrap();
        for (split, state) in [(0u32, BRANCH_PENDING), (3, BRANCH_GATED), (17, BRANCH_GATED)] {
            let req = Request::InferPartial {
                split,
                branch_state: state,
                activation: t.clone(),
            };
            assert_eq!(roundtrip_req(&req), req);
        }
        // The split and branch state must change the wire bytes.
        let a = Request::InferPartial {
            split: 1,
            branch_state: BRANCH_PENDING,
            activation: t.clone(),
        };
        let b = Request::InferPartial {
            split: 2,
            branch_state: BRANCH_PENDING,
            activation: t.clone(),
        };
        let c = Request::InferPartial {
            split: 1,
            branch_state: BRANCH_GATED,
            activation: t.clone(),
        };
        assert_ne!(a.encode(), b.encode());
        assert_ne!(a.encode(), c.encode());

        // Truncated header / invalid branch state / truncated tensor.
        assert!(Request::decode(&[4]).is_err());
        assert!(Request::decode(&[4, 1, 0, 0, 0]).is_err());
        assert!(Request::decode(&[4, 1, 0, 0, 0, 2, 1, 0, 0, 0]).is_err());
        let mut trunc = a.encode();
        trunc.truncate(trunc.len() - 1);
        assert!(Request::decode(&trunc).is_err());
    }

    #[test]
    fn partial_result_roundtrips() {
        let empty = Response::PartialResult {
            samples: vec![],
            cloud_s: 0.0,
        };
        assert_eq!(roundtrip_resp(&empty), empty);
        let r = Response::PartialResult {
            samples: vec![
                PartialSample {
                    class: 1,
                    exited: false,
                    entropy: 0.0,
                },
                PartialSample {
                    class: 0,
                    exited: true,
                    entropy: 0.125,
                },
            ],
            cloud_s: 0.0042,
        };
        assert_eq!(roundtrip_resp(&r), r);
    }

    #[test]
    fn partial_result_rejects_malformed_bodies() {
        // Truncated header.
        assert!(Response::decode(&[3]).is_err());
        assert!(Response::decode(&[3, 1, 0]).is_err());
        // Count/body length mismatch (claims 2 samples, carries 1).
        let one = Response::PartialResult {
            samples: vec![PartialSample {
                class: 7,
                exited: false,
                entropy: 0.5,
            }],
            cloud_s: 1.0,
        };
        let mut body = one.encode();
        body[1..5].copy_from_slice(&2u32.to_le_bytes());
        assert!(Response::decode(&body).is_err());
        // Hostile sample count: rejected before allocation.
        let mut hostile = vec![3u8];
        hostile.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Response::decode(&hostile).is_err());
        // Invalid exited flag.
        let mut bad = one.encode();
        bad[9] = 7; // kind | u32 n | u32 class | exited byte
        assert!(Response::decode(&bad).is_err());
        // Truncated tail (missing part of cloud_s).
        let mut trunc = one.encode();
        trunc.truncate(trunc.len() - 3);
        assert!(Response::decode(&trunc).is_err());
    }

    #[test]
    fn malformed_bodies_rejected() {
        assert!(Request::decode(&[]).is_err());
        assert!(Request::decode(&[9]).is_err());
        assert!(Request::decode(&[1, 1, 0, 0, 0]).is_err()); // truncated dims
        // INFER with mismatched payload:
        let mut b = vec![1u8];
        put_u32(&mut b, 1);
        put_u32(&mut b, 4); // shape [4] -> wants 16 payload bytes
        b.extend_from_slice(&[0u8; 8]);
        assert!(Request::decode(&b).is_err());
        assert!(Response::decode(&[1, 0, 0]).is_err());
    }
}
