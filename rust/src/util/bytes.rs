//! Byte-size formatting/parsing and little-endian f32 buffer I/O used by
//! the fixture loader and the tensor type.

use std::path::Path;

/// Human-readable base-2 size: 1536 -> "1.50 KiB".
pub fn format_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if n < 1024 {
        return format!("{n} B");
    }
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// Parse "64", "4KiB", "2.5 MiB", "1MB" (decimal suffixes are base-10).
pub fn parse_bytes(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s.find(|c: char| !(c.is_ascii_digit() || c == '.'))?;
    let (num, unit) = if split == 0 {
        return None;
    } else {
        s.split_at(split)
    };
    let v: f64 = num.parse().ok()?;
    let mult: f64 = match unit.trim().to_ascii_lowercase().as_str() {
        "b" | "" => 1.0,
        "kib" => 1024.0,
        "mib" => 1024.0 * 1024.0,
        "gib" => 1024.0f64.powi(3),
        "kb" => 1e3,
        "mb" => 1e6,
        "gb" => 1e9,
        _ => return None,
    };
    Some((v * mult) as u64)
}

/// Full-string integer-or-suffixed parse (handles plain "123" too).
pub fn parse_bytes_or_int(s: &str) -> Option<u64> {
    s.trim().parse::<u64>().ok().or_else(|| parse_bytes(s))
}

/// Read a raw little-endian f32 file (the Python fixture format).
pub fn read_f32_file(path: &Path) -> std::io::Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    if bytes.len() % 4 != 0 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("{} length {} not a multiple of 4", path.display(), bytes.len()),
        ));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a raw little-endian f32 file.
pub fn write_f32_file(path: &Path, data: &[f32]) -> std::io::Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_roundtrip_points() {
        assert_eq!(format_bytes(0), "0 B");
        assert_eq!(format_bytes(1023), "1023 B");
        assert_eq!(format_bytes(1536), "1.50 KiB");
        assert_eq!(format_bytes(57_600), "56.25 KiB");
    }

    #[test]
    fn parse_variants() {
        assert_eq!(parse_bytes_or_int("123"), Some(123));
        assert_eq!(parse_bytes("4KiB"), Some(4096));
        assert_eq!(parse_bytes("2.5 MiB"), Some(2_621_440));
        assert_eq!(parse_bytes("1MB"), Some(1_000_000));
        assert_eq!(parse_bytes("nope"), None);
    }

    #[test]
    fn f32_file_roundtrip() {
        let dir = std::env::temp_dir().join("branchyserve_bytes_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        let data = vec![1.5f32, -2.25, 0.0, f32::MAX];
        write_f32_file(&p, &data).unwrap();
        assert_eq!(read_f32_file(&p).unwrap(), data);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn f32_file_bad_length() {
        let dir = std::env::temp_dir().join("branchyserve_bytes_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [0u8; 7]).unwrap();
        assert!(read_f32_file(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }
}
