//! Streaming and batch statistics used by the profiler, the metrics
//! subsystem and the bench harness: mean/variance (Welford), percentiles,
//! trimmed means, confidence intervals, and a fixed-bucket latency
//! histogram cheap enough for the request hot path.

/// Online mean/variance accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Half-width of the ~95% CI of the mean (normal approximation).
    pub fn ci95_half_width(&self) -> f64 {
        if self.n < 2 {
            return f64::INFINITY;
        }
        1.96 * self.stddev() / (self.n as f64).sqrt()
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a sample by linear interpolation (type-7, numpy default).
/// `q` in [0, 100]. Sorts a copy; use for offline reporting, not hot paths.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&q));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(v: &[f64], q: f64) -> f64 {
    let n = v.len();
    if n == 1 {
        return v[0];
    }
    let pos = q / 100.0 * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    v[lo] + (v[hi] - v[lo]) * frac
}

/// Mean after dropping the `trim` fraction from each tail — the profiler's
/// defense against scheduler noise spikes.
pub fn trimmed_mean(xs: &[f64], trim: f64) -> f64 {
    assert!((0.0..0.5).contains(&trim));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let k = (v.len() as f64 * trim).floor() as usize;
    let kept = &v[k..v.len() - k];
    kept.iter().sum::<f64>() / kept.len() as f64
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Fixed-boundary log-scale histogram for latencies in seconds.
/// Buckets: [0, 1us), [1us, ~1.26us), ... decade split into 10 — cheap
/// `push` (a log10 + index) suitable for the serving hot path. Also
/// accumulates the exact sum, so `mean()` is unbounded-run accurate
/// (unlike a capped raw-sample vector) and histograms merge losslessly
/// across shards.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    underflow: u64,
    overflow: u64,
    total: u64,
    sum_s: f64,
}

const HIST_MIN: f64 = 1e-6; // 1 us
const HIST_DECADES: usize = 8; // up to 100 s
const HIST_PER_DECADE: usize = 10;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            counts: vec![0; HIST_DECADES * HIST_PER_DECADE],
            underflow: 0,
            overflow: 0,
            total: 0,
            sum_s: 0.0,
        }
    }

    #[inline]
    fn bucket_of(secs: f64) -> Option<usize> {
        if secs < HIST_MIN {
            return None;
        }
        let idx = ((secs / HIST_MIN).log10() * HIST_PER_DECADE as f64) as usize;
        Some(idx)
    }

    pub fn push(&mut self, secs: f64) {
        self.total += 1;
        self.sum_s += secs;
        match Self::bucket_of(secs) {
            None => self.underflow += 1,
            Some(i) if i >= self.counts.len() => self.overflow += 1,
            Some(i) => self.counts[i] += 1,
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn sum(&self) -> f64 {
        self.sum_s
    }

    /// Exact mean over everything ever pushed (0 when empty, never NaN).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_s / self.total as f64
        }
    }

    /// Bucket lower edge in seconds.
    fn edge(i: usize) -> f64 {
        HIST_MIN * 10f64.powf(i as f64 / HIST_PER_DECADE as f64)
    }

    /// Approximate quantile from bucket boundaries (upper edge of the
    /// bucket containing the q-th sample) — within one bucket (~26%) of
    /// truth, fine for dashboards.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = self.underflow;
        if seen >= target {
            return HIST_MIN;
        }
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::edge(i + 1);
            }
        }
        f64::INFINITY
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.overflow += other.overflow;
        self.total += other.total;
        self.sum_s += other.sum_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let m = mean(&xs);
        let var = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - m).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.variance() - all.variance()).abs() < 1e-10);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn trimmed_mean_drops_outlier() {
        let xs = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1000.0];
        assert!((trimmed_mean(&xs, 0.1) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_bracket_truth() {
        let mut h = LatencyHistogram::new();
        let mut r = crate::util::rng::Pcg32::seeded(11);
        let mut xs = Vec::new();
        for _ in 0..50_000 {
            let v = r.exponential(100.0); // mean 10ms
            h.push(v);
            xs.push(v);
        }
        for q in [0.5, 0.9, 0.99] {
            let truth = percentile(&xs, q * 100.0);
            let est = h.quantile(q);
            assert!(
                est >= truth * 0.7 && est <= truth * 1.4,
                "q={q} est={est} truth={truth}"
            );
        }
    }

    #[test]
    fn histogram_extremes() {
        let mut h = LatencyHistogram::new();
        h.push(1e-9);
        h.push(1e6);
        assert_eq!(h.count(), 2);
        assert_eq!(h.quantile(0.0), HIST_MIN);
        assert_eq!(h.quantile(1.0), f64::INFINITY);
    }

    #[test]
    fn histogram_mean_and_merge_are_exact() {
        let mut a = LatencyHistogram::new();
        assert_eq!(a.mean(), 0.0); // empty: zero, not NaN
        for v in [0.010, 0.020, 0.030] {
            a.push(v);
        }
        assert!((a.mean() - 0.020).abs() < 1e-15);
        let mut b = LatencyHistogram::new();
        b.push(0.040);
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert!((a.sum() - 0.100).abs() < 1e-15);
        assert!((a.mean() - 0.025).abs() < 1e-15);
    }
}
