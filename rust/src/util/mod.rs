//! Small self-contained utilities: deterministic PRNG, statistics,
//! logging, and byte/time formatting.
//!
//! These exist because the build environment is fully offline (DESIGN.md
//! §3): `rand`, `env_logger` etc. are unavailable, so the substrates are
//! implemented here and tested like everything else.

pub mod bytes;
pub mod logger;
pub mod rng;
pub mod stats;
pub mod timefmt;
