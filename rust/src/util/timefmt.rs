//! Duration formatting for reports: pick the natural unit.

use std::time::Duration;

/// "1.234 ms", "56.7 us", "2.3 s" — three significant-ish digits.
pub fn format_secs(secs: f64) -> String {
    if !secs.is_finite() {
        return format!("{secs}");
    }
    let abs = secs.abs();
    if abs >= 1.0 {
        format!("{secs:.3} s")
    } else if abs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if abs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

pub fn format_duration(d: Duration) -> String {
    format_secs(d.as_secs_f64())
}

/// Throughput: "12.3 req/s" style with unit scaling.
pub fn format_rate(per_sec: f64) -> String {
    if per_sec >= 1e6 {
        format!("{:.2} M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} k/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.2} /s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn units() {
        assert_eq!(format_secs(2.5), "2.500 s");
        assert_eq!(format_secs(0.0125), "12.500 ms");
        assert_eq!(format_secs(42e-6), "42.000 us");
        assert_eq!(format_secs(3e-9), "3.0 ns");
    }

    #[test]
    fn rates() {
        assert_eq!(format_rate(12.3), "12.30 /s");
        assert_eq!(format_rate(4_200.0), "4.20 k/s");
        assert_eq!(format_rate(2_000_000.0), "2.00 M/s");
    }

    #[test]
    fn non_finite() {
        assert_eq!(format_secs(f64::INFINITY), "inf");
    }
}
