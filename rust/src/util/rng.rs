//! Deterministic pseudo-random generation: SplitMix64 seeding + PCG32
//! core, with the distribution samplers the workload generator and the
//! property-test framework need (uniform, exponential, normal, Poisson).
//!
//! PCG32 (O'Neill 2014, `PCG-XSH-RR 64/32`) is small, fast, and passes
//! BigCrush — more than enough statistical quality for load generation
//! and property-test case generation.

/// SplitMix64: used to expand a user seed into PCG streams.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32 generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub const DEFAULT_STREAM: u64 = 0xDA3E_39CB_94B9_5BDB;

    /// Seed deterministically; distinct `stream` values give independent
    /// sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let init = splitmix64(&mut sm);
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(init);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, Self::DEFAULT_STREAM)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut m = (self.next_u32() as u64).wrapping_mul(n as u64);
        let mut lo = m as u32;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = (self.next_u32() as u64).wrapping_mul(n as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        if span == u64::MAX {
            return self.next_u64();
        }
        // Use 32-bit path when possible, otherwise rejection over u64.
        if span < u32::MAX as u64 {
            lo + self.below(span as u32 + 1) as u64
        } else {
            loop {
                let v = self.next_u64();
                if let Some(r) = span.checked_add(1) {
                    return lo + v % r;
                }
                return lo + v;
            }
        }
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate lambda (mean 1/lambda) — Poisson-process
    /// inter-arrival times for the open-loop load generator.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let mut u = self.f64();
        if u <= f64::MIN_POSITIVE {
            u = f64::MIN_POSITIVE;
        }
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
        mean + std * z
    }

    /// Poisson(lambda) via Knuth for small lambda, normal approx above 30.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let v = self.normal(lambda, lambda.sqrt()).round();
            return if v < 0.0 { 0 } else { v as u64 };
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.below(xs.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg32::new(7, 1);
        let mut b = Pcg32::new(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Pcg32::seeded(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Pcg32::seeded(3);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[r.below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 500, "{counts:?}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg32::seeded(4);
        let lambda = 5.0;
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn poisson_mean_small_and_large_lambda() {
        let mut r = Pcg32::seeded(6);
        for lambda in [0.5, 4.0, 80.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < 0.05 * lambda.max(1.0),
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(8);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_u64_bounds() {
        let mut r = Pcg32::seeded(9);
        for _ in 0..10_000 {
            let v = r.range_u64(10, 20);
            assert!((10..=20).contains(&v));
        }
    }
}
