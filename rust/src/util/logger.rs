//! Minimal `log`-facade backend (env_logger is unavailable offline).
//!
//! Level comes from `BRANCHYSERVE_LOG` (error|warn|info|debug|trace),
//! defaulting to `info`. Output goes to stderr with elapsed-time stamps so
//! serving traces are easy to correlate with bench output.

use std::io::Write;
use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static START: OnceLock<Instant> = OnceLock::new();
static LOGGER: Logger = Logger;

struct Logger;

impl log::Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get_or_init(Instant::now).elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{:>9.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). Called by `main` and test setups.
pub fn init() {
    let level = std::env::var("BRANCHYSERVE_LOG")
        .ok()
        .and_then(|s| parse_level(&s))
        .unwrap_or(LevelFilter::Info);
    START.get_or_init(Instant::now);
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

fn parse_level(s: &str) -> Option<LevelFilter> {
    match s.to_ascii_lowercase().as_str() {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("info"), Some(LevelFilter::Info));
        assert_eq!(parse_level("TRACE"), Some(LevelFilter::Trace));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        log::info!("logger smoke line");
    }
}
