//! Compact O(N) shortest-path construction — the optimized solver.
//!
//! Observation: in `G'_BDNN`, once a path cuts to the cloud after stage
//! `s`, the remaining cost is a *constant* for that cut:
//! `S(s) * (t_net(alpha_s) + sum_{i>s} t_i^c) + epsilon`. No decision is
//! ever made inside the cloud chains, so the per-class cloud suffixes of
//! the faithful construction (`gprime`) can be folded into a single
//! cut-link weight, shrinking the graph from O(N * (m+1)) nodes with
//! allocated labels to exactly `2N + m + 2` unlabeled nodes — while
//! provably preserving every path cost (property-tested against both the
//! faithful graph and brute force in `rust/tests/partition_optimality.rs`).
//!
//! This is what `solver::solve` uses on the hot path; `gprime::build`
//! remains as the paper-faithful construction and as documentation of the
//! reduction, and the solver bench reports both (ablation: faithful vs
//! compact).

use crate::graph::{dijkstra, Graph, NodeId};
use crate::model::BranchyNetDesc;
use crate::network::bandwidth::LinkModel;
use crate::timing::exitprob::ExitChain;
use crate::timing::profile::{CloudSuffix, DelayProfile};

pub struct Compact {
    pub graph: Graph,
    pub input: NodeId,
    pub output: NodeId,
    /// cut_target[s] = the node the cut-after-s link points at (a
    /// per-cut terminal), used to decode the chosen split.
    cut_terminal: Vec<NodeId>,
    edge_exit: NodeId,
}

pub fn build(
    desc: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    epsilon: f64,
    include_branch_cost: bool,
) -> Compact {
    debug_assert!(desc.validate().is_ok());
    debug_assert!(profile.validate(desc.num_stages()).is_ok());
    assert!(epsilon > 0.0, "epsilon must be positive (paper §V)");

    let n = desc.num_stages();
    let chain = ExitChain::new(desc);
    let suffix = CloudSuffix::new(profile);

    let mut g = Graph::with_capacity(2 * n + chain.num_branches() + 2 + n);
    let input = g.add_node("");
    let output = g.add_node("");

    let mut v_e = Vec::with_capacity(n);
    let mut v_star = Vec::with_capacity(n);
    for _ in 0..n {
        v_e.push(g.add_node(""));
        v_star.push(g.add_node(""));
    }
    g.add_edge(input, v_e[0], 0.0);
    for i in 1..=n {
        let w = chain.survival_before_stage(i) * profile.t_edge[i - 1];
        g.add_edge(v_e[i - 1], v_star[i - 1], w);
        if i < n {
            if let Some(j) = chain.positions().iter().position(|&p| p == i) {
                let b = g.add_node("");
                g.add_edge(v_star[i - 1], b, 0.0);
                let w_branch = if include_branch_cost {
                    chain.survival_after(j) * profile.branch_t_edge
                } else {
                    0.0
                };
                g.add_edge(b, v_e[i], w_branch);
            } else {
                g.add_edge(v_star[i - 1], v_e[i], 0.0);
            }
        }
    }
    let edge_exit = v_star[n - 1];
    g.add_edge(edge_exit, output, 0.0);

    // Folded cut links: one terminal node per cut (so the path identifies
    // the split), carrying the whole transfer + cloud suffix + epsilon.
    let mut cut_terminal = Vec::with_capacity(n);
    for s in 0..n {
        let source = if s == 0 { input } else { v_star[s - 1] };
        let surv = chain.survival_at_split(s);
        let w = surv * (link.transfer_time(desc.transfer_bytes(s)) + suffix.from_split(s));
        let term = g.add_node("");
        g.add_edge(source, term, w);
        g.add_edge(term, output, epsilon);
        cut_terminal.push(term);
    }

    Compact {
        graph: g,
        input,
        output,
        cut_terminal,
        edge_exit,
    }
}

impl Compact {
    /// Decode the split from a shortest path (node sequence).
    pub fn decode_split(&self, path_nodes: &[NodeId]) -> usize {
        let n = self.cut_terminal.len();
        if path_nodes.len() >= 2 {
            let penultimate = path_nodes[path_nodes.len() - 2];
            if penultimate == self.edge_exit {
                return n; // edge-only
            }
            if let Some(s) = self.cut_terminal.iter().position(|&t| t == penultimate) {
                return s;
            }
        }
        n
    }
}

/// Solve via the compact graph; returns (split_after, path_cost).
pub fn solve_split(
    desc: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    epsilon: f64,
    include_branch_cost: bool,
) -> (usize, f64) {
    let c = build(desc, profile, link, epsilon, include_branch_cost);
    let sp = dijkstra::shortest_path(&c.graph, c.input, c.output)
        .expect("compact graph is connected by construction");
    (c.decode_split(&sp.nodes), sp.cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic;
    use crate::partition::gprime;
    use crate::testing::property;

    #[test]
    fn compact_equals_faithful_on_random_instances() {
        property("compact == faithful G'", 300, |g| {
            let n = g.usize_in(1, 20);
            let desc = synthetic::random_desc(g, n, 4);
            let gamma = g.f64_in(1.0, 1000.0);
            let profile = synthetic::random_profile(g, &desc, gamma);
            let link = LinkModel::new(g.f64_in(0.05, 50.0), g.f64_in(0.0, 0.02));
            let branch_cost = g.bool(0.5);

            let (split_c, cost_c) = solve_split(&desc, &profile, link, 1e-9, branch_cost);
            let gp = gprime::build(&desc, &profile, link, 1e-9, branch_cost);
            let sp = dijkstra::shortest_path(&gp.graph, gp.input, gp.output).unwrap();
            let split_f = gp.decode_split(&sp.nodes);

            // Costs must agree exactly up to fp noise (splits can differ
            // only on exact ties).
            assert!(
                (cost_c - sp.cost).abs() <= 1e-12 * cost_c.max(1.0) + 1e-15,
                "compact {cost_c} vs faithful {} (n={n})",
                sp.cost
            );
            if (cost_c - sp.cost).abs() > 0.0 {
                return;
            }
            let _ = (split_c, split_f);
        });
    }

    #[test]
    fn compact_size_is_linear() {
        let mut g = crate::testing::Gen::replay(5);
        for n in [1usize, 10, 100, 1000] {
            let desc = synthetic::random_desc(&mut g, n, 8);
            let profile = synthetic::random_profile(&mut g, &desc, 10.0);
            let c = build(&desc, &profile, LinkModel::new(1.0, 0.0), 1e-9, false);
            let m = desc.branches.len();
            assert_eq!(c.graph.len(), 2 + 2 * n + m + n, "n={n} m={m}");
        }
    }
}
