//! Construction of `G'_BDNN` (paper §V, Figure 3, Eqs. 7–8): the weighted
//! DAG whose shortest `input -> output` path encodes the optimal split.
//!
//! Structure (for N stages, following the paper's Figure 3):
//!
//! ```text
//! input ─0─► v1e ─t1e─► v1*e ─···─► vNe ─tNe─► vN*e ─0─► output
//!   │                    │ \___ b_k nodes sit between v_k*e and v_{k+1}e
//!   │                    │      when a branch follows stage k
//!   │                    └──t_net(alpha_k)──► v_{k+1}^c(class) ─► ... ─► v*c ─ε─► output
//!   └──t_net(alpha_0)──► v1^c(0) ─t1c─► v2^c(0) ─► ... (cloud-only)
//! ```
//!
//! * every edge vertex `v_i^e` gets an auxiliary `v_i^{*e}` so the cut
//!   can leave *after* stage i's compute but *before* branch b_i — this
//!   encodes the paper's rule that a branch exactly at the cut is
//!   discarded (B = {b_1..b_{s-1}});
//! * Eq. 8's probability weighting: every link weight is scaled by the
//!   survival probability at that point in the chain, i.e. the product of
//!   `(1 - p_k)` over branches already crossed. (The paper states this
//!   for its single-branch example as "weights after the side branch are
//!   weighted by the probability"; survival scaling is the general form
//!   that makes path cost == Eq. 5's expectation.)
//! * **cloud chain classes**: the expectation multiplies transfer *and
//!   all cloud work* by the survival at the cut, so cloud chains entered
//!   after crossing j branches need weights scaled by S_j. A single
//!   shared cloud chain (as drawn in the paper's 3-node example) cannot
//!   carry two scalings at once, so we instantiate one cloud-suffix chain
//!   per survival class — O(N * (m+1)) nodes for m branches, still
//!   trivially polynomial. For the paper's single-branch B-AlexNet this
//!   is exactly two chains: pre-branch (unscaled) and post-branch
//!   (scaled by 1-p), which is what Eq. 8 describes.
//! * the `epsilon` link before `output` on each cloud exit reproduces the
//!   paper's tie-breaker: when survival hits 0 (p = 1), all post-branch
//!   weights vanish and epsilon makes the shortest path prefer staying on
//!   the edge rather than a spurious zero-cost cloud hop.

use crate::graph::{Graph, NodeId};
use crate::model::BranchyNetDesc;
use crate::network::bandwidth::LinkModel;
use crate::timing::exitprob::ExitChain;
use crate::timing::profile::DelayProfile;

/// The constructed graph plus the bookkeeping needed to decode a shortest
/// path back into a split point.
#[derive(Debug)]
pub struct GPrime {
    pub graph: Graph,
    pub input: NodeId,
    pub output: NodeId,
    /// cut_links[s] = the node the cut-after-stage-s transfer link leaves
    /// from (v_s^{*e}, or `input` for s = 0). Used to decode paths.
    cut_sources: Vec<NodeId>,
    /// edge_exit = v_N^{*e} (the edge-only terminal hop source).
    edge_exit: NodeId,
}

/// Build `G'_BDNN`. `include_branch_cost` mirrors the estimator's mode:
/// when true, branch vertices carry the branch evaluation time on their
/// outgoing link; when false (paper mode) they are zero-cost.
pub fn build(
    desc: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    epsilon: f64,
    include_branch_cost: bool,
) -> GPrime {
    desc.validate().expect("invalid desc");
    profile
        .validate(desc.num_stages())
        .expect("profile mismatch");
    assert!(epsilon > 0.0, "epsilon must be positive (paper §V)");

    let n = desc.num_stages();
    let chain = ExitChain::new(desc);
    let m = chain.num_branches();

    let mut g = Graph::with_capacity(2 * n + m + 2 + (m + 1) * n);
    let input = g.add_node("input");
    let output = g.add_node("output");

    // ---- edge chain: v_i^e and v_i^{*e}, with b_k between v_k^{*e} and
    // v_{k+1}^e where a branch exists.
    let mut v_e = Vec::with_capacity(n);
    let mut v_star = Vec::with_capacity(n);
    for i in 1..=n {
        v_e.push(g.add_node(format!("v{i}e")));
        v_star.push(g.add_node(format!("v{i}*e")));
    }
    // input -> v1e: zero weight (edge-only entry, Eq. 7 last case analog).
    g.add_edge(input, v_e[0], 0.0);
    for i in 1..=n {
        // v_i^e -> v_i^{*e}: the compute cost of stage i on the edge,
        // survival-weighted (Eq. 7 first case x Eq. 8).
        let w = chain.survival_before_stage(i) * profile.t_edge[i - 1];
        g.add_edge(v_e[i - 1], v_star[i - 1], w);
        if i < n {
            // Continue on the edge: through b_i if a branch follows stage i.
            if let Some(j) = chain.positions().iter().position(|&p| p == i) {
                let b = g.add_node(format!("b{i}"));
                g.add_edge(v_star[i - 1], b, 0.0);
                let w_branch = if include_branch_cost {
                    chain.survival_after(j) * profile.branch_t_edge
                } else {
                    0.0
                };
                g.add_edge(b, v_e[i], w_branch);
            } else {
                g.add_edge(v_star[i - 1], v_e[i], 0.0);
            }
        }
    }
    // Edge-only exit: v_N^{*e} -> output, free.
    let edge_exit = v_star[n - 1];
    g.add_edge(edge_exit, output, 0.0);

    // ---- cloud chains, one per survival class. Class j covers cuts s
    // with `active_branches(s) == j`; its chain holds stages entered at
    // s+1 for the smallest such s, but suffix sharing within a class is
    // safe because the scaling factor is constant. We lazily create class
    // chains from their earliest entry stage.
    //
    // cut s enters the cloud at stage s+1 with class j = active_branches(s).
    let mut class_nodes: Vec<Vec<Option<NodeId>>> = vec![vec![None; n + 2]; m + 1];
    let mut class_exit: Vec<Option<NodeId>> = vec![None; m + 1];
    let mut cut_sources = Vec::with_capacity(n + 1);

    // Helper to materialize cloud chain of class `j` from stage `from`
    // (1-based) to the output, returning the entry node.
    let ensure_cloud_suffix = |g: &mut Graph,
                                   class_nodes: &mut Vec<Vec<Option<NodeId>>>,
                                   class_exit: &mut Vec<Option<NodeId>>,
                                   j: usize,
                                   from: usize|
     -> NodeId {
        debug_assert!(from >= 1 && from <= n + 1);
        let surv = chain.survival_after(j);
        // Terminal v*c for this class.
        if class_exit[j].is_none() {
            let exit = g.add_node(format!("v*c({j})"));
            // The epsilon tie-breaker link (Eq. 7 fourth case).
            g.add_edge(exit, output, epsilon);
            class_exit[j] = Some(exit);
        }
        let exit = class_exit[j].unwrap();
        // Build the suffix backwards from the output, reusing any nodes a
        // later cut already materialized (suffix sharing within a class
        // is safe: the scaling factor is constant per class).
        let mut next: NodeId = exit;
        for i in (from..=n).rev() {
            if let Some(node) = class_nodes[j][i] {
                next = node;
                continue;
            }
            let node = g.add_node(format!("v{i}c({j})"));
            // v_i^c -> next: the compute cost of stage i in the cloud,
            // scaled by this class's survival (Eq. 7 second case x Eq. 8).
            g.add_edge(node, next, surv * profile.t_cloud[i - 1]);
            class_nodes[j][i] = Some(node);
            next = node;
        }
        if from == n + 1 {
            exit
        } else {
            class_nodes[j][from].unwrap()
        }
    };

    for s in 0..=n {
        let source = if s == 0 { input } else { v_star[s - 1] };
        cut_sources.push(source);
        if s == n {
            continue; // edge-only has no transfer link
        }
        let j = chain.active_branches(s);
        let surv = chain.survival_after(j);
        let entry = ensure_cloud_suffix(&mut g, &mut class_nodes, &mut class_exit, j, s + 1);
        // Transfer link (Eq. 7 third case x Eq. 8): alpha_s / B, scaled.
        let w = surv * link.transfer_time(desc.transfer_bytes(s));
        g.add_edge(source, entry, w);
    }

    GPrime {
        graph: g,
        input,
        output,
        cut_sources,
        edge_exit,
    }
}

impl GPrime {
    /// Decode a shortest path (node sequence) into the split point it
    /// represents: the last `v_s^{*e}` (or `input`) from which the path
    /// leaves the edge chain — or N if it exits via the edge-only hop.
    pub fn decode_split(&self, path_nodes: &[NodeId]) -> usize {
        let n = self.cut_sources.len() - 1;
        // Edge-only: path ends output directly after v_N^{*e}.
        if path_nodes.len() >= 2 {
            let last_hop_src = path_nodes[path_nodes.len() - 2];
            if last_hop_src == self.edge_exit {
                return n;
            }
        }
        // Otherwise: find the cut — the unique adjacent pair
        // (cut_sources[s], non-edge node).
        for s in (0..=n).rev() {
            let src = self.cut_sources[s];
            if let Some(pos) = path_nodes.iter().position(|&x| x == src) {
                // Is the next node a cloud node (i.e. not the edge chain)?
                if pos + 1 < path_nodes.len() {
                    let label = self.graph.label(path_nodes[pos + 1]);
                    if label.contains('c') || label == "output" && s == n {
                        return s;
                    }
                }
            }
        }
        // input -> v1c(0) ... (cloud-only) is covered by s = 0 above;
        // reaching here means the path never left the edge chain.
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dijkstra;
    use crate::model::{BranchDesc, BranchyNetDesc};
    use crate::timing::Estimator;

    fn desc(p: f64) -> BranchyNetDesc {
        BranchyNetDesc {
            stage_names: vec!["v1".into(), "v2".into(), "v3".into()],
            stage_out_bytes: vec![1000, 500, 8],
            input_bytes: 800,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: p,
            }],
        }
    }

    fn profile() -> DelayProfile {
        DelayProfile::from_cloud_times(vec![1e-3, 2e-3, 3e-3], 4e-4, 10.0)
    }

    const EPS: f64 = 1e-9;

    #[test]
    fn graph_is_a_dag_with_expected_size() {
        let d = desc(0.5);
        let p = profile();
        let gp = build(&d, &p, LinkModel::new(8.0, 0.0), EPS, false);
        assert!(gp.graph.is_dag());
        // input, output, 3x(v_e, v*e), 1 branch, cloud class 0 (stages
        // 1..3 + exit) and class 1 (stages 3..3 + exit) = 2+6+1+4+2 = 15.
        assert_eq!(gp.graph.len(), 15);
    }

    #[test]
    fn path_costs_match_estimator_for_every_split() {
        // The fundamental equivalence: for each split s, the cost of the
        // corresponding path in G' equals E[T(s)] (+epsilon if via cloud).
        for p in [0.0, 0.3, 0.7, 1.0] {
            let d = desc(p);
            let prof = profile();
            let link = LinkModel::new(8.0, 0.0);
            let est = Estimator::new(&d, &prof, link).paper_mode();
            let gp = build(&d, &prof, link, EPS, false);
            let sp = dijkstra::shortest_path(&gp.graph, gp.input, gp.output).unwrap();
            let split = gp.decode_split(&sp.nodes);
            let want = est.expected_time(split);
            let slack = if split == d.num_stages() { 0.0 } else { EPS };
            assert!(
                (sp.cost - want - slack).abs() < 1e-12,
                "p={p} split={split}: path {} vs estimator {want}",
                sp.cost
            );
            // And the path must be optimal wrt the estimator:
            let best = (0..=3)
                .map(|s| est.expected_time(s))
                .fold(f64::INFINITY, f64::min);
            assert!(
                sp.cost <= best + EPS + 1e-12,
                "p={p}: shortest path {} worse than best split {best}",
                sp.cost
            );
        }
    }

    #[test]
    fn p_one_prefers_edge_via_epsilon() {
        // With p = 1 everything after b1 is free, so the edge path
        // (cost t1_e) ties with a cut at s = 2 (cost t1_e + 0 transfer +
        // 0 cloud). The epsilon tie-breaker must keep the path on the
        // edge chain (paper §V). Use a slow network so cloud-only does
        // not win outright.
        let d = desc(1.0);
        let prof = profile();
        let gp = build(&d, &prof, LinkModel::new(0.01, 0.0), EPS, false);
        let sp = dijkstra::shortest_path(&gp.graph, gp.input, gp.output).unwrap();
        let split = gp.decode_split(&sp.nodes);
        assert_eq!(split, 3, "epsilon must break the tie toward edge-only");
        assert!((sp.cost - prof.t_edge[0]).abs() < 1e-12);
    }

    #[test]
    fn branch_cost_included_when_asked() {
        let d = desc(0.5);
        let prof = profile();
        let link = LinkModel::new(8.0, 0.0);
        let with = build(&d, &prof, link, EPS, true);
        let without = build(&d, &prof, link, EPS, false);
        let c_with = dijkstra::shortest_path(&with.graph, with.input, with.output)
            .unwrap()
            .cost;
        let c_without = dijkstra::shortest_path(&without.graph, without.input, without.output)
            .unwrap()
            .cost;
        assert!(c_with >= c_without);
    }

    #[test]
    fn no_branches_degenerates_to_plain_dnn_graph() {
        let d = BranchyNetDesc {
            stage_names: vec!["a".into(), "b".into()],
            stage_out_bytes: vec![100, 10],
            input_bytes: 50,
            branches: vec![],
        };
        let prof = DelayProfile::from_cloud_times(vec![1e-3, 1e-3], 0.0, 5.0);
        let link = LinkModel::new(1.0, 0.0);
        let gp = build(&d, &prof, link, EPS, false);
        let est = Estimator::new(&d, &prof, link).paper_mode();
        let sp = dijkstra::shortest_path(&gp.graph, gp.input, gp.output).unwrap();
        let split = gp.decode_split(&sp.nodes);
        let slack = if split == 2 { 0.0 } else { EPS };
        assert!((sp.cost - est.expected_time(split) - slack).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "epsilon")]
    fn zero_epsilon_rejected() {
        let d = desc(0.5);
        let p = profile();
        build(&d, &p, LinkModel::new(8.0, 0.0), 0.0, false);
    }
}
