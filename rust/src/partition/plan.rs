//! The partition plan: which stages run where, what gets transferred,
//! and what the model predicts it costs.

use crate::config::settings::Strategy;
use crate::model::BranchyNetDesc;
use crate::network::encoding::WireEncoding;

#[derive(Debug, Clone, PartialEq)]
pub struct PartitionPlan {
    /// Split point: stages 1..=split_after run on the edge, the rest in
    /// the cloud. 0 = cloud-only, N = edge-only.
    pub split_after: usize,
    /// Predicted `E[T_inf]` in seconds (the quantity that was minimized).
    pub expected_time_s: f64,
    /// Strategy that produced this plan.
    pub strategy: Strategy,
    /// 1-based positions of side branches that are *active* (on the edge
    /// side of the cut and before it — paper §IV-B).
    pub active_branches: Vec<usize>,
    /// *Raw* activation bytes at the cut when no early exit happens —
    /// a property of the model alone, independent of the transfer codec.
    pub transfer_bytes: u64,
    /// Bytes the deployment actually puts on the wire per transferred
    /// sample: `transfer_bytes` pushed through the solver's wire
    /// encoding — the size the expected time was *minimized against*.
    /// Equal to `transfer_bytes` for raw-f32 transfers.
    pub wire_bytes: u64,
}

impl PartitionPlan {
    pub fn from_split(
        split_after: usize,
        expected_time_s: f64,
        strategy: Strategy,
        desc: &BranchyNetDesc,
    ) -> PartitionPlan {
        PartitionPlan::from_split_encoded(
            split_after,
            expected_time_s,
            strategy,
            desc,
            WireEncoding::Raw,
        )
    }

    /// [`PartitionPlan::from_split`] for a solver that priced transfers
    /// under `encoding`: `wire_bytes` reports the encoded size at the
    /// cut, so the plan summary states the quantity the solver actually
    /// minimized (under `Raw` the two byte fields coincide).
    pub fn from_split_encoded(
        split_after: usize,
        expected_time_s: f64,
        strategy: Strategy,
        desc: &BranchyNetDesc,
        encoding: WireEncoding,
    ) -> PartitionPlan {
        let n = desc.num_stages();
        assert!(split_after <= n);
        let (transfer_bytes, wire_bytes) = if split_after == n {
            (0, 0)
        } else {
            (
                desc.transfer_bytes(split_after),
                desc.transfer_wire_bytes(split_after, encoding),
            )
        };
        PartitionPlan {
            split_after,
            expected_time_s,
            strategy,
            active_branches: desc
                .branches
                .iter()
                .filter(|b| b.after_stage < split_after)
                .map(|b| b.after_stage)
                .collect(),
            transfer_bytes,
            wire_bytes,
        }
    }

    pub fn is_cloud_only(&self) -> bool {
        self.split_after == 0
    }

    pub fn is_edge_only(&self, num_stages: usize) -> bool {
        self.split_after == num_stages
    }

    /// Human-readable split-point name: "input" (cloud-only) or a stage
    /// name — matches the paper's Fig. 5 x-axis labels.
    pub fn split_label(&self, desc: &BranchyNetDesc) -> String {
        if self.split_after == 0 {
            "input".to_string()
        } else {
            desc.stage_names[self.split_after - 1].clone()
        }
    }

    /// Sets V_e and V_c as (stage index) vectors — the paper's partition
    /// sets, for reporting. V_e includes active branch markers "b@k".
    pub fn partition_sets(&self, desc: &BranchyNetDesc) -> (Vec<String>, Vec<String>) {
        let mut v_e = Vec::new();
        for i in 1..=self.split_after {
            v_e.push(desc.stage_names[i - 1].clone());
            if self.active_branches.contains(&i) {
                v_e.push(format!("b@{i}"));
            }
        }
        let v_c = desc.stage_names[self.split_after..].to_vec();
        (v_e, v_c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BranchDesc, BranchyNetDesc};

    fn desc() -> BranchyNetDesc {
        BranchyNetDesc {
            stage_names: vec!["conv1".into(), "conv2".into(), "fc".into()],
            stage_out_bytes: vec![100, 50, 8],
            input_bytes: 80,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: 0.5,
            }],
        }
    }

    #[test]
    fn active_branch_rule() {
        let d = desc();
        // split 1: branch at 1 is NOT active (needs position < split).
        let p = PartitionPlan::from_split(1, 0.1, Strategy::ShortestPath, &d);
        assert!(p.active_branches.is_empty());
        // split 2: active.
        let p = PartitionPlan::from_split(2, 0.1, Strategy::ShortestPath, &d);
        assert_eq!(p.active_branches, vec![1]);
    }

    #[test]
    fn transfer_bytes_and_labels() {
        let d = desc();
        let p0 = PartitionPlan::from_split(0, 0.1, Strategy::CloudOnly, &d);
        assert_eq!(p0.transfer_bytes, 80);
        assert_eq!(p0.wire_bytes, 80, "raw: wire == transfer");
        assert_eq!(p0.split_label(&d), "input");
        assert!(p0.is_cloud_only());

        let p3 = PartitionPlan::from_split(3, 0.1, Strategy::EdgeOnly, &d);
        assert_eq!(p3.transfer_bytes, 0);
        assert_eq!(p3.wire_bytes, 0);
        assert_eq!(p3.split_label(&d), "fc");
        assert!(p3.is_edge_only(3));
    }

    #[test]
    fn wire_bytes_follow_the_encoding_not_the_raw_size() {
        // The drift this pins against: a quantized solver must not
        // summarize its plan with raw f32 sizes — `wire_bytes` reports
        // what the codec ships, `transfer_bytes` stays the raw model
        // property.
        let d = desc();
        for s in 0..3 {
            for enc in WireEncoding::ALL {
                let p = PartitionPlan::from_split_encoded(s, 0.1, Strategy::ShortestPath, &d, enc);
                assert_eq!(p.transfer_bytes, d.transfer_bytes(s), "split {s} {enc:?}");
                assert_eq!(
                    p.wire_bytes,
                    d.transfer_wire_bytes(s, enc),
                    "split {s} {enc:?}"
                );
            }
            // Raw is the identity between the two fields.
            let raw = PartitionPlan::from_split(s, 0.1, Strategy::ShortestPath, &d);
            assert_eq!(raw.wire_bytes, raw.transfer_bytes, "split {s}");
        }
        // Interior cut under q8: an actual strict shrink (100 f32-ish
        // bytes -> header + 1-byte codes), so the two fields genuinely
        // diverge and the test can't pass vacuously.
        let q8 = PartitionPlan::from_split_encoded(1, 0.1, Strategy::ShortestPath, &d, WireEncoding::Q8);
        assert!(
            q8.wire_bytes < q8.transfer_bytes,
            "q8 must shrink the wire: {} vs {}",
            q8.wire_bytes,
            q8.transfer_bytes
        );
    }

    #[test]
    fn partition_sets_disjoint_and_complete() {
        let d = desc();
        for s in 0..=3 {
            let p = PartitionPlan::from_split(s, 0.0, Strategy::BruteForce, &d);
            let (v_e, v_c) = p.partition_sets(&d);
            let stages_e: Vec<&String> = v_e.iter().filter(|n| !n.starts_with("b@")).collect();
            assert_eq!(stages_e.len() + v_c.len(), 3, "split {s}");
            for n in &stages_e {
                assert!(!v_c.contains(n), "stage {n} in both sets");
            }
        }
    }
}
