//! Baseline partitioning strategies from the paper's related work (§II):
//!
//! * **Neurosurgeon** [3] — partitions a *plain* DNN: it has no notion of
//!   side branches, so it plans with p = 0 (Eq. 3) even when the deployed
//!   network is a BranchyNet. The gap between its plan and the paper's
//!   solver quantifies the value of modeling exit probability.
//! * **edge-only / cloud-only** — the static strategies of Fig. 2(a)/(b).

use crate::config::settings::Strategy;
use crate::model::BranchyNetDesc;
use crate::network::bandwidth::LinkModel;
use crate::timing::{DelayProfile, Estimator};

use super::plan::PartitionPlan;

/// Branch-blind planning: choose the split minimizing the *plain-DNN*
/// time (Eq. 3), then report the *actual* expected time of that split on
/// the real BranchyNet (what a Neurosurgeon deployment would experience).
pub fn neurosurgeon(
    desc: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    paper_mode: bool,
) -> PartitionPlan {
    let est = Estimator::new(desc, profile, link);
    let est = if paper_mode { est.paper_mode() } else { est };

    let mut best_split = 0usize;
    let mut best_plain = f64::INFINITY;
    for s in 0..est.num_splits() {
        let t = est.plain_dnn_time(s);
        if t < best_plain || (t == best_plain && s > best_split) {
            best_plain = t;
            best_split = s;
        }
    }
    let actual = est.expected_time(best_split);
    let mut plan = PartitionPlan::from_split(best_split, actual, Strategy::Neurosurgeon, desc);
    plan.strategy = Strategy::Neurosurgeon;
    plan
}

/// Static strategy at a fixed split (0 = cloud-only, N = edge-only),
/// costed with the full expectation model.
pub fn static_split(est: &Estimator<'_>, split: usize, strategy: Strategy) -> PartitionPlan {
    PartitionPlan::from_split(split, est.expected_time(split), strategy, est.desc())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::BranchDesc;
    use crate::partition::brute;

    fn fixture(p: f64) -> (BranchyNetDesc, DelayProfile) {
        let desc = BranchyNetDesc {
            stage_names: (1..=4).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![50_000, 20_000, 4_000, 8],
            input_bytes: 12_288,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: p,
            }],
        };
        let profile =
            DelayProfile::from_cloud_times(vec![1e-3, 2e-3, 1e-3, 5e-4], 2e-4, 50.0);
        (desc, profile)
    }

    #[test]
    fn neurosurgeon_ignores_probability() {
        // Its chosen split must be identical for p = 0 and p = 0.9.
        let link = LinkModel::new(5.85, 0.0);
        let (d0, prof) = fixture(0.0);
        let (d9, _) = fixture(0.9);
        let n0 = neurosurgeon(&d0, &prof, link, true);
        let n9 = neurosurgeon(&d9, &prof, link, true);
        assert_eq!(n0.split_after, n9.split_after);
    }

    #[test]
    fn neurosurgeon_never_beats_the_solver() {
        // The paper's solver optimizes the true objective; Neurosurgeon
        // optimizes a surrogate. On the true objective it can only tie or
        // lose.
        for p in [0.0, 0.3, 0.6, 0.9, 1.0] {
            for mbps in [1.10, 5.85, 18.80] {
                let (desc, profile) = fixture(p);
                let link = LinkModel::new(mbps, 0.0);
                let est = Estimator::new(&desc, &profile, link).paper_mode();
                let opt = brute::solve(&est);
                let ns = neurosurgeon(&desc, &profile, link, true);
                assert!(
                    opt.expected_time_s <= ns.expected_time_s + 1e-12,
                    "p={p} mbps={mbps}: solver {} > neurosurgeon {}",
                    opt.expected_time_s,
                    ns.expected_time_s
                );
            }
        }
    }

    #[test]
    fn neurosurgeon_equals_solver_when_p_zero() {
        let (desc, profile) = fixture(0.0);
        let link = LinkModel::new(5.85, 0.0);
        let est = Estimator::new(&desc, &profile, link).paper_mode();
        let opt = brute::solve(&est);
        let ns = neurosurgeon(&desc, &profile, link, true);
        assert!((opt.expected_time_s - ns.expected_time_s).abs() < 1e-15);
    }

    #[test]
    fn static_strategies() {
        let (desc, profile) = fixture(0.5);
        let link = LinkModel::new(5.85, 0.0);
        let est = Estimator::new(&desc, &profile, link).paper_mode();
        let edge = static_split(&est, 4, Strategy::EdgeOnly);
        let cloud = static_split(&est, 0, Strategy::CloudOnly);
        assert!(edge.is_edge_only(4));
        assert!(cloud.is_cloud_only());
        assert_eq!(edge.transfer_bytes, 0);
        assert_eq!(cloud.transfer_bytes, 12_288);
    }
}
