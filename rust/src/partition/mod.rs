//! BranchyNet partitioning — the paper's contribution (§V).
//!
//! * [`gprime`] — constructs the weighted graph `G'_BDNN` whose shortest
//!   `input -> output` path *is* the optimal edge/cloud split (Eqs. 7–8);
//! * [`solver`] — Dijkstra over `G'_BDNN`, decoding the path back into a
//!   [`PartitionPlan`];
//! * [`brute`] — the exhaustive baseline evaluating Eq. 6 at every split
//!   (the oracle the property tests compare the solver against, and the
//!   "Li et al. [7]-style search" baseline of §II);
//! * [`baselines`] — Neurosurgeon-style branch-blind planning (p = 0),
//!   plus static edge-only / cloud-only strategies;
//! * [`plan`] — the `PartitionPlan` everything produces and the
//!   coordinator consumes.
//!
//! The hot solve path lives in [`crate::planner`]: `solver::solve` (and
//! the `ShortestPath` arm below) delegate to its precomputed O(N)
//! sweep; the graph constructions here remain as the paper-faithful
//! oracle (`solver::solve_faithful`) and the compact ablation.

pub mod baselines;
pub mod brute;
pub mod compact;
pub mod gprime;
pub mod plan;
pub mod solver;

pub use plan::PartitionPlan;
pub use solver::solve;

use crate::config::settings::Strategy;
use crate::model::BranchyNetDesc;
use crate::network::bandwidth::LinkModel;
use crate::timing::{DelayProfile, Estimator};

/// Plan with the given strategy. The estimator settings (paper mode or
/// serving mode) are chosen by the caller via `paper_mode`.
pub fn plan_with_strategy(
    strategy: Strategy,
    desc: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    epsilon: f64,
    paper_mode: bool,
) -> PartitionPlan {
    fn make_estimator<'a>(
        d: &'a BranchyNetDesc,
        p: &'a DelayProfile,
        link: LinkModel,
        paper_mode: bool,
    ) -> Estimator<'a> {
        let e = Estimator::new(d, p, link);
        if paper_mode {
            e.paper_mode()
        } else {
            e
        }
    }
    match strategy {
        Strategy::ShortestPath => {
            crate::planner::Planner::new(desc, profile, epsilon, paper_mode).plan_for(link)
        }
        Strategy::BruteForce => brute::solve(&make_estimator(desc, profile, link, paper_mode)),
        Strategy::Neurosurgeon => baselines::neurosurgeon(desc, profile, link, paper_mode),
        Strategy::EdgeOnly => baselines::static_split(
            &make_estimator(desc, profile, link, paper_mode),
            desc.num_stages(),
            Strategy::EdgeOnly,
        ),
        Strategy::CloudOnly => baselines::static_split(
            &make_estimator(desc, profile, link, paper_mode),
            0,
            Strategy::CloudOnly,
        ),
    }
}
