//! The paper's solver surface. The one-shot entry point [`solve`] now
//! delegates to [`crate::planner::Planner`] — a precomputed O(N)
//! arithmetic sweep with no graph construction at all — while
//! [`solve_faithful`] keeps the paper's literal reduction (`G'_BDNN` +
//! Dijkstra, §V) as the oracle the planner is property-tested against
//! (and versus Li et al. [7]'s exponential branch×partition search that
//! §II argues against).

use crate::config::settings::Strategy;
use crate::graph::dijkstra;
use crate::model::BranchyNetDesc;
use crate::network::bandwidth::LinkModel;
use crate::planner::Planner;
use crate::timing::{DelayProfile, Estimator};

use super::gprime;
use super::plan::PartitionPlan;

/// Solve the partitioning problem (paper §V semantics).
///
/// `paper_mode = true` omits branch-evaluation cost (Eq. 5 exactly);
/// `false` includes it (the serving planner default).
///
/// One-shot convenience over [`Planner`]: builds the planner's
/// link-independent state and runs a single sweep. Callers that replan
/// across many links should construct a [`Planner`] once and call
/// `plan_for` / `plan_cached` instead.
pub fn solve(
    desc: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    epsilon: f64,
    paper_mode: bool,
) -> PartitionPlan {
    Planner::new(desc, profile, epsilon, paper_mode).plan_for(link)
}

/// The paper-faithful variant: builds the full `G'_BDNN` of §V (explicit
/// per-class cloud chains) and runs Dijkstra on it. Same answer as
/// [`solve`]; kept for the solver bench ablation and as executable
/// documentation of the reduction.
pub fn solve_faithful(
    desc: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    epsilon: f64,
    paper_mode: bool,
) -> PartitionPlan {
    let gp = gprime::build(desc, profile, link, epsilon, !paper_mode);
    let sp = dijkstra::shortest_path(&gp.graph, gp.input, gp.output)
        .expect("G'_BDNN is connected by construction");
    let split = gp.decode_split(&sp.nodes);
    let est = Estimator::new(desc, profile, link);
    let est = if paper_mode { est.paper_mode() } else { est };
    let expected = est.expected_time(split);
    PartitionPlan::from_split(split, expected, Strategy::ShortestPath, desc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BranchDesc, BranchyNetDesc};

    fn fixture() -> (BranchyNetDesc, DelayProfile) {
        let desc = BranchyNetDesc {
            stage_names: (1..=5).map(|i| format!("s{i}")).collect(),
            // Non-monotonic alphas as in B-AlexNet.
            stage_out_bytes: vec![57_600, 18_816, 25_088, 3_456, 8],
            input_bytes: 12_288,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: 0.6,
            }],
        };
        let profile = DelayProfile::from_cloud_times(
            vec![1e-3, 2e-3, 1.5e-3, 8e-4, 2e-4],
            3e-4,
            100.0,
        );
        (desc, profile)
    }

    #[test]
    fn solver_matches_exhaustive_minimum() {
        let (desc, profile) = fixture();
        for mbps in [1.10, 5.85, 18.80] {
            let link = LinkModel::new(mbps, 0.0);
            let plan = solve(&desc, &profile, link, 1e-9, true);
            let est = Estimator::new(&desc, &profile, link).paper_mode();
            let best = (0..=5)
                .map(|s| est.expected_time(s))
                .fold(f64::INFINITY, f64::min);
            assert!(
                (plan.expected_time_s - best).abs() <= 1e-12 + 1e-9,
                "mbps={mbps}: plan {} vs best {best}",
                plan.expected_time_s
            );
        }
    }

    #[test]
    fn slow_network_and_fast_edge_prefer_edge() {
        let (desc, profile) = fixture();
        // gamma = 1: edge as fast as cloud; crawling network.
        let p1 = profile.with_gamma(1.0);
        let plan = solve(&desc, &p1, LinkModel::new(0.01, 0.0), 1e-9, true);
        assert!(plan.is_edge_only(5), "{plan:?}");
    }

    #[test]
    fn fast_network_and_slow_edge_prefer_cloud() {
        let (desc, profile) = fixture();
        let p = profile.with_gamma(10_000.0);
        let plan = solve(&desc, &p, LinkModel::new(10_000.0, 0.0), 1e-9, true);
        assert!(plan.is_cloud_only(), "{plan:?}");
    }

    #[test]
    fn p_one_never_chooses_cloud_suffix_after_branch() {
        let (mut desc, profile) = fixture();
        desc.branches[0].exit_prob = 1.0;
        // Slow network: cloud-only (upload + full cloud chain) must lose
        // to the edge path, whose cost with p = 1 is exactly t1_e.
        let plan = solve(&desc, &profile, LinkModel::new(0.05, 0.0), 1e-9, true);
        assert!(plan.split_after >= 2, "{plan:?}");
        assert!((plan.expected_time_s - profile.t_edge[0]).abs() < 1e-12);
    }

    #[test]
    fn p_one_fast_network_cloud_only_can_still_win() {
        // Counterpoint: with p = 1 but a very expensive edge and a fast
        // network, uploading the raw input beats even one edge stage.
        let (mut desc, profile) = fixture();
        desc.branches[0].exit_prob = 1.0;
        let p = profile.with_gamma(10_000.0);
        let plan = solve(&desc, &p, LinkModel::new(10_000.0, 0.0), 1e-9, true);
        assert!(plan.is_cloud_only(), "{plan:?}");
    }
}
