//! Brute-force baseline: evaluate Eq. 6 at every split point and take the
//! argmin. O(N²) total (each estimator query is O(N)) — the obviously
//! correct oracle that the shortest-path solver is property-tested
//! against, and the scaling baseline for the solver bench.

use crate::config::settings::Strategy;
use crate::timing::Estimator;

use super::plan::PartitionPlan;

/// Exhaustively minimize expected inference time over all splits.
/// Ties break toward the *larger* split (more work on the edge), matching
/// the epsilon tie-break direction of the graph solver.
pub fn solve(est: &Estimator<'_>) -> PartitionPlan {
    let mut best_split = 0usize;
    let mut best_time = f64::INFINITY;
    for s in 0..est.num_splits() {
        let t = est.expected_time(s);
        if t < best_time || (t == best_time && s > best_split) {
            best_time = t;
            best_split = s;
        }
    }
    PartitionPlan::from_split(best_split, best_time, Strategy::BruteForce, est.desc())
}

/// Like [`solve`] but returns the full cost curve too (used by the
/// Fig. 4 driver, which plots `E[T]` rather than just the argmin).
pub fn solve_with_curve(est: &Estimator<'_>) -> (PartitionPlan, Vec<f64>) {
    let curve = est.all_times();
    let plan = solve(est);
    (plan, curve)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BranchDesc, BranchyNetDesc};
    use crate::network::bandwidth::LinkModel;
    use crate::timing::DelayProfile;

    #[test]
    fn picks_global_minimum() {
        let desc = BranchyNetDesc {
            stage_names: vec!["a".into(), "b".into(), "c".into()],
            stage_out_bytes: vec![1_000_000, 10, 5],
            input_bytes: 500,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: 0.0,
            }],
        };
        // Edge 10x slower; stage 2 output is tiny -> split after 2 only
        // if edge compute is worth it. Make cloud times huge so edge wins.
        let profile = DelayProfile::from_cloud_times(vec![1e-6, 1e-6, 1e-6], 0.0, 10.0);
        let link = LinkModel::new(0.008, 0.0); // 1 byte = 1 ms: transfers dominate
        let est = Estimator::new(&desc, &profile, link).paper_mode();
        let plan = solve(&est);
        // alpha: input 500 -> 0.5s; s1: 1e6 -> 1000s; s2: 10 -> 10ms; s3: edge-only.
        // Edge compute is microseconds, so edge-only wins.
        assert_eq!(plan.split_after, 3);
    }

    #[test]
    fn tie_breaks_toward_edge() {
        // All-zero costs: every split ties at 0 -> pick N.
        let desc = BranchyNetDesc {
            stage_names: vec!["a".into(), "b".into()],
            stage_out_bytes: vec![0, 0],
            input_bytes: 1,
            branches: vec![],
        };
        let profile = DelayProfile::from_cloud_times(vec![0.0, 0.0], 0.0, 1.0);
        let link = LinkModel::new(1e12, 0.0); // ~0 transfer time for 0/1 bytes
        let est = Estimator::new(&desc, &profile, link).paper_mode();
        let plan = solve(&est);
        assert_eq!(plan.split_after, 2);
    }

    #[test]
    fn curve_has_min_at_plan() {
        let desc = BranchyNetDesc {
            stage_names: (1..=4).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![100, 200, 50, 8],
            input_bytes: 300,
            branches: vec![BranchDesc {
                after_stage: 2,
                exit_prob: 0.5,
            }],
        };
        let profile =
            DelayProfile::from_cloud_times(vec![1e-4, 2e-4, 3e-4, 1e-4], 1e-5, 50.0);
        let est = Estimator::new(&desc, &profile, LinkModel::new(5.85, 0.0));
        let (plan, curve) = solve_with_curve(&est);
        assert_eq!(curve.len(), 5);
        let min = curve.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(plan.expected_time_s, min);
        assert_eq!(curve[plan.split_after], min);
    }
}
