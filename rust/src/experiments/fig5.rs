//! Figure 5: the partition layer the optimizer chooses as a function of
//! the processing factor gamma, for 3G and 4G, one curve per side-branch
//! probability.
//!
//! Paper shape claims: as gamma grows (weaker edge), the chosen layer
//! marches toward `input` (cloud-only); for the higher-bandwidth 4G this
//! march happens at *lower* gamma than for 3G.

use crate::model::BranchyNetDesc;
use crate::network::bandwidth::{LinkModel, Profile};
use crate::planner::Planner;
use crate::timing::DelayProfile;

pub const PROBABILITIES: [f64; 4] = [0.2, 0.5, 0.8, 1.0];

#[derive(Debug, Clone)]
pub struct Curve {
    pub network: Profile,
    pub probability: f64,
    /// (gamma, chosen split_after, split label).
    pub points: Vec<(f64, usize, String)>,
}

/// Log-spaced gamma grid from 1 to `max_gamma`.
pub fn gamma_grid(points: usize, max_gamma: f64) -> Vec<f64> {
    assert!(points >= 2 && max_gamma > 1.0);
    (0..points)
        .map(|i| 10f64.powf(i as f64 / (points - 1) as f64 * max_gamma.log10()))
        .collect()
}

pub fn run(
    desc_template: &BranchyNetDesc,
    profile: &DelayProfile,
    gammas: &[f64],
    epsilon: f64,
) -> Vec<Curve> {
    const NETS: [Profile; 2] = [Profile::ThreeG, Profile::FourG];
    let mut curves: Vec<Curve> = NETS
        .iter()
        .flat_map(|&net| {
            PROBABILITIES.iter().map(move |&p| Curve {
                network: net,
                probability: p,
                points: Vec::with_capacity(gammas.len()),
            })
        })
        .collect();
    for (pi, &p) in PROBABILITIES.iter().enumerate() {
        let mut desc = desc_template.clone();
        for b in &mut desc.branches {
            b.exit_prob = p;
        }
        for &gamma in gammas {
            let prof = profile.with_gamma(gamma);
            // One planner per (p, gamma), shared by both networks.
            let planner = Planner::new(&desc, &prof, epsilon, true);
            for (ni, &net) in NETS.iter().enumerate() {
                let plan = planner.plan_for(LinkModel::from_profile(net));
                let label = plan.split_label(&desc);
                curves[ni * PROBABILITIES.len() + pi]
                    .points
                    .push((gamma, plan.split_after, label));
            }
        }
    }
    curves
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::BranchDesc;

    fn fixture() -> (BranchyNetDesc, DelayProfile) {
        let desc = BranchyNetDesc {
            stage_names: (1..=8).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![57_600, 18_816, 25_088, 25_088, 3_456, 1_024, 512, 8],
            input_bytes: 12_288,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: 0.0,
            }],
        };
        let profile = DelayProfile::from_cloud_times(
            vec![1e-3, 1.5e-3, 1.2e-3, 1.2e-3, 8e-4, 3e-4, 1e-4, 5e-5],
            2e-4,
            10.0,
        );
        (desc, profile)
    }

    #[test]
    fn gamma_grid_is_log_spaced() {
        let g = gamma_grid(4, 1000.0);
        assert_eq!(g.len(), 4);
        assert!((g[0] - 1.0).abs() < 1e-12);
        assert!((g[3] - 1000.0).abs() < 1e-9);
        assert!((g[1] - 10.0).abs() < 1e-9);
    }

    #[test]
    fn split_marches_toward_input_as_gamma_grows() {
        let (desc, profile) = fixture();
        let gammas = gamma_grid(25, 5000.0);
        let curves = run(&desc, &profile, &gammas, 1e-9);
        assert_eq!(curves.len(), 8); // 2 networks x 4 probabilities
        for c in &curves {
            // Non-strictly decreasing split index in gamma, modulo the
            // p=1 regime where the split can stick at the branch.
            let splits: Vec<usize> = c.points.iter().map(|&(_, s, _)| s).collect();
            let first = splits[0];
            let last = *splits.last().unwrap();
            assert!(
                last <= first,
                "net {:?} p {}: splits {:?}",
                c.network,
                c.probability,
                splits
            );
        }
    }

    #[test]
    fn fourg_goes_cloud_only_at_lower_gamma_than_threeg() {
        let (desc, profile) = fixture();
        let gammas = gamma_grid(40, 10_000.0);
        let curves = run(&desc, &profile, &gammas, 1e-9);
        let first_cloud_only = |net: Profile, p: f64| -> Option<f64> {
            curves
                .iter()
                .find(|c| c.network == net && c.probability == p)
                .unwrap()
                .points
                .iter()
                .find(|&&(_, s, _)| s == 0)
                .map(|&(g, _, _)| g)
        };
        for &p in &[0.2, 0.5, 0.8] {
            let g3 = first_cloud_only(Profile::ThreeG, p);
            let g4 = first_cloud_only(Profile::FourG, p);
            if let (Some(g3), Some(g4)) = (g3, g4) {
                assert!(
                    g4 <= g3,
                    "p={p}: 4G should switch to cloud-only no later than 3G ({g4} vs {g3})"
                );
            }
        }
    }
}
