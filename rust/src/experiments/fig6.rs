//! Figure 6: probability that the side branch classifies a sample, as a
//! function of the entropy threshold, for Gaussian-blur distortion levels
//! {none, 5, 15, 65} — run on the *real* trained B-AlexNet through the
//! PJRT runtime (48-sample batches, as in the paper).
//!
//! This is the experiment that closes the loop: the p(threshold, quality)
//! surface measured here is exactly the `p_k` parameter the Fig. 4/5
//! planning experiments sweep analytically.

use anyhow::Result;

use crate::runtime::{fixture, HostTensor, InferenceEngine};

pub const LEVELS: [&str; 4] = ["none", "low", "mid", "high"];

#[derive(Debug, Clone)]
pub struct LevelResult {
    pub level: String,
    pub blur_ksize: usize,
    /// Per-sample branch entropies (nats).
    pub entropies: Vec<f32>,
    /// Branch top-1 accuracy on this batch (extra vs the paper).
    pub branch_accuracy: f64,
}

impl LevelResult {
    /// `P[exit]` at a given entropy threshold — one Fig. 6 curve point.
    pub fn exit_probability(&self, threshold: f64) -> f64 {
        let n = self.entropies.len();
        if n == 0 {
            return 0.0;
        }
        self.entropies
            .iter()
            .filter(|&&e| (e as f64) < threshold)
            .count() as f64
            / n as f64
    }

    /// Full curve over `points` thresholds in [0, max_nats].
    pub fn curve(&self, points: usize, max_nats: f64) -> Vec<(f64, f64)> {
        (0..points)
            .map(|i| {
                let thr = i as f64 / (points - 1) as f64 * max_nats;
                (thr, self.exit_probability(thr))
            })
            .collect()
    }
}

/// Run branch inference over the blurred fixture batches.
pub fn run(engine: &InferenceEngine) -> Result<Vec<LevelResult>> {
    let m = engine.manifest().clone();
    let labels = m.fig6_labels()?;
    let mut results = Vec::with_capacity(LEVELS.len());
    let exec_b = *m
        .batch_sizes
        .iter()
        .max()
        .expect("manifest has batch sizes");

    for level in LEVELS {
        let info = m.fig6_fixture(level)?;
        let batch = fixture::load(&info)?;
        let n = batch.batch();
        let mut entropies = Vec::with_capacity(n);
        let mut correct = 0usize;

        // Chunk the 48-sample batch through the largest executable.
        let samples = batch.unstack();
        let mut i = 0;
        while i < n {
            let take = (n - i).min(exec_b);
            let chunk = HostTensor::stack(&samples[i..i + take])?;
            let padded = chunk.pad_batch(exec_b);
            let acts = engine.run_stages(1, m.branch.after_stage, &padded)?;
            let out = engine.run_branch(&acts)?;
            let classes = InferenceEngine::argmax_classes(&out.probs);
            for j in 0..take {
                entropies.push(out.entropy[j]);
                if classes[j] == labels[i + j] {
                    correct += 1;
                }
            }
            i += take;
        }

        // ksize bookkeeping (mirrors data.BLUR_LEVELS).
        let blur_ksize = match level {
            "none" => 0,
            "low" => 5,
            "mid" => 15,
            "high" => 65,
            _ => unreachable!(),
        };
        results.push(LevelResult {
            level: level.to_string(),
            blur_ksize,
            branch_accuracy: correct as f64 / n as f64,
            entropies,
        });
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exit_probability_is_a_cdf() {
        let r = LevelResult {
            level: "t".into(),
            blur_ksize: 0,
            entropies: vec![0.1, 0.2, 0.3, 0.6],
            branch_accuracy: 1.0,
        };
        assert_eq!(r.exit_probability(0.0), 0.0);
        assert_eq!(r.exit_probability(0.15), 0.25);
        assert_eq!(r.exit_probability(0.31), 0.75);
        assert_eq!(r.exit_probability(1.0), 1.0);
        let curve = r.curve(8, 0.7);
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
        assert_eq!(curve.len(), 8);
    }

    #[test]
    fn empty_entropies_safe() {
        let r = LevelResult {
            level: "t".into(),
            blur_ksize: 0,
            entropies: vec![],
            branch_accuracy: 0.0,
        };
        assert_eq!(r.exit_probability(0.5), 0.0);
    }
}
