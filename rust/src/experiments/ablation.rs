//! Ablations beyond the paper's figures — the design-choice experiments
//! DESIGN.md §6 lists:
//!
//! * **strategy gap** — paper solver vs Neurosurgeon [3] vs edge-only vs
//!   cloud-only across (p, gamma, B): how much does modeling the branch
//!   buy? (quantifies §II's argument);
//! * **epsilon sensitivity** — the tie-breaker must not change any
//!   non-degenerate decision across orders of magnitude;
//! * **branch-cost sensitivity** — paper mode vs serving mode planning;
//! * **branch placement** — sweep the side branch position (the paper's
//!   stated future work, §VII).

use crate::config::settings::Strategy;
use crate::model::{BranchDesc, BranchyNetDesc};
use crate::network::bandwidth::{LinkModel, Profile};
use crate::partition;
use crate::planner::Planner;
use crate::timing::DelayProfile;

/// One strategy-gap cell.
#[derive(Debug, Clone)]
pub struct StrategyGap {
    pub probability: f64,
    pub gamma: f64,
    pub network: Profile,
    /// (strategy, split, expected time).
    pub rows: Vec<(Strategy, usize, f64)>,
}

impl StrategyGap {
    pub fn solver_time(&self) -> f64 {
        self.rows
            .iter()
            .find(|r| r.0 == Strategy::ShortestPath)
            .unwrap()
            .2
    }

    /// Worst competitor / solver — how much the paper's method saves.
    pub fn max_speedup(&self) -> f64 {
        let s = self.solver_time();
        self.rows
            .iter()
            .map(|r| r.2 / s)
            .fold(1.0, f64::max)
    }
}

pub fn strategy_gap(
    desc_template: &BranchyNetDesc,
    profile: &DelayProfile,
    probabilities: &[f64],
    gammas: &[f64],
) -> Vec<StrategyGap> {
    let strategies = [
        Strategy::ShortestPath,
        Strategy::Neurosurgeon,
        Strategy::EdgeOnly,
        Strategy::CloudOnly,
    ];
    let mut out = Vec::new();
    for &p in probabilities {
        for &gamma in gammas {
            for net in Profile::ALL {
                let link = LinkModel::from_profile(net);
                let prof = profile.with_gamma(gamma);
                let mut desc = desc_template.clone();
                for b in &mut desc.branches {
                    b.exit_prob = p;
                }
                let rows = strategies
                    .iter()
                    .map(|&st| {
                        let plan =
                            partition::plan_with_strategy(st, &desc, &prof, link, 1e-9, true);
                        (st, plan.split_after, plan.expected_time_s)
                    })
                    .collect();
                out.push(StrategyGap {
                    probability: p,
                    gamma,
                    network: net,
                    rows,
                });
            }
        }
    }
    out
}

/// Does the chosen split change when epsilon varies over [lo, hi]?
/// Returns the distinct splits seen per epsilon (should be 1 entry).
pub fn epsilon_sensitivity(
    desc: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    epsilons: &[f64],
) -> Vec<(f64, usize)> {
    // Epsilon only enters the tie-break, so one precompute serves the
    // whole sweep.
    let planner = Planner::new(desc, profile, 1e-9, true);
    epsilons
        .iter()
        .map(|&eps| (eps, planner.plan_with_epsilon(link, eps).split_after))
        .collect()
}

/// Sweep the branch position over every interior stage, reporting the
/// optimal expected time for each placement — the paper's future-work
/// "heuristics for side branch placement" (§VII) seeded as data.
pub fn branch_placement(
    desc_template: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    exit_prob: f64,
) -> Vec<(usize, f64, usize)> {
    let n = desc_template.num_stages();
    (1..n)
        .map(|pos| {
            let mut desc = desc_template.clone();
            desc.branches = vec![BranchDesc {
                after_stage: pos,
                exit_prob,
            }];
            let plan = Planner::new(&desc, profile, 1e-9, true).plan_for(link);
            (pos, plan.expected_time_s, plan.split_after)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> (BranchyNetDesc, DelayProfile) {
        let desc = BranchyNetDesc {
            stage_names: (1..=8).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![57_600, 18_816, 25_088, 25_088, 3_456, 1_024, 512, 8],
            input_bytes: 12_288,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: 0.5,
            }],
        };
        let profile = DelayProfile::from_cloud_times(
            vec![1e-3, 1.5e-3, 1.2e-3, 1.2e-3, 8e-4, 3e-4, 1e-4, 5e-5],
            2e-4,
            10.0,
        );
        (desc, profile)
    }

    #[test]
    fn solver_dominates_every_strategy() {
        let (desc, profile) = fixture();
        let gaps = strategy_gap(&desc, &profile, &[0.0, 0.5, 1.0], &[10.0, 1000.0]);
        for g in &gaps {
            let s = g.solver_time();
            for &(st, _, t) in &g.rows {
                assert!(
                    s <= t + 1e-12,
                    "{st:?} beat the solver at p={} gamma={} {:?}",
                    g.probability,
                    g.gamma,
                    g.network
                );
            }
            assert!(g.max_speedup() >= 1.0);
        }
    }

    #[test]
    fn epsilon_does_not_flip_decisions() {
        let (desc, profile) = fixture();
        let link = LinkModel::from_profile(Profile::FourG);
        let res = epsilon_sensitivity(
            &desc,
            &profile,
            link,
            &[1e-12, 1e-10, 1e-9, 1e-7, 1e-5],
        );
        let first = res[0].1;
        assert!(res.iter().all(|&(_, s)| s == first), "{res:?}");
    }

    #[test]
    fn branch_placement_covers_interior() {
        let (desc, profile) = fixture();
        let res = branch_placement(&desc, &profile, LinkModel::from_profile(Profile::ThreeG), 0.6);
        assert_eq!(res.len(), 7);
        assert!(res.iter().all(|&(_, t, _)| t.is_finite() && t > 0.0));
    }
}
