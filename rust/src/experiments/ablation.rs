//! Ablations beyond the paper's figures — the design-choice experiments
//! DESIGN.md §6 lists:
//!
//! * **strategy gap** — paper solver vs Neurosurgeon [3] vs edge-only vs
//!   cloud-only across (p, gamma, B): how much does modeling the branch
//!   buy? (quantifies §II's argument);
//! * **epsilon sensitivity** — the tie-breaker must not change any
//!   non-degenerate decision across orders of magnitude;
//! * **branch-cost sensitivity** — paper mode vs serving mode planning;
//! * **branch placement** — sweep the side branch position (the paper's
//!   stated future work, §VII); [`branch_set_candidates`] generalizes
//!   the sweep into the move/add/drop candidate stream the joint
//!   search ([`crate::planner::Planner::plan_joint`]) consumes.

use crate::config::settings::Strategy;
use crate::model::{BranchDesc, BranchyNetDesc};
use crate::network::bandwidth::{LinkModel, Profile};
use crate::partition;
use crate::planner::{JointSearchSpace, Planner};
use crate::timing::DelayProfile;

/// One strategy-gap cell.
#[derive(Debug, Clone)]
pub struct StrategyGap {
    pub probability: f64,
    pub gamma: f64,
    pub network: Profile,
    /// (strategy, split, expected time).
    pub rows: Vec<(Strategy, usize, f64)>,
}

impl StrategyGap {
    pub fn solver_time(&self) -> f64 {
        self.rows
            .iter()
            .find(|r| r.0 == Strategy::ShortestPath)
            .unwrap()
            .2
    }

    /// Worst competitor / solver — how much the paper's method saves.
    pub fn max_speedup(&self) -> f64 {
        let s = self.solver_time();
        self.rows
            .iter()
            .map(|r| r.2 / s)
            .fold(1.0, f64::max)
    }
}

pub fn strategy_gap(
    desc_template: &BranchyNetDesc,
    profile: &DelayProfile,
    probabilities: &[f64],
    gammas: &[f64],
) -> Vec<StrategyGap> {
    let strategies = [
        Strategy::ShortestPath,
        Strategy::Neurosurgeon,
        Strategy::EdgeOnly,
        Strategy::CloudOnly,
    ];
    let mut out = Vec::new();
    for &p in probabilities {
        for &gamma in gammas {
            for net in Profile::ALL {
                let link = LinkModel::from_profile(net);
                let prof = profile.with_gamma(gamma);
                let mut desc = desc_template.clone();
                for b in &mut desc.branches {
                    b.exit_prob = p;
                }
                let rows = strategies
                    .iter()
                    .map(|&st| {
                        let plan =
                            partition::plan_with_strategy(st, &desc, &prof, link, 1e-9, true);
                        (st, plan.split_after, plan.expected_time_s)
                    })
                    .collect();
                out.push(StrategyGap {
                    probability: p,
                    gamma,
                    network: net,
                    rows,
                });
            }
        }
    }
    out
}

/// Does the chosen split change when epsilon varies over [lo, hi]?
/// Returns the distinct splits seen per epsilon (should be 1 entry).
pub fn epsilon_sensitivity(
    desc: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    epsilons: &[f64],
) -> Vec<(f64, usize)> {
    // Epsilon only enters the tie-break, so one precompute serves the
    // whole sweep.
    let planner = Planner::new(desc, profile, 1e-9, true);
    epsilons
        .iter()
        .map(|&eps| (eps, planner.plan_with_epsilon(link, eps).split_after))
        .collect()
}

/// Sweep the branch position over every interior stage, reporting the
/// optimal expected time for each placement — the paper's future-work
/// "heuristics for side branch placement" (§VII) seeded as data.
///
/// One `Planner` core serves every placement: each candidate position
/// is priced through the joint search's cheap derived view instead of
/// a full per-candidate `Planner::new` (bit-identical either way,
/// pinned by a unit test below). Rows come back in position order.
pub fn branch_placement(
    desc_template: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    exit_prob: f64,
) -> Vec<(usize, f64, usize)> {
    let n = desc_template.num_stages();
    if n <= 1 {
        return Vec::new();
    }
    let planner = Planner::new(desc_template, profile, 1e-9, true);
    let space = JointSearchSpace {
        branch_sets: (1..n)
            .map(|pos| {
                vec![BranchDesc {
                    after_stage: pos,
                    exit_prob,
                }]
            })
            .collect(),
        encodings: vec![planner.wire_encoding()],
        min_accuracy_proxy: 0.0,
    };
    let joint = planner.plan_joint(link, &space);
    let mut rows: Vec<(usize, f64, usize)> = joint
        .ranked
        .iter()
        .map(|c| (c.branch_set[0].after_stage, c.expected_time, c.split))
        .collect();
    rows.sort_by_key(|&(pos, _, _)| pos);
    rows
}

/// Candidate branch architectures for the joint search, derived from a
/// template: the template's own branch set first, then every
/// single-branch **move** (each branch relocated to each vacant
/// interior slot, keeping its probability), then every **add** (a new
/// branch at `exit_prob` in each vacant slot), then every **drop**.
/// Branch sets are position-sorted, the order is deterministic, and
/// the first occurrence wins on duplicates — the joint search's
/// candidate stream is stable across runs (pinned by a unit test).
pub fn branch_set_candidates(
    desc_template: &BranchyNetDesc,
    exit_prob: f64,
) -> Vec<Vec<BranchDesc>> {
    fn push_unique(out: &mut Vec<Vec<BranchDesc>>, mut set: Vec<BranchDesc>) {
        set.sort_by_key(|b| b.after_stage);
        if !out.contains(&set) {
            out.push(set);
        }
    }
    let n = desc_template.num_stages();
    let mut own = desc_template.branches.clone();
    own.sort_by_key(|b| b.after_stage);
    let occupied = |pos: usize| own.iter().any(|b| b.after_stage == pos);

    let mut out = Vec::new();
    push_unique(&mut out, own.clone());
    for j in 0..own.len() {
        for pos in 1..n {
            if occupied(pos) {
                continue;
            }
            let mut set = own.clone();
            set[j].after_stage = pos;
            push_unique(&mut out, set);
        }
    }
    for pos in 1..n {
        if occupied(pos) {
            continue;
        }
        let mut set = own.clone();
        set.push(BranchDesc {
            after_stage: pos,
            exit_prob,
        });
        push_unique(&mut out, set);
    }
    for j in 0..own.len() {
        let mut set = own.clone();
        set.remove(j);
        push_unique(&mut out, set);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> (BranchyNetDesc, DelayProfile) {
        let desc = BranchyNetDesc {
            stage_names: (1..=8).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![57_600, 18_816, 25_088, 25_088, 3_456, 1_024, 512, 8],
            input_bytes: 12_288,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: 0.5,
            }],
        };
        let profile = DelayProfile::from_cloud_times(
            vec![1e-3, 1.5e-3, 1.2e-3, 1.2e-3, 8e-4, 3e-4, 1e-4, 5e-5],
            2e-4,
            10.0,
        );
        (desc, profile)
    }

    #[test]
    fn solver_dominates_every_strategy() {
        let (desc, profile) = fixture();
        let gaps = strategy_gap(&desc, &profile, &[0.0, 0.5, 1.0], &[10.0, 1000.0]);
        for g in &gaps {
            let s = g.solver_time();
            for &(st, _, t) in &g.rows {
                assert!(
                    s <= t + 1e-12,
                    "{st:?} beat the solver at p={} gamma={} {:?}",
                    g.probability,
                    g.gamma,
                    g.network
                );
            }
            assert!(g.max_speedup() >= 1.0);
        }
    }

    #[test]
    fn epsilon_does_not_flip_decisions() {
        let (desc, profile) = fixture();
        let link = LinkModel::from_profile(Profile::FourG);
        let res = epsilon_sensitivity(
            &desc,
            &profile,
            link,
            &[1e-12, 1e-10, 1e-9, 1e-7, 1e-5],
        );
        let first = res[0].1;
        assert!(res.iter().all(|&(_, s)| s == first), "{res:?}");
    }

    #[test]
    fn branch_placement_covers_interior() {
        let (desc, profile) = fixture();
        let res = branch_placement(&desc, &profile, LinkModel::from_profile(Profile::ThreeG), 0.6);
        assert_eq!(res.len(), 7);
        assert!(res.iter().all(|&(_, t, _)| t.is_finite() && t > 0.0));
    }

    #[test]
    fn branch_placement_is_bit_identical_to_per_candidate_construction() {
        // The cheap-view refactor must answer exactly what the old
        // full-`Planner::new`-per-position implementation answered.
        let (desc, profile) = fixture();
        for net in Profile::ALL {
            let link = LinkModel::from_profile(net);
            let res = branch_placement(&desc, &profile, link, 0.6);
            for &(pos, t, split) in &res {
                let mut one = desc.clone();
                one.branches = vec![BranchDesc {
                    after_stage: pos,
                    exit_prob: 0.6,
                }];
                let plan = Planner::new(&one, &profile, 1e-9, true).plan_for(link);
                assert_eq!(split, plan.split_after, "pos {pos} {net:?}");
                assert_eq!(
                    t.to_bits(),
                    plan.expected_time_s.to_bits(),
                    "pos {pos} {net:?}"
                );
            }
        }
    }

    #[test]
    fn candidate_stream_order_is_pinned_and_deterministic() {
        let b = |after_stage: usize, exit_prob: f64| BranchDesc {
            after_stage,
            exit_prob,
        };
        let desc = BranchyNetDesc {
            stage_names: (1..=4).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![40_000, 20_000, 8_000, 8],
            input_bytes: 12_288,
            branches: vec![b(1, 0.5), b(3, 0.2)],
        };
        let got = branch_set_candidates(&desc, 0.3);
        // Own set, then moves (branch order x vacant position order),
        // then adds, then drops — exactly this, in exactly this order.
        let want = vec![
            vec![b(1, 0.5), b(3, 0.2)],
            vec![b(2, 0.5), b(3, 0.2)],
            vec![b(1, 0.5), b(2, 0.2)],
            vec![b(1, 0.5), b(2, 0.3), b(3, 0.2)],
            vec![b(3, 0.2)],
            vec![b(1, 0.5)],
        ];
        assert_eq!(got, want);
        assert_eq!(got, branch_set_candidates(&desc, 0.3), "stable across runs");

        // A branch-free template: itself (the plain DNN), then one add
        // per interior slot.
        let plain = BranchyNetDesc {
            branches: vec![],
            ..desc.clone()
        };
        assert_eq!(
            branch_set_candidates(&plain, 0.3),
            vec![vec![], vec![b(1, 0.3)], vec![b(2, 0.3)], vec![b(3, 0.3)]]
        );
    }
}
