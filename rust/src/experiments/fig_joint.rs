//! Joint-search figure (beyond the paper): fixed-architecture optimum
//! vs [`Planner::plan_joint`] across a bandwidth × exit-probability
//! grid, at equal-or-better accuracy proxy.
//!
//! The paper's optimizer (and `fig4`) holds the BranchyNet and the f32
//! wire format fixed and moves only the split. Each cell here solves
//! both: the fixed plan (`plan_for`, raw activations, the template's
//! branch set at the grid p) and the joint plan over
//! [`ablation::branch_set_candidates`] × all three wire encodings, with
//! the accuracy floor pinned to the *fixed* architecture's survival
//! mass — so the joint plan may never buy latency with accuracy. Since
//! the fixed configuration is itself a candidate, the joint expected
//! time is ≤ the fixed one by construction in every cell (asserted);
//! the interesting output is where it is *strictly* better and which
//! axis (placement or precision) paid.
//!
//! [`ablation::branch_set_candidates`]: super::ablation::branch_set_candidates

use crate::model::BranchyNetDesc;
use crate::network::bandwidth::LinkModel;
use crate::network::encoding::WireEncoding;
use crate::planner::joint::accuracy_proxy;
use crate::planner::{JointSearchSpace, Planner};
use crate::timing::DelayProfile;

use super::ablation::branch_set_candidates;

/// Default uplink grid: a starved sub-3G link, the paper's 3G/4G, and
/// Wi-Fi.
pub const DEFAULT_BANDWIDTHS_MBPS: [f64; 4] = [0.5, 1.10, 5.85, 18.80];
/// Default exit-probability grid, endpoints included.
pub const DEFAULT_PROBS: [f64; 4] = [0.0, 0.3, 0.6, 0.9];

/// One (bandwidth, p) cell: the fixed-architecture optimum vs the
/// joint optimum at equal-or-better accuracy proxy.
#[derive(Debug, Clone)]
pub struct JointCell {
    pub mbps: f64,
    pub p: f64,
    pub fixed_split: usize,
    pub fixed_time: f64,
    /// Survival mass of the template's branch set at this p — also the
    /// accuracy floor the joint search ran under.
    pub fixed_proxy: f64,
    pub joint_split: usize,
    pub joint_time: f64,
    pub joint_proxy: f64,
    pub joint_encoding: WireEncoding,
    /// Winning branch positions, ascending.
    pub joint_branches: Vec<usize>,
}

impl JointCell {
    /// Percent latency reduction of the joint plan over the fixed plan.
    pub fn improvement_pct(&self) -> f64 {
        (1.0 - self.joint_time / self.fixed_time) * 100.0
    }

    /// Did the joint plan strictly beat the fixed plan?
    pub fn strictly_better(&self) -> bool {
        self.joint_time < self.fixed_time
    }
}

/// Run the full grid. One `Planner` core serves every cell: each grid
/// p is a cheap view for the fixed plan, and the joint search prices
/// its candidates over the same core. Asserts `joint_time <=
/// fixed_time` and `joint_proxy >= fixed_proxy` in every cell — the
/// fixed configuration is in the candidate set, so losing to it would
/// be a search bug, not a data point.
pub fn run(
    desc_template: &BranchyNetDesc,
    profile: &DelayProfile,
    bandwidths: &[f64],
    probs: &[f64],
    epsilon: f64,
) -> Vec<JointCell> {
    let base = Planner::new(desc_template, profile, epsilon, true);
    let n_branches = desc_template.branches.len();
    let mut cells = Vec::new();
    for &p in probs {
        let mut desc_p = desc_template.clone();
        for b in &mut desc_p.branches {
            b.exit_prob = p;
        }
        let planner = base.with_exit_probs(&vec![p; n_branches]);
        let fixed_proxy = accuracy_proxy(&desc_p.branches);
        let space = JointSearchSpace {
            branch_sets: branch_set_candidates(&desc_p, p),
            encodings: WireEncoding::ALL.to_vec(),
            min_accuracy_proxy: fixed_proxy,
        };
        for &mbps in bandwidths {
            let link = LinkModel::new(mbps, 0.0);
            let fixed = planner.plan_for(link);
            let joint = planner.plan_joint(link, &space);
            assert!(
                joint.expected_time <= fixed.expected_time_s,
                "joint lost to its own fixed candidate at mbps={mbps} p={p}: \
                 {} vs {}",
                joint.expected_time,
                fixed.expected_time_s
            );
            assert!(
                joint.accuracy_proxy >= fixed_proxy,
                "accuracy floor violated at mbps={mbps} p={p}"
            );
            cells.push(JointCell {
                mbps,
                p,
                fixed_split: fixed.split_after,
                fixed_time: fixed.expected_time_s,
                fixed_proxy,
                joint_split: joint.split,
                joint_time: joint.expected_time,
                joint_proxy: joint.accuracy_proxy,
                joint_encoding: joint.encoding,
                joint_branches: joint.branch_set.iter().map(|b| b.after_stage).collect(),
            });
        }
    }
    cells
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::BranchDesc;

    fn fixture() -> (BranchyNetDesc, DelayProfile) {
        let desc = BranchyNetDesc {
            stage_names: (1..=8).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![57_600, 18_816, 25_088, 25_088, 3_456, 1_024, 512, 8],
            input_bytes: 12_288,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: 0.0,
            }],
        };
        let profile = DelayProfile::from_cloud_times(
            vec![1e-3, 1.5e-3, 1.2e-3, 1.2e-3, 8e-4, 3e-4, 1e-4, 5e-5],
            2e-4,
            10.0,
        );
        (desc, profile)
    }

    #[test]
    fn covers_the_grid_and_never_loses_to_fixed() {
        let (desc, profile) = fixture();
        let cells = run(
            &desc,
            &profile,
            &DEFAULT_BANDWIDTHS_MBPS,
            &DEFAULT_PROBS,
            1e-9,
        );
        assert_eq!(
            cells.len(),
            DEFAULT_BANDWIDTHS_MBPS.len() * DEFAULT_PROBS.len()
        );
        for c in &cells {
            // run() already asserts these; restate so the test stands
            // alone if the asserts are ever relaxed.
            assert!(c.joint_time <= c.fixed_time, "{c:?}");
            assert!(c.joint_proxy >= c.fixed_proxy, "{c:?}");
            assert!(c.joint_time.is_finite() && c.joint_time > 0.0);
        }
    }

    #[test]
    fn some_cell_strictly_beats_the_fixed_architecture() {
        // The acceptance claim: joint search is not vacuous — somewhere
        // on the grid it finds a strictly faster configuration at
        // equal-or-better accuracy proxy.
        let (desc, profile) = fixture();
        let cells = run(
            &desc,
            &profile,
            &DEFAULT_BANDWIDTHS_MBPS,
            &DEFAULT_PROBS,
            1e-9,
        );
        let wins: Vec<&JointCell> = cells.iter().filter(|c| c.strictly_better()).collect();
        assert!(!wins.is_empty(), "no strict win anywhere on the grid");
        for w in &wins {
            assert!(w.improvement_pct() > 0.0);
        }
    }

    #[test]
    fn wins_come_from_a_real_axis_change() {
        // Every strict win must differ from the fixed plan on at least
        // one searched axis: encoding, branch placement, or split.
        let (desc, profile) = fixture();
        let cells = run(
            &desc,
            &profile,
            &DEFAULT_BANDWIDTHS_MBPS,
            &DEFAULT_PROBS,
            1e-9,
        );
        for c in cells.iter().filter(|c| c.strictly_better()) {
            let fixed_branches: Vec<usize> = vec![1];
            let moved = c.joint_encoding != WireEncoding::Raw
                || c.joint_branches != fixed_branches
                || c.joint_split != c.fixed_split;
            assert!(moved, "strict win with identical configuration: {c:?}");
        }
    }
}
