//! Experiment drivers regenerating the paper's evaluation (§VI):
//! Figure 4 (inference time vs exit probability), Figure 5 (partition
//! layer vs processing factor), Figure 6 (exit probability vs entropy
//! threshold under blur), plus ablations beyond the paper.
//!
//! Each driver returns plain data (series of points) so the CLI, the
//! bench binaries and the shape-assertion tests all consume the same
//! computation.

pub mod ablation;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig_joint;
