//! Figure 4: expected inference time vs the probability of classifying a
//! sample at the side branch, for processing factors gamma in
//! {10, 100, 1000} and uplinks {3G, 4G, Wi-Fi}.
//!
//! "These results are obtained based on the solution of our optimization
//! problem when varying the probability" (§VI) — i.e. each point is the
//! *optimal* `E[T_inf]` at that (p, gamma, B), not a fixed partition's.

use crate::model::BranchyNetDesc;
use crate::network::bandwidth::{LinkModel, Profile};
use crate::planner::Planner;
use crate::timing::DelayProfile;

pub const GAMMAS: [f64; 3] = [10.0, 100.0, 1000.0];
pub const DEFAULT_POINTS: usize = 21;

/// One curve: optimal expected time per probability point.
#[derive(Debug, Clone)]
pub struct Curve {
    pub gamma: f64,
    pub network: Profile,
    /// (p, optimal `E[T]` seconds, chosen split_after).
    pub points: Vec<(f64, f64, usize)>,
}

impl Curve {
    /// Percent reduction of `E[T]` from p = 0 to p = 1 — the quantity the
    /// paper quotes as 87.27% / 82.98% / 70% for 3G/4G/Wi-Fi at gamma=10.
    pub fn reduction_pct(&self) -> f64 {
        let t0 = self.points.first().unwrap().1;
        let t1 = self.points.last().unwrap().1;
        (1.0 - t1 / t0) * 100.0
    }
}

/// Run the full Fig. 4 sweep: the grid probability is applied to every
/// branch of `desc_template` via cheap planner p-views; `profile`
/// carries measured cloud times.
pub fn run(
    desc_template: &BranchyNetDesc,
    profile: &DelayProfile,
    points: usize,
    epsilon: f64,
) -> Vec<Curve> {
    let mut curves = Vec::new();
    for &gamma in &GAMMAS {
        let prof = profile.with_gamma(gamma);
        // One full precompute per gamma; each probability grid point is
        // a cheap p-view over the shared static core (bit-identical to
        // a fresh construction at that p), shared by all three networks.
        let base = Planner::new(desc_template, &prof, epsilon, true);
        let n_branches = desc_template.branches.len();
        let mut per_net: Vec<Vec<(f64, f64, usize)>> =
            vec![Vec::with_capacity(points); Profile::ALL.len()];
        for i in 0..points {
            let p = i as f64 / (points - 1) as f64;
            let planner = base.with_exit_probs(&vec![p; n_branches]);
            for (ni, &net) in Profile::ALL.iter().enumerate() {
                let plan = planner.plan_for(LinkModel::from_profile(net));
                per_net[ni].push((p, plan.expected_time_s, plan.split_after));
            }
        }
        for (ni, &net) in Profile::ALL.iter().enumerate() {
            curves.push(Curve {
                gamma,
                network: net,
                points: std::mem::take(&mut per_net[ni]),
            });
        }
    }
    curves
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::BranchDesc;

    fn fixture() -> (BranchyNetDesc, DelayProfile) {
        let desc = BranchyNetDesc {
            stage_names: (1..=8).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![57_600, 18_816, 25_088, 25_088, 3_456, 1_024, 512, 8],
            input_bytes: 12_288,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: 0.0,
            }],
        };
        let profile = DelayProfile::from_cloud_times(
            vec![1e-3, 1.5e-3, 1.2e-3, 1.2e-3, 8e-4, 3e-4, 1e-4, 5e-5],
            2e-4,
            10.0,
        );
        (desc, profile)
    }

    #[test]
    fn produces_nine_curves_with_monotone_nonincreasing_times() {
        let (desc, profile) = fixture();
        let curves = run(&desc, &profile, 11, 1e-9);
        assert_eq!(curves.len(), 9);
        for c in &curves {
            assert_eq!(c.points.len(), 11);
            // Optimal E[T] can only improve as exit probability grows.
            for w in c.points.windows(2) {
                assert!(
                    w[1].1 <= w[0].1 + 1e-12,
                    "gamma={} net={:?}: {} -> {}",
                    c.gamma,
                    c.network,
                    w[0].1,
                    w[1].1
                );
            }
        }
    }

    #[test]
    fn lower_bandwidth_more_sensitive_to_probability() {
        // Paper: "networks with lower bandwidth are more affected by
        // probability" — at gamma=10 the 3G reduction must exceed 4G's,
        // which must exceed Wi-Fi's.
        let (desc, profile) = fixture();
        let curves = run(&desc, &profile, 11, 1e-9);
        let get = |net: Profile| {
            curves
                .iter()
                .find(|c| c.gamma == 10.0 && c.network == net)
                .unwrap()
                .reduction_pct()
        };
        let (r3, r4, rw) = (get(Profile::ThreeG), get(Profile::FourG), get(Profile::WiFi));
        assert!(r3 > r4 && r4 > rw, "3G {r3:.1}% 4G {r4:.1}% WiFi {rw:.1}%");
    }

    #[test]
    fn p_one_equalizes_networks_at_low_gamma() {
        // Paper: "when the probability is one, all network technologies
        // have the same inference time".
        let (desc, profile) = fixture();
        let curves = run(&desc, &profile, 11, 1e-9);
        let at_one: Vec<f64> = Profile::ALL
            .iter()
            .map(|&net| {
                curves
                    .iter()
                    .find(|c| c.gamma == 10.0 && c.network == net)
                    .unwrap()
                    .points
                    .last()
                    .unwrap()
                    .1
            })
            .collect();
        assert!((at_one[0] - at_one[1]).abs() < 1e-12);
        assert!((at_one[1] - at_one[2]).abs() < 1e-12);
    }
}
