//! `branchyserve` — CLI entrypoint.
//!
//! Subcommands:
//!   profile  — measure per-stage t_i^c on this machine's PJRT runtime
//!   plan     — solve the partitioning problem, print the plan + sets
//!   serve    — run the TCP serving front-end with a chosen plan
//!   cloud-serve — run the remote cloud-stage server (the other half of
//!               a physically partitioned deployment; see --cloud-addr)
//!   fig4/fig5/fig6 — regenerate the paper's figures as tables/CSV
//!   ablation — strategy-gap / epsilon / branch-placement studies

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use branchyserve::cli::{Cli, Command, Flag, Invocation, Parsed};
use branchyserve::config::settings::{validate_host_port, Flavor, Settings, Strategy};
use branchyserve::experiments::{ablation, fig4, fig5, fig6};
use branchyserve::fleet::{ClassProfile, ClassRegistry, Fleet, FleetConfig, RoutePolicy};
use branchyserve::harness::Table;
use branchyserve::model::{BranchDesc, Manifest};
use branchyserve::network::bandwidth::{LinkModel, Profile};
use branchyserve::network::{BandwidthTrace, WireEncoding};
use branchyserve::partition;
use branchyserve::planner::{AdaptiveConfig, EstimatorConfig, JointSearchSpace, Planner};
use branchyserve::profiler::{self, ProfileOptions, ProfileReport};
use branchyserve::runtime::InferenceEngine;
use branchyserve::scenario::{self, ScenarioSpec};
use branchyserve::server::{
    CloudStageServer, RemoteCloudConfig, RemoteCloudEngine, Server, ServerConfig,
};
use branchyserve::util::logger;
use branchyserve::util::timefmt::format_secs;

fn cli() -> Cli {
    Cli {
        program: "branchyserve",
        about: "BranchyNet edge/cloud partitioning + serving (Pacheco & Couto, ISCC 2020)",
        global_flags: vec![
            Flag::value("config", "TOML config file").short('c'),
            Flag::value("artifacts", "artifacts directory").default("artifacts"),
            Flag::value("flavor", "kernel flavor: ref|pl").default("ref"),
        ],
        commands: vec![
            Command::new("profile", "measure per-stage cloud times on this host")
                .flag(Flag::value("out", "write profile JSON here").default("artifacts/profile.json"))
                .flag(Flag::value("iters", "timed iterations per stage").default("15"))
                .flag(Flag::value("batch", "batch size to profile").default("1")),
            Command::new("plan", "solve the partitioning problem")
                .flag(Flag::value("network", "3g|4g|wifi").default("4g"))
                .flag(Flag::value("gamma", "edge processing factor").default("100"))
                .flag(Flag::value("probability", "side-branch exit probability").default("0.5"))
                .flag(Flag::value("strategy", "shortest-path|brute|neurosurgeon|edge|cloud").default("shortest-path"))
                .flag(Flag::value("profile", "profile JSON (else measured now)"))
                .flag(Flag::switch("all", "print every strategy for comparison"))
                .flag(Flag::switch(
                    "joint",
                    "also run the joint search: branch placement x wire encoding x split",
                )),
            Command::new("serve", "run the sharded multi-class TCP serving fleet")
                .flag(Flag::value("port", "TCP port (0 = auto)").default("7878"))
                .flag(Flag::value("network", "default class when no [[link_class]] config: 3g|4g|wifi").default("4g"))
                .flag(Flag::value("gamma", "edge processing factor").default("100"))
                // No CLI default: a default here would mask the
                // [branch] exit_probability config fallback.
                .flag(Flag::value("probability", "planning exit probability (default 0.5)"))
                .flag(Flag::value("threshold", "entropy exit threshold (nats)").default("0.3"))
                .flag(Flag::value("profile", "profile JSON (else measured now)"))
                .flag(Flag::value("shards", "edge/cloud pipeline pairs per link class"))
                .flag(Flag::value("cloud-workers", "cloud worker threads per shard"))
                .flag(Flag::value("routing", "round-robin|hash|least-loaded"))
                .flag(Flag::switch(
                    "autoscale",
                    "grow/shrink each class's shards from queue depth and rejections",
                ))
                .flag(Flag::value("min-shards", "autoscale floor (default 1)"))
                .flag(Flag::value("max-shards", "autoscale ceiling (default 8)"))
                .flag(Flag::switch(
                    "per-request",
                    "plan each request at the instantaneous link estimate",
                ))
                .flag(Flag::switch(
                    "estimate-exit-rate",
                    "track observed exit rates and replan on drift",
                ))
                .flag(Flag::value(
                    "drift-threshold",
                    "exit-rate drift that triggers a replan",
                ))
                .flag(Flag::value(
                    "probe-fraction",
                    "fraction of per-request plans probed through a branch-active split",
                ))
                .flag(Flag::value(
                    "cloud-addr",
                    "HOST:PORT of a cloud-serve instance; cloud stages run there",
                ))
                .flag(Flag::switch(
                    "tier-chain",
                    "route cloud stages through the config's [[tier]] chain (K-tier partition)",
                ))
                .flag(Flag::value(
                    "wire-encoding",
                    "activation transfer codec to the cloud stage: raw|q8|q4",
                ))
                .flag(Flag::value("bind", "listen address").default("127.0.0.1"))
                .flag(Flag::switch(
                    "reactor",
                    "serve with the event-driven epoll front end (Linux)",
                ))
                .flag(Flag::value("reactor-threads", "reactor event-loop threads (default 1)"))
                .flag(Flag::value(
                    "max-conns",
                    "shed connections over this cap with THROTTLE (0 = unlimited)",
                ))
                .flag(Flag::value(
                    "conn-window",
                    "per-connection in-flight request window, reactor path (default 32)",
                ))
                .flag(Flag::switch("sim", "serve the simulated model (no artifacts needed)"))
                .flag(Flag::value("sim-stage-cost-us", "synthetic per-stage compute cost, us").default("200")),
            Command::new(
                "cloud-serve",
                "run the remote cloud-stage server (suffix layers over TCP)",
            )
                .flag(Flag::value("port", "TCP port (0 = auto)").default("7879"))
                .flag(Flag::value("bind", "listen address").default("0.0.0.0"))
                .flag(Flag::value(
                    "forward-addr",
                    "HOST:PORT of the next tier; this server runs its chain segment and forwards the rest",
                ))
                .flag(Flag::value(
                    "forward-encoding",
                    "activation codec on the forwarded hop: raw|q8|q4 (default raw)",
                ))
                .flag(Flag::value(
                    "max-conns",
                    "shed connections over this cap with THROTTLE (0 = unlimited)",
                ))
                .flag(Flag::switch("sim", "serve the simulated model (no artifacts needed)"))
                .flag(Flag::value("sim-stage-cost-us", "synthetic per-stage compute cost, us").default("200")),
            Command::new(
                "scenario",
                "replay a declarative scenario file against a deterministic fleet twin",
            )
            .flag(Flag::value("seed", "override the file's [scenario] seed"))
            .flag(Flag::value(
                "out",
                "benchmark JSON path (default BENCH_scenario_<name>.json)",
            )),
            Command::new("fig4", "inference time vs exit probability (paper Fig. 4)")
                .flag(Flag::value("points", "probability grid points").default("21"))
                .flag(Flag::value("profile", "profile JSON (else measured now)"))
                .flag(Flag::switch("csv", "emit CSV instead of a table")),
            Command::new("fig5", "partition layer vs processing factor (paper Fig. 5)")
                .flag(Flag::value("points", "gamma grid points").default("30"))
                .flag(Flag::value("max-gamma", "largest gamma").default("1000"))
                .flag(Flag::value("profile", "profile JSON (else measured now)"))
                .flag(Flag::switch("csv", "emit CSV instead of a table")),
            Command::new("fig6", "exit probability vs entropy threshold (paper Fig. 6)")
                .flag(Flag::value("points", "threshold grid points").default("15"))
                .flag(Flag::switch("csv", "emit CSV instead of a table")),
            Command::new("ablation", "strategy gap / epsilon / branch placement")
                .flag(Flag::value("network", "3g|4g|wifi").default("4g"))
                .flag(Flag::value("gamma", "edge processing factor").default("100"))
                .flag(Flag::value("probability", "side-branch exit probability").default("0.5"))
                .flag(Flag::value("profile", "profile JSON (else measured now)")),
        ],
    }
}

fn main() {
    logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    match cli().parse(args) {
        Ok(Parsed::Help(text)) => print!("{text}"),
        Ok(Parsed::Run(inv)) => {
            if let Err(e) = dispatch(&inv) {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
}

fn dispatch(inv: &Invocation) -> Result<()> {
    let mut settings = Settings::load(inv.get("config").map(Path::new))?;
    if let Some(dir) = inv.get("artifacts") {
        settings.model.artifacts_dir = PathBuf::from(dir);
    }
    if let Some(f) = inv.get("flavor") {
        settings.model.flavor = Flavor::parse(f)?;
    }
    match inv.command.as_str() {
        "profile" => cmd_profile(inv, &settings),
        "plan" => cmd_plan(inv, &settings),
        "serve" => cmd_serve(inv, &settings),
        "cloud-serve" => cmd_cloud_serve(inv, &settings),
        "scenario" => cmd_scenario(inv),
        "fig4" => cmd_fig4(inv, &settings),
        "fig5" => cmd_fig5(inv, &settings),
        "fig6" => cmd_fig6(inv, &settings),
        "ablation" => cmd_ablation(inv, &settings),
        other => anyhow::bail!("unhandled command {other}"),
    }
}

fn get_f64(inv: &Invocation, name: &str) -> Result<Option<f64>> {
    inv.get_f64(name).map_err(anyhow::Error::msg)
}

fn get_usize(inv: &Invocation, name: &str) -> Result<Option<usize>> {
    inv.get_usize(name).map_err(anyhow::Error::msg)
}

fn open_engine(settings: &Settings) -> Result<InferenceEngine> {
    let manifest = Manifest::load(&settings.model.artifacts_dir)?;
    InferenceEngine::open(
        &settings.model.artifacts_dir,
        manifest,
        settings.model.flavor,
        "main",
    )
}

/// Load a saved profile or measure one now.
fn load_or_measure_profile(
    inv: &Invocation,
    settings: &Settings,
    engine: Option<&InferenceEngine>,
) -> Result<ProfileReport> {
    if let Some(path) = inv.get("profile") {
        return ProfileReport::load(Path::new(path));
    }
    let default = settings.model.artifacts_dir.join("profile.json");
    if default.exists() {
        return ProfileReport::load(&default);
    }
    log::info!("no saved profile; measuring now (use `branchyserve profile` to cache)");
    let owned;
    let engine = match engine {
        Some(e) => e,
        None => {
            owned = open_engine(settings)?;
            &owned
        }
    };
    profiler::measure(engine, ProfileOptions::default())
}

fn link_from(inv: &Invocation, settings: &Settings) -> Result<LinkModel> {
    match inv.get("network") {
        Some(name) => Ok(LinkModel::from_profile(Profile::parse(name)?)),
        // Config values should fail fast on nonsense, not silently
        // clamp like measured samples do.
        None => LinkModel::try_new(settings.network.uplink_mbps, settings.network.rtt_s),
    }
}

fn cmd_profile(inv: &Invocation, settings: &Settings) -> Result<()> {
    let engine = open_engine(settings)?;
    let compile_s = engine.warmup()?;
    log::info!(
        "warmup compiled {} executables in {compile_s:.2}s",
        engine.cached_count()
    );
    let opts = ProfileOptions {
        iters: get_usize(inv, "iters")?.unwrap_or(15),
        batch: get_usize(inv, "batch")?.unwrap_or(1),
        ..Default::default()
    };
    let report = profiler::measure(&engine, opts)?;
    let mut table = Table::new(&["stage", "t_cloud", "min", "max"]);
    for s in report.stages.iter().chain(std::iter::once(&report.branch)) {
        table.row(vec![
            s.name.clone(),
            format_secs(s.t_cloud_s),
            format_secs(s.min_s),
            format_secs(s.max_s),
        ]);
    }
    println!("{}", table.render());
    let out = PathBuf::from(inv.get("out").unwrap_or("artifacts/profile.json"));
    report.save(&out)?;
    println!("profile written to {}", out.display());
    Ok(())
}

fn planning_inputs(
    inv: &Invocation,
    settings: &Settings,
) -> Result<(Manifest, branchyserve::timing::DelayProfile, LinkModel, f64)> {
    let manifest = Manifest::load(&settings.model.artifacts_dir)?;
    let report = load_or_measure_profile(inv, settings, None)?;
    let gamma = get_f64(inv, "gamma")?.unwrap_or(settings.edge.gamma);
    let profile = report.to_delay_profile(gamma);
    let link = link_from(inv, settings)?;
    let p = get_f64(inv, "probability")?
        .or(settings.branch.exit_probability)
        .unwrap_or(0.5);
    Ok((manifest, profile, link, p))
}

fn cmd_plan(inv: &Invocation, settings: &Settings) -> Result<()> {
    let (manifest, profile, link, p) = planning_inputs(inv, settings)?;
    let desc = manifest.to_desc(p);
    let strategies: Vec<Strategy> = if inv.has("all") {
        vec![
            Strategy::ShortestPath,
            Strategy::BruteForce,
            Strategy::Neurosurgeon,
            Strategy::EdgeOnly,
            Strategy::CloudOnly,
        ]
    } else {
        vec![Strategy::parse(inv.get("strategy").unwrap_or("shortest-path"))?]
    };
    let mut table = Table::new(&["strategy", "split after", "E[T]", "transfer bytes"]);
    for st in strategies {
        let plan = partition::plan_with_strategy(
            st,
            &desc,
            &profile,
            link,
            settings.partition.epsilon,
            true,
        );
        table.row(vec![
            st.as_str().to_string(),
            plan.split_label(&desc),
            format_secs(plan.expected_time_s),
            plan.transfer_bytes.to_string(),
        ]);
        if st == Strategy::ShortestPath {
            let (v_e, v_c) = plan.partition_sets(&desc);
            println!("V_e = {v_e:?}");
            println!("V_c = {v_c:?}");
        }
    }
    println!("{}", table.render());
    if inv.has("joint") {
        let planner = Planner::new(&desc, &profile, settings.partition.epsilon, true);
        let space = JointSearchSpace {
            branch_sets: ablation::branch_set_candidates(&desc, p),
            encodings: WireEncoding::ALL.to_vec(),
            min_accuracy_proxy: settings.planner.min_accuracy_proxy,
        };
        let joint = planner.plan_joint(link, &space);
        let fixed = planner.plan_for(link);
        println!(
            "joint search: {} branch set(s) x {} encoding(s), accuracy floor {} \
             ({} set(s) pruned)",
            space.branch_sets.len(),
            space.encodings.len(),
            space.min_accuracy_proxy,
            joint.pruned,
        );
        let mut jt = Table::new(&["rank", "branches", "encoding", "split after", "E[T]", "proxy"]);
        for (i, c) in joint.ranked.iter().take(10).enumerate() {
            jt.row(vec![
                (i + 1).to_string(),
                format_branch_set(&c.branch_set),
                c.encoding.as_str().to_string(),
                c.split.to_string(),
                format_secs(c.expected_time),
                format!("{:.3}", c.accuracy_proxy),
            ]);
        }
        println!("{}", jt.render());
        println!(
            "joint best {} vs fixed plan {} ({:+.2}%)",
            format_secs(joint.expected_time),
            format_secs(fixed.expected_time_s),
            (joint.expected_time / fixed.expected_time_s - 1.0) * 100.0,
        );
    }
    Ok(())
}

/// `@pos(p)` list for a joint-search candidate, `-` for branch-free.
fn format_branch_set(set: &[BranchDesc]) -> String {
    if set.is_empty() {
        return "-".to_string();
    }
    set.iter()
        .map(|b| format!("@{}({})", b.after_stage, b.exit_prob))
        .collect::<Vec<_>>()
        .join(" ")
}

/// The simulated B-AlexNet stand-in the `--sim` serving path runs.
fn sim_manifest() -> Manifest {
    Manifest::synthetic_sim(
        "sim-balexnet",
        vec![3, 32, 32],
        &[2048, 1024, 512, 128, 2],
        1,
        2,
        vec![1, 2, 4, 8],
    )
    .expect("static sim manifest spec is valid")
}

fn cmd_serve(inv: &Invocation, settings: &Settings) -> Result<()> {
    let sim = inv.has("sim");
    let gamma = get_f64(inv, "gamma")?.unwrap_or(settings.edge.gamma);
    let threshold =
        get_f64(inv, "threshold")?.unwrap_or(settings.branch.entropy_threshold) as f32;
    let default_p = get_f64(inv, "probability")?
        .or(settings.branch.exit_probability)
        .unwrap_or(0.5);
    let shards = get_usize(inv, "shards")?.unwrap_or(settings.fleet.shards);
    let cloud_workers =
        get_usize(inv, "cloud-workers")?.unwrap_or(settings.fleet.cloud_workers);
    let routing = match inv.get("routing") {
        Some(r) => RoutePolicy::parse(r)?,
        None => RoutePolicy::parse(&settings.fleet.routing)?,
    };
    let per_request = inv.has("per-request") || settings.fleet.per_request_planning;
    let autoscale = if inv.has("autoscale") || settings.fleet.autoscale {
        let mut acfg = settings.fleet.autoscale_config()?;
        if let Some(lo) = get_usize(inv, "min-shards")? {
            acfg.min_shards = lo;
        }
        if let Some(hi) = get_usize(inv, "max-shards")? {
            acfg.max_shards = hi;
        }
        acfg.validate()?;
        if !(acfg.min_shards..=acfg.max_shards).contains(&shards) {
            anyhow::bail!(
                "--shards {} must lie within --min-shards..=--max-shards ({}..={})",
                shards,
                acfg.min_shards,
                acfg.max_shards
            );
        }
        Some(acfg)
    } else {
        if get_usize(inv, "min-shards")?.is_some() || get_usize(inv, "max-shards")?.is_some() {
            anyhow::bail!(
                "--min-shards/--max-shards require --autoscale (or [fleet] autoscale = true); \
                 without it the shard count is fixed at --shards"
            );
        }
        None
    };
    let probe_fraction =
        get_f64(inv, "probe-fraction")?.unwrap_or(settings.fleet.probe_fraction);
    let cloud_addr = inv
        .get("cloud-addr")
        .map(str::to_string)
        .or_else(|| settings.fleet.cloud_addr.clone());
    if let Some(addr) = &cloud_addr {
        // The TOML path was validated at load; the CLI value needs the
        // same check or a typo silently serves local-only forever.
        if let Err(e) = validate_host_port(addr) {
            anyhow::bail!("--cloud-addr: {e}");
        }
    }
    let wire_encoding = match inv.get("wire-encoding") {
        Some(s) => WireEncoding::parse(s)?,
        None => settings.fleet.wire_encoding,
    };
    let tier_chain = if inv.has("tier-chain") {
        if settings.tiers.is_empty() {
            anyhow::bail!(
                "--tier-chain needs [[tier]] entries in the config file \
                 (the chain topology is not expressible as flags)"
            );
        }
        settings.tiers.clone()
    } else {
        if !settings.tiers.is_empty() {
            println!(
                "note: config has {} [[tier]] entries but --tier-chain was not given — \
                 serving without a chain",
                settings.tiers.len()
            );
        }
        Vec::new()
    };
    let estimation = if inv.has("estimate-exit-rate") || settings.fleet.online_estimation {
        let cfg = EstimatorConfig {
            drift_threshold: get_f64(inv, "drift-threshold")?
                .unwrap_or(settings.fleet.drift_threshold),
            ..EstimatorConfig::default()
        };
        cfg.validate()?;
        Some(cfg)
    } else {
        None
    };
    let sim_cost =
        Duration::from_micros(get_usize(inv, "sim-stage-cost-us")?.unwrap_or(200) as u64);

    // Model + one engine pair per shard. Sim shards share nothing; PJRT
    // shards each get their own pair of PJRT clients.
    let manifest = if sim {
        sim_manifest()
    } else {
        Manifest::load(&settings.model.artifacts_dir)?
    };
    // `Send + Sync`: the fleet retains the factory so the autoscaler
    // can provision shards long after startup, from its own thread.
    type EngineFactory =
        Box<dyn Fn(&str) -> Result<(InferenceEngine, InferenceEngine)> + Send + Sync>;
    let make_engines: EngineFactory = if sim {
        let m = manifest.clone();
        Box::new(move |label: &str| {
            Ok((
                InferenceEngine::open_sim_with_cost(m.clone(), &format!("{label}-edge"), sim_cost)?,
                InferenceEngine::open_sim_with_cost(
                    m.clone(),
                    &format!("{label}-cloud"),
                    sim_cost,
                )?,
            ))
        })
    } else {
        let dir = settings.model.artifacts_dir.clone();
        let flavor = settings.model.flavor;
        let m = manifest.clone();
        Box::new(move |label: &str| {
            let edge = InferenceEngine::open(&dir, m.clone(), flavor, &format!("{label}-edge"))?;
            let cloud = InferenceEngine::open(&dir, m.clone(), flavor, &format!("{label}-cloud"))?;
            let compile_s = edge.warmup()? + cloud.warmup()?;
            log::info!("[{label}] precompiled artifacts in {compile_s:.2}s");
            Ok((edge, cloud))
        })
    };

    // Per-stage delays: saved/measured profile for real artifacts,
    // measured on a probe engine for the sim. When a PJRT measurement is
    // needed, the probe pair is handed to the fleet as its first shard
    // instead of leaking a third warmed-up PJRT client. Mutex (not
    // RefCell): the fleet's factory closure must be `Sync` now that
    // autoscaling can invoke it from the control-loop thread.
    let spare_pair: std::sync::Mutex<Option<(InferenceEngine, InferenceEngine)>> =
        std::sync::Mutex::new(None);
    let report = if sim {
        let probe = InferenceEngine::open_sim_with_cost(manifest.clone(), "profile", sim_cost)?;
        profiler::measure(&probe, ProfileOptions::default())?
    } else {
        let saved = inv.get("profile").map(PathBuf::from).or_else(|| {
            let cached = settings.model.artifacts_dir.join("profile.json");
            cached.exists().then_some(cached)
        });
        match saved {
            Some(path) => ProfileReport::load(&path)?,
            None => {
                log::info!(
                    "no saved profile; measuring on the first shard's edge engine \
                     (use `branchyserve profile` to cache)"
                );
                let pair = make_engines("shard-probe")?;
                let r = profiler::measure(&pair.0, ProfileOptions::default())?;
                *spare_pair.lock().unwrap() = Some(pair);
                r
            }
        }
    };
    let delay = report.to_delay_profile(gamma);

    // Link classes: `[[link_class]]` config entries, or one default
    // class from --network / [network].
    let registry = if settings.link_classes.is_empty() {
        let link = link_from(inv, settings)?;
        let name = inv
            .get("network")
            .map(str::to_string)
            .unwrap_or_else(|| settings.network.kind.clone());
        let mut class = ClassProfile {
            name,
            link,
            trace: None,
            exit_probability: None,
            cloud_addr: None,
            min_shards: None,
            max_shards: None,
            joint_search: None,
        };
        if let Some(path) = &settings.network.trace {
            println!(
                "bandwidth trace {} — adaptive replanning enabled",
                path.display()
            );
            class = class.with_trace(BandwidthTrace::load(path)?);
        }
        ClassRegistry::single(class)
    } else {
        if settings.network.trace.is_some() {
            // Say so loudly: the old single-pipeline path honored the
            // trace, and per-class TOML traces don't exist yet.
            log::warn!(
                "[network] trace is ignored when [[link_class]] entries are \
                 configured (per-class traces are not expressible in TOML yet)"
            );
            println!(
                "warning: [network] trace ignored with [[link_class]] — \
                 adaptive replanning disabled"
            );
        }
        ClassRegistry::from_settings(&settings.link_classes)?
    };
    let adaptive = registry
        .iter()
        .any(|c| c.trace.is_some())
        .then(AdaptiveConfig::default);

    let fleet = Arc::new(Fleet::start(
        registry,
        &manifest,
        &delay,
        FleetConfig {
            shards_per_class: shards,
            cloud_workers_per_shard: cloud_workers,
            routing,
            entropy_threshold: threshold,
            max_batch: settings.serve.max_batch,
            batch_timeout: Duration::from_secs_f64(settings.serve.batch_timeout_ms / 1e3),
            queue_capacity: settings.serve.queue_capacity,
            default_exit_prob: default_p,
            epsilon: settings.partition.epsilon,
            adaptive,
            autoscale: autoscale.clone(),
            autoscale_external: false,
            max_total_shards: settings.fleet.max_total_shards,
            estimation,
            per_request_planning: per_request,
            probe_fraction,
            cloud_addr: cloud_addr.clone(),
            tier_chain: tier_chain.clone(),
            wire_encoding,
            joint_search: settings.planner.joint_search,
            min_accuracy_proxy: settings.planner.min_accuracy_proxy,
            channel_jitter: 0.0,
            real_time_channel: true,
        },
        move |label: &str| {
            // The profiling probe becomes the first shard.
            if let Some(pair) = spare_pair.lock().unwrap().take() {
                return Ok(pair);
            }
            make_engines(label)
        },
    )?);

    for c in &fleet.report().classes {
        let cloud = match &c.cloud_addr {
            Some(a) => format!(" -> {a}"),
            None => String::new(),
        };
        let cuts = match &c.cuts {
            Some(v) => format!(" (chain cuts {v:?})"),
            None => String::new(),
        };
        println!(
            "class {:>10} @ {:>9.2} Mbps -> split after {:>2}{}, {} wire \
             ({} shard(s) x {} cloud worker(s)){}",
            c.name,
            c.link.uplink_mbps,
            c.split_after,
            cuts,
            c.wire_encoding,
            c.shards.len(),
            cloud_workers,
            cloud,
        );
    }
    println!(
        "per-request planning: {}   exit-rate estimation: {}   probe fraction: {}",
        if per_request { "on" } else { "off" },
        match estimation {
            Some(cfg) => format!("on (drift threshold {})", cfg.drift_threshold),
            None => "off".to_string(),
        },
        probe_fraction,
    );
    match &autoscale {
        Some(a) => println!(
            "autoscale: on ({}..={} shards per class, up at depth {}, down at {}, \
             cooldown {:?})",
            a.min_shards, a.max_shards, a.scale_up_depth, a.scale_down_depth, a.cooldown,
        ),
        None => println!("autoscale: off (fixed {shards} shard(s) per class)"),
    }
    if tier_chain.is_empty() {
        match &cloud_addr {
            Some(addr) => println!(
                "cloud stages: remote @ {addr} (local fallback on failure) — \
                 run `branchyserve cloud-serve` there"
            ),
            None => println!("cloud stages: in-process"),
        }
    } else {
        let hops: Vec<&str> = tier_chain.iter().map(|t| t.addr.as_str()).collect();
        println!(
            "cloud stages: {}-tier chain, edge -> {} — run `branchyserve cloud-serve \
             --forward-addr NEXT` on every tier but the last (head failures degrade \
             to a direct hop to {})",
            tier_chain.len() + 1,
            hops.join(" -> "),
            hops[hops.len() - 1],
        );
    }
    println!("activation wire encoding: {wire_encoding} (planner prices transfers at this codec)");
    println!(
        "startup joint search: {}",
        if settings.planner.joint_search {
            format!(
                "on (encoding x split per class, accuracy floor {})",
                settings.planner.min_accuracy_proxy
            )
        } else {
            "off (enable with [planner] joint_search = true)".to_string()
        }
    );

    let port = get_usize(inv, "port")?.unwrap_or(7878) as u16;
    let bind = inv.get("bind").unwrap_or("127.0.0.1");
    let server_cfg = server_config_from(inv, settings)?;
    let reactor = server_cfg.reactor;
    let handle = Server::with_config(fleet.clone(), server_cfg).start_on(bind, port)?;
    println!(
        "serving on {} ({}) — Ctrl-C to stop",
        handle.addr(),
        if reactor { "reactor" } else { "thread-per-connection" },
    );
    loop {
        std::thread::sleep(Duration::from_secs(10));
        println!("{}", fleet.report().summary());
    }
}

/// Front-end tuning from CLI flags over `[fleet]` config defaults.
fn server_config_from(inv: &Invocation, settings: &Settings) -> Result<ServerConfig> {
    Ok(ServerConfig {
        reactor: inv.has("reactor") || settings.fleet.reactor,
        reactor_threads: get_usize(inv, "reactor-threads")?
            .unwrap_or(settings.fleet.reactor_threads),
        max_conns: get_usize(inv, "max-conns")?.unwrap_or(settings.fleet.max_conns),
        conn_window: get_usize(inv, "conn-window")?.unwrap_or(settings.fleet.conn_window),
    })
}

/// The cloud half of a physically partitioned deployment: an accept
/// loop over a [`CloudStageServer`] that executes the suffix stages
/// `split+1..=N` of every INFER_PARTIAL frame an edge `serve
/// --cloud-addr` instance ships to it. No planner runs here — each
/// frame carries its own cut. With `--forward-addr` the server is a
/// *middle* tier of a K-tier chain: it runs only its own segment of
/// each INFER_CHAIN frame and ships the remainder to the next tier.
fn cmd_cloud_serve(inv: &Invocation, settings: &Settings) -> Result<()> {
    let sim = inv.has("sim");
    let sim_cost =
        Duration::from_micros(get_usize(inv, "sim-stage-cost-us")?.unwrap_or(200) as u64);
    let engine = if sim {
        InferenceEngine::open_sim_with_cost(sim_manifest(), "cloud", sim_cost)?
    } else {
        let manifest = Manifest::load(&settings.model.artifacts_dir)?;
        let engine = InferenceEngine::open(
            &settings.model.artifacts_dir,
            manifest,
            settings.model.flavor,
            "cloud",
        )?;
        let compile_s = engine.warmup()?;
        log::info!("precompiled artifacts in {compile_s:.2}s");
        engine
    };
    println!(
        "cloud-stage server: {} stages, batch sizes {:?}",
        engine.manifest().num_stages(),
        engine.manifest().batch_sizes,
    );

    let mut stage_server = CloudStageServer::new(engine);
    if let Some(addr) = inv.get("forward-addr") {
        if let Err(e) = validate_host_port(addr) {
            anyhow::bail!("--forward-addr: {e}");
        }
        let mut rcfg = RemoteCloudConfig::new(addr.to_string());
        if let Some(enc) = inv.get("forward-encoding") {
            rcfg.encoding = WireEncoding::parse(enc)?;
        }
        let encoding = rcfg.encoding;
        stage_server = stage_server.with_forward(Arc::new(RemoteCloudEngine::new(rcfg)));
        println!(
            "forwarding tier: chain tails ship onward to {addr} ({encoding} on that hop)"
        );
    } else if inv.get("forward-encoding").is_some() {
        anyhow::bail!("--forward-encoding requires --forward-addr");
    }
    let server = Arc::new(stage_server);
    let port = get_usize(inv, "port")?.unwrap_or(7879) as u16;
    let bind = inv.get("bind").unwrap_or("0.0.0.0");
    let cfg = ServerConfig {
        max_conns: get_usize(inv, "max-conns")?.unwrap_or(settings.fleet.max_conns),
        ..ServerConfig::default()
    };
    let handle = Server::with_config(server.clone(), cfg).start_on(bind, port)?;
    println!(
        "cloud-serving on {} — point an edge at it with \
         `branchyserve serve --cloud-addr HOST:{}` — Ctrl-C to stop",
        handle.addr(),
        handle.addr().port(),
    );
    loop {
        std::thread::sleep(Duration::from_secs(10));
        let (batches, samples, gated, full, errors) = server.counters();
        let (chain, forwarded) = server.chain_counters();
        println!(
            "partial batches {batches} ({samples} samples, {gated} gated), \
             chain batches {chain} ({forwarded} forwarded), \
             full infers {full}, errors {errors}, splits served {:?}",
            server.splits_served(),
        );
    }
}

/// `scenario run <file.toml>` — replay a declarative scenario against
/// a real fleet in deterministic virtual time, write the
/// `BENCH_scenario_<name>.json`, and print the SLO verdicts. Exits
/// nonzero when any SLO check fails — *after* writing the JSON, so CI
/// always gets the artifact to diff.
fn cmd_scenario(inv: &Invocation) -> Result<()> {
    let usage = "usage: branchyserve scenario run <file.toml> [--seed N] [--out PATH]";
    let (verb, file) = match inv.positionals.as_slice() {
        [verb, file] => (verb.as_str(), file.as_str()),
        _ => anyhow::bail!("{usage}"),
    };
    if verb != "run" {
        anyhow::bail!("unknown scenario verb '{verb}' — {usage}");
    }

    let spec = ScenarioSpec::load(Path::new(file))?;
    let seed = get_usize(inv, "seed")?.map(|s| s as u64);
    let outcome = scenario::run(&spec, seed)?;

    let out_path = match inv.get("out") {
        Some(p) => PathBuf::from(p),
        None => PathBuf::from(format!("BENCH_scenario_{}.json", outcome.name)),
    };
    std::fs::write(&out_path, outcome.json.to_string_pretty() + "\n")?;

    println!("scenario '{}' (seed {}) — {}", outcome.name, outcome.seed, out_path.display());
    let mut table = Table::new(&["check", "verdict", "detail"]);
    for c in &outcome.checks {
        let verdict = if c.pass { "PASS" } else { "FAIL" };
        table.row(vec![c.name.clone(), verdict.to_string(), c.detail.clone()]);
    }
    print!("{}", table.render());

    if !outcome.passed {
        let failed = outcome.checks.iter().filter(|c| !c.pass).count();
        anyhow::bail!("{failed} SLO check(s) failed (JSON written to {})", out_path.display());
    }
    println!("all {} SLO checks passed", outcome.checks.len());
    Ok(())
}

fn cmd_fig4(inv: &Invocation, settings: &Settings) -> Result<()> {
    let (manifest, profile, _, _) = planning_inputs(inv, settings)?;
    let desc = manifest.to_desc(0.0);
    let points = get_usize(inv, "points")?.unwrap_or(21);
    let curves = fig4::run(&desc, &profile, points, settings.partition.epsilon);

    for &gamma in &fig4::GAMMAS {
        let mut table = Table::new(&[
            "p", "3G E[T]", "4G E[T]", "WiFi E[T]", "3G split", "4G split", "WiFi split",
        ]);
        let get =
            |net: Profile| curves.iter().find(|c| c.gamma == gamma && c.network == net).unwrap();
        let (c3, c4, cw) = (get(Profile::ThreeG), get(Profile::FourG), get(Profile::WiFi));
        for i in 0..points {
            table.row(vec![
                format!("{:.2}", c3.points[i].0),
                format_secs(c3.points[i].1),
                format_secs(c4.points[i].1),
                format_secs(cw.points[i].1),
                c3.points[i].2.to_string(),
                c4.points[i].2.to_string(),
                cw.points[i].2.to_string(),
            ]);
        }
        println!("\nFig. 4 — gamma = {gamma}");
        if inv.has("csv") {
            println!("{}", table.to_csv());
        } else {
            println!("{}", table.render());
        }
        println!(
            "reduction p=0 -> p=1: 3G {:.2}%  4G {:.2}%  WiFi {:.2}%",
            c3.reduction_pct(),
            c4.reduction_pct(),
            cw.reduction_pct()
        );
    }
    Ok(())
}

fn cmd_fig5(inv: &Invocation, settings: &Settings) -> Result<()> {
    let (manifest, profile, _, _) = planning_inputs(inv, settings)?;
    let desc = manifest.to_desc(0.0);
    let points = get_usize(inv, "points")?.unwrap_or(30);
    let max_gamma = get_f64(inv, "max-gamma")?.unwrap_or(1000.0);
    let gammas = fig5::gamma_grid(points, max_gamma);
    let curves = fig5::run(&desc, &profile, &gammas, settings.partition.epsilon);

    for net in [Profile::ThreeG, Profile::FourG] {
        let mut headers = vec!["gamma".to_string()];
        headers.extend(fig5::PROBABILITIES.iter().map(|p| format!("p={p}")));
        let headers_ref: Vec<&str> = headers.iter().map(String::as_str).collect();
        let mut table = Table::new(&headers_ref);
        for (i, &gamma) in gammas.iter().enumerate() {
            let mut row = vec![format!("{gamma:.1}")];
            for &p in &fig5::PROBABILITIES {
                let c = curves
                    .iter()
                    .find(|c| c.network == net && c.probability == p)
                    .unwrap();
                row.push(c.points[i].2.clone());
            }
            table.row(row);
        }
        println!("\nFig. 5 — {} (chosen partition layer)", net.name());
        if inv.has("csv") {
            println!("{}", table.to_csv());
        } else {
            println!("{}", table.render());
        }
    }
    Ok(())
}

fn cmd_fig6(inv: &Invocation, settings: &Settings) -> Result<()> {
    let engine = open_engine(settings)?;
    let results = fig6::run(&engine)?;
    let points = get_usize(inv, "points")?.unwrap_or(15);
    let max_nats = engine.manifest().entropy_max_nats;

    let mut headers = vec!["threshold".to_string()];
    headers.extend(
        results
            .iter()
            .map(|r| format!("{} (k={})", r.level, r.blur_ksize)),
    );
    let headers_ref: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut table = Table::new(&headers_ref);
    for i in 0..points {
        let thr = i as f64 / (points - 1) as f64 * max_nats;
        let mut row = vec![format!("{thr:.3}")];
        for r in &results {
            row.push(format!("{:.3}", r.exit_probability(thr)));
        }
        table.row(row);
    }
    println!("\nFig. 6 — P[classified at side branch] vs entropy threshold");
    if inv.has("csv") {
        println!("{}", table.to_csv());
    } else {
        println!("{}", table.render());
    }
    for r in &results {
        println!(
            "{:>5} (k={:>2}): mean entropy {:.4} nats, branch accuracy {:.3}",
            r.level,
            r.blur_ksize,
            r.entropies.iter().map(|&e| e as f64).sum::<f64>() / r.entropies.len() as f64,
            r.branch_accuracy
        );
    }
    Ok(())
}

fn cmd_ablation(inv: &Invocation, settings: &Settings) -> Result<()> {
    let (manifest, profile, link, p) = planning_inputs(inv, settings)?;
    let desc = manifest.to_desc(p);

    println!("\n== strategy gap ==");
    let gaps =
        ablation::strategy_gap(&desc, &profile, &[0.0, 0.5, 0.9], &[10.0, 100.0, 1000.0]);
    let mut table = Table::new(&[
        "p", "gamma", "net", "solver", "neurosurgeon", "edge-only", "cloud-only", "max speedup",
    ]);
    for g in &gaps {
        let t = |st: Strategy| {
            g.rows
                .iter()
                .find(|r| r.0 == st)
                .map(|r| format_secs(r.2))
                .unwrap_or_default()
        };
        table.row(vec![
            format!("{:.1}", g.probability),
            format!("{}", g.gamma),
            g.network.name().to_string(),
            t(Strategy::ShortestPath),
            t(Strategy::Neurosurgeon),
            t(Strategy::EdgeOnly),
            t(Strategy::CloudOnly),
            format!("{:.2}x", g.max_speedup()),
        ]);
    }
    println!("{}", table.render());

    println!("== epsilon sensitivity ==");
    let eps = ablation::epsilon_sensitivity(
        &desc,
        &profile,
        link,
        &[1e-12, 1e-10, 1e-9, 1e-7, 1e-5],
    );
    for (e, s) in &eps {
        println!("  epsilon {e:>8.0e} -> split {s}");
    }

    println!("\n== branch placement sweep (p = {p}) ==");
    for (pos, t, split) in ablation::branch_placement(&desc, &profile, link, p) {
        println!(
            "  branch after {:<8} E[T*] = {:>12}  split {}",
            desc.stage_names[pos - 1],
            format_secs(t),
            split
        );
    }
    Ok(())
}
