//! Bench harness (criterion is unavailable offline): warmup + timed
//! iterations with trimmed statistics and aligned table output, shared by
//! every target in `rust/benches/`.

use std::time::{Duration, Instant};

use crate::util::stats::{percentile, trimmed_mean};
use crate::util::timefmt::format_secs;

/// Result of benchmarking one case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12} {:>12} {:>7}",
            self.name,
            format_secs(self.mean_s),
            format_secs(self.p50_s),
            format_secs(self.min_s),
            format_secs(self.max_s),
            self.iters
        )
    }
}

pub fn header() -> String {
    format!(
        "{:<44} {:>12} {:>12} {:>12} {:>12} {:>7}",
        "benchmark", "mean", "p50", "min", "max", "iters"
    )
}

/// Time `f` adaptively: warm up, then iterate until `min_time` has been
/// spent or `max_iters` reached (at least `min_iters`).
pub fn bench(name: &str, min_time: Duration, mut f: impl FnMut()) -> BenchResult {
    const MIN_ITERS: usize = 5;
    const MAX_ITERS: usize = 100_000;

    // Warmup: one untimed call plus enough to fill ~10% of min_time.
    let warm_start = Instant::now();
    f();
    let one = warm_start.elapsed();
    let mut warmups = (min_time.as_secs_f64() * 0.1 / one.as_secs_f64().max(1e-9)) as usize;
    warmups = warmups.clamp(1, 100);
    for _ in 0..warmups {
        f();
    }

    let mut samples = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < min_time || samples.len() < MIN_ITERS)
        && samples.len() < MAX_ITERS
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }

    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: trimmed_mean(&samples, 0.05),
        p50_s: percentile(&samples, 50.0),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Pretty-print a labeled table section.
pub fn print_table(title: &str, rows: &[BenchResult]) {
    println!("\n=== {title} ===");
    println!("{}", header());
    for r in rows {
        println!("{}", r.row());
    }
}

/// Simple aligned data table for experiment output (figure regeneration).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV form (for plotting outside).
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop-ish", Duration::from_millis(20), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s);
        assert!(r.row().contains("noop-ish"));
    }

    #[test]
    fn table_renders_aligned_and_csv() {
        let mut t = Table::new(&["p", "3G", "4G"]);
        t.row(vec!["0.0".into(), "1.5".into(), "0.9".into()]);
        t.row(vec!["1.0".into(), "0.2".into(), "0.2".into()]);
        let s = t.render();
        assert!(s.contains("3G"));
        assert_eq!(s.lines().count(), 4);
        let csv = t.to_csv();
        assert_eq!(csv.lines().next().unwrap(), "p,3G,4G");
        assert_eq!(csv.lines().count(), 3);
    }
}
