//! Replays a [`ScenarioSpec`] against a **real** fleet — real
//! coordinators, real planners, real autoscale enforcement, real
//! loopback cloud-stage servers when asked (a forwarding chain of
//! them when `[[tier]]` is configured) — in lockstep *virtual* time.
//!
//! Determinism contract: wall clocks never decide anything.
//!
//! - A virtual clock advances in fixed ticks. Each tick the harness
//!   draws that tick's Poisson arrivals from a seeded RNG, submits them
//!   to the fleet, and then receives **every** response before the next
//!   tick begins. The pipeline is quiescent at every tick boundary, so
//!   plan switches, estimator observations and scaling decisions land
//!   at reproducible points in the sample stream.
//! - Latency and queueing are accounted by a *virtual queue twin*: one
//!   busy-until horizon per shard, serviced at the class planner's own
//!   `expected_time(split, link)`. Real execution (sim engines, zero
//!   stage cost) validates the ledger — every accepted request must
//!   come back — while the twin produces the latencies the SLOs judge.
//! - Scaling is harness-driven: the fleet runs with
//!   `autoscale_external`, the harness samples the twin's depths on the
//!   autoscaler's own interval/window/cooldown schedule (in virtual
//!   time) and executes decisions through
//!   [`Fleet::grow_class_triggered`] / [`Fleet::shrink_class_triggered`]
//!   — so per-class ceilings and the fleet-wide budget are enforced by
//!   the *real* fleet, deterministically.
//! - The fleet is pinned to `max_batch = 1`, round-robin routing, one
//!   cloud worker per shard and a non-real-time channel. That makes
//!   batch-level counters sample-level, keeps routing independent of
//!   wall-clock queue depths, and serializes the remote path so
//!   brownout fallbacks are counted identically on every run.
//!
//! The emitted `BENCH_scenario_<name>.json` contains only deterministic
//! quantities except the single `"wall"` object — strip it and two runs
//! with the same seed compare bit-identical.

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::json::Json;
use crate::coordinator::InferenceResponse;
use crate::fleet::{
    AutoscaleConfig, ClassRegistry, Fleet, FleetConfig, FleetReport, GrowOutcome, LinkClass,
    LoadSample, LoadSignal, RoutePolicy, ScaleDecision,
};
use crate::model::Manifest;
use crate::network::bandwidth::LinkModel;
use crate::planner::EstimatorConfig;
use crate::runtime::InferenceEngine;
use crate::server::{
    CloudStageServer, RemoteCloudConfig, RemoteCloudEngine, Server, ServerHandle,
};
use crate::timing::DelayProfile;
use crate::util::rng::Pcg32;
use crate::util::stats::percentile;
use crate::workload::images::ImageSource;

use super::spec::{EventKind, ScenarioSpec};

/// The synthetic model every scenario serves: five flat stages with the
/// side branch after stage 1, fed by the 3×32×32 image source.
const STAGE_OUT: [usize; 5] = [512, 256, 128, 64, 2];
/// Per-stage cloud time of the synthetic delay profile, seconds; edge
/// times are `gamma ×` this ([`DelayProfile::from_cloud_times`]).
const STAGE_CLOUD_S: f64 = 1e-4;
const BRANCH_CLOUD_S: f64 = 2e-5;
/// Wall-clock ceiling on one response; a quiesce that takes this long
/// means the pipeline lost a request, which is a harness bug.
const RECV_TIMEOUT: Duration = Duration::from_secs(120);

/// One SLO check's verdict, as emitted under `slo.checks[]`.
#[derive(Debug, Clone)]
pub struct SloCheck {
    pub name: String,
    pub pass: bool,
    pub detail: String,
}

/// A finished run: verdicts plus the full benchmark JSON.
#[derive(Debug)]
pub struct ScenarioOutcome {
    pub name: String,
    pub seed: u64,
    pub passed: bool,
    pub checks: Vec<SloCheck>,
    pub json: Json,
}

/// Linear rate ramp in progress.
struct Ramp {
    from: f64,
    to: f64,
    t0: f64,
    t1: f64,
}

/// Everything the harness tracks per link class.
struct ClassState {
    id: LinkClass,
    name: String,
    rate: f64,
    ramp: Option<Ramp>,
    /// Reroute this fraction of future arrivals to another class index.
    reassign: Option<(usize, f64)>,
    source: ImageSource,
    /// The *virtual* link — starts at the class profile, moved by
    /// `set_bandwidth` (which retunes the real fleet in the same step).
    link: LinkModel,
    split: usize,
    /// Split trajectory: `(t_s, split)`, first entry at t = 0.
    splits: Vec<(f64, usize)>,
    /// Routes through a K-tier chain (fixed cut vector); `split` is
    /// the chain's edge cut `cuts[0]`.
    chain: bool,
    /// Virtual queue twin: busy-until horizon per shard, seconds.
    twin: Vec<f64>,
    offered: u64,
    accepted: u64,
    rejected: u64,
    completed: u64,
    edge_exits: u64,
    /// Virtual latencies, seconds.
    latencies: Vec<f64>,
    /// Resolved autoscale config (fleet defaults + class overrides);
    /// `None` = fixed-size class.
    acfg: Option<AutoscaleConfig>,
    window: Vec<LoadSample>,
    prev: LoadSample,
    next_sample_t: f64,
    cooldown_until: f64,
    scale_ups: u64,
    scale_downs: u64,
    grow_denied_cap: u64,
    grow_denied_budget: u64,
    high_water: usize,
    low_water: usize,
}

impl ClassState {
    fn rate_at(&self, t: f64) -> f64 {
        match &self.ramp {
            Some(r) if t < r.t1 => {
                let f = ((t - r.t0) / (r.t1 - r.t0)).clamp(0.0, 1.0);
                r.from + f * (r.to - r.from)
            }
            Some(r) => r.to,
            None => self.rate,
        }
    }

    /// Twin service time at virtual time `t`: the class planner's
    /// expected time for the executing route at the virtual link.
    /// Three-way degrade ladder, mirroring the real pipeline: chain
    /// classes price the full cut vector while the chain head is up; a
    /// head-only brownout re-prices as a direct single-hop offload at
    /// the same edge split (degrade-to-direct against the terminal); a
    /// full cloud brownout prices edge-only execution (local fallback).
    fn service_s(
        &self,
        fleet: &Fleet,
        cloud_up: bool,
        tier_up: bool,
        num_stages: usize,
    ) -> Result<f64> {
        let s = if !cloud_up {
            fleet.expected_time_of(self.id, num_stages, self.link)?
        } else if self.chain && tier_up {
            fleet.chain_expected_time_of(self.id, self.link)?
        } else {
            fleet.expected_time_of(self.id, self.split, self.link)?
        };
        if !(s.is_finite() && s > 0.0) {
            bail!("class '{}': non-positive expected time {s}", self.name);
        }
        Ok(s)
    }

    fn twin_depth(&self, t: f64, service: f64) -> usize {
        self.twin
            .iter()
            .map(|&busy| {
                if busy > t {
                    ((busy - t) / service).ceil() as usize
                } else {
                    0
                }
            })
            .sum()
    }
}

/// Accumulates one metrics window.
#[derive(Default)]
struct WindowAcc {
    offered: u64,
    accepted: u64,
    rejected: u64,
    completed: u64,
    latencies: Vec<f64>,
}

/// Seconds → milliseconds, rounded to 3 decimals (stable to print).
fn ms3(s: f64) -> f64 {
    (s * 1e6).round() / 1e3
}

fn p_or_zero(lats: &[f64], q: f64) -> f64 {
    if lats.is_empty() {
        0.0
    } else {
        percentile(lats, q)
    }
}

/// Run a scenario. `seed_override` (the CLI's `--seed`) replaces the
/// file's `[scenario] seed`. Two runs with the same spec and seed emit
/// bit-identical JSON apart from the `"wall"` object.
pub fn run(spec: &ScenarioSpec, seed_override: Option<u64>) -> Result<ScenarioOutcome> {
    let wall_start = Instant::now();
    let seed = seed_override.unwrap_or(spec.seed);
    let settings = &spec.settings;
    let num_stages = STAGE_OUT.len();

    let manifest = Manifest::synthetic_sim(
        "scenario-sim",
        vec![3, 32, 32],
        &STAGE_OUT,
        1,
        2,
        vec![1],
    )?;
    let delay = DelayProfile::from_cloud_times(
        vec![STAGE_CLOUD_S; num_stages],
        BRANCH_CLOUD_S,
        settings.edge.gamma,
    );
    let registry = ClassRegistry::from_settings(&settings.link_classes)?;

    // Loopback cloud: real cloud-stage servers on 127.0.0.1, so
    // brownouts exercise the real remote path (wire protocol,
    // administrative refusal, local fallback). With a [[tier]] chain
    // configured, one server per tier comes up — each non-terminal
    // tier forwarding to the next — and the placeholder addrs in the
    // file are rewritten to the listeners that actually bound, so
    // tier brownouts exercise the real chain path (forwarded frames,
    // a fail-fast head, degrade-to-direct against the live terminal).
    let mut tier_chain = settings.tiers.clone();
    let mut tier_handles: Vec<ServerHandle> = Vec::new();
    let cloud_handle: Option<ServerHandle> = if spec.loopback_cloud && tier_chain.is_empty() {
        let engine = InferenceEngine::open_sim(manifest.clone(), "scenario-cloudstage")?;
        Some(Server::new(Arc::new(CloudStageServer::new(engine))).start(0)?)
    } else if spec.loopback_cloud {
        // Back to front: the terminal first, then each earlier tier
        // forwarding to the server that just bound.
        let mut next_addr: Option<String> = None;
        for i in (0..tier_chain.len()).rev() {
            let engine =
                InferenceEngine::open_sim(manifest.clone(), &format!("scenario-tier{i}"))?;
            let mut stage = CloudStageServer::new(engine);
            if let Some(addr) = &next_addr {
                stage = stage.with_forward(Arc::new(RemoteCloudEngine::new(
                    RemoteCloudConfig::new(addr.clone()),
                )));
            }
            let handle = Server::new(Arc::new(stage)).start(0)?;
            next_addr = Some(handle.addr().to_string());
            tier_handles.push(handle);
        }
        // `tier_handles` is terminal-first; walk it backwards to pair
        // head with head.
        for (t, h) in tier_chain.iter_mut().zip(tier_handles.iter().rev()) {
            t.addr = h.addr().to_string();
        }
        None
    } else {
        None
    };
    let cloud_addr = cloud_handle.as_ref().map(|h| h.addr().to_string());

    let autoscale = if settings.fleet.autoscale {
        Some(settings.fleet.autoscale_config()?)
    } else {
        None
    };
    let fleet_manifest = manifest.clone();
    let fleet = Fleet::start(
        registry,
        &manifest,
        &delay,
        FleetConfig {
            shards_per_class: settings.fleet.shards,
            // One cloud worker serializes the remote path: per-sample
            // transfer order (and hence fallback counts) is fixed.
            cloud_workers_per_shard: 1,
            // Round-robin is load-independent; least-loaded reads
            // wall-clock queue depths and would tie routing to timing.
            routing: RoutePolicy::RoundRobin,
            entropy_threshold: settings.branch.entropy_threshold as f32,
            // One sample per batch: batch-level counters become
            // sample-level, and the batcher never waits on a timeout.
            max_batch: 1,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: settings.serve.queue_capacity,
            default_exit_prob: settings.branch.exit_probability.unwrap_or(0.5),
            epsilon: settings.partition.epsilon,
            adaptive: None,
            autoscale: autoscale.clone(),
            // The harness is the control loop; the fleet only enforces.
            autoscale_external: true,
            max_total_shards: settings.fleet.max_total_shards,
            estimation: settings.fleet.online_estimation.then(|| EstimatorConfig {
                drift_threshold: settings.fleet.drift_threshold,
                ..EstimatorConfig::default()
            }),
            per_request_planning: false,
            probe_fraction: 0.0,
            cloud_addr,
            tier_chain: tier_chain.clone(),
            wire_encoding: settings.fleet.wire_encoding,
            channel_jitter: 0.0,
            real_time_channel: false,
            ..FleetConfig::default()
        },
        move |label: &str| {
            Ok((
                InferenceEngine::open_sim(fleet_manifest.clone(), &format!("{label}-edge"))?,
                InferenceEngine::open_sim(fleet_manifest.clone(), &format!("{label}-cloud"))?,
            ))
        },
    )?;
    if settings.fleet.online_estimation && settings.fleet.shards > 1 {
        log::warn!(
            "scenario: online estimation with {} shards — observation order across \
             shards is scheduling-dependent; use shards = 1 for bit-identical runs",
            settings.fleet.shards
        );
    }

    // ------------------------------------------------- per-class state
    let start_shards = settings.fleet.shards;
    let mut classes: Vec<ClassState> = Vec::with_capacity(settings.link_classes.len());
    for (ci, lc) in settings.link_classes.iter().enumerate() {
        let id = fleet
            .class_by_name(&lc.name)
            .ok_or_else(|| anyhow!("class '{}' vanished from the fleet", lc.name))?;
        let workload = spec
            .workloads
            .iter()
            .find(|w| w.class.eq_ignore_ascii_case(&lc.name));
        let mut source = ImageSource::new(seed.wrapping_add(ci as u64));
        source.set_mix(workload.map(|w| w.class1_fraction).unwrap_or(0.5));
        let split = fleet.plan_of(id)?.split_after;
        let chain = fleet.chain_cuts_of(id)?.is_some();
        let acfg = fleet.autoscale_of(id)?;
        let interval = acfg
            .as_ref()
            .map(|a| a.interval.as_secs_f64())
            .unwrap_or(f64::INFINITY);
        classes.push(ClassState {
            id,
            name: lc.name.clone(),
            rate: workload.map(|w| w.rate_rps).unwrap_or(0.0),
            ramp: None,
            reassign: None,
            source,
            link: LinkModel::try_new(lc.uplink_mbps, lc.rtt_s)?,
            split,
            splits: vec![(0.0, split)],
            chain,
            twin: vec![0.0; start_shards],
            offered: 0,
            accepted: 0,
            rejected: 0,
            completed: 0,
            edge_exits: 0,
            latencies: Vec::new(),
            acfg,
            window: Vec::new(),
            prev: LoadSample::default(),
            next_sample_t: interval,
            cooldown_until: 0.0,
            scale_ups: 0,
            scale_downs: 0,
            grow_denied_cap: 0,
            grow_denied_budget: 0,
            high_water: start_shards,
            low_water: start_shards,
        });
    }

    // --------------------------------------------------- the tick loop
    let tick_s = spec.tick_ms / 1e3;
    let n_ticks = (spec.duration_s / tick_s).ceil() as u64;
    let queue_cap = settings.serve.queue_capacity;
    let mut arrivals_rng = Pcg32::new(seed, 1);
    let mut reassign_rng = Pcg32::new(seed, 2);
    let mut cloud_up = true;
    let mut tier_up = true;
    let mut next_event = 0usize;
    let mut win = WindowAcc::default();
    let mut windows: Vec<Json> = Vec::new();
    let mut window_idx = 0u64;
    let mut pending: Vec<(usize, Receiver<InferenceResponse>)> = Vec::new();

    for k in 0..n_ticks {
        let t0 = k as f64 * tick_s;
        let t_end = t0 + tick_s;

        // Events due at or before this tick's start.
        while next_event < spec.events.len() && spec.events[next_event].at_s <= t0 + 1e-9 {
            let ev = &spec.events[next_event];
            apply_event(
                &ev.kind,
                ev.at_s,
                &mut classes,
                &fleet,
                &mut cloud_up,
                &mut tier_up,
            )?;
            next_event += 1;
        }

        // This tick's arrivals, class by class in declaration order.
        #[allow(clippy::needless_range_loop)]
        for ci in 0..classes.len() {
            let rate = classes[ci].rate_at(t0);
            if rate <= 0.0 {
                continue;
            }
            let n = arrivals_rng.poisson(rate * tick_s);
            let mut offsets: Vec<f64> = (0..n).map(|_| arrivals_rng.f64() * tick_s).collect();
            offsets.sort_by(f64::total_cmp);
            for off in offsets {
                let tau = t0 + off;
                let (image, _label) = classes[ci].source.sample();
                let eff = match classes[ci].reassign {
                    Some((to, f)) if reassign_rng.bool(f) => to,
                    _ => ci,
                };
                let service = classes[eff].service_s(&fleet, cloud_up, tier_up, num_stages)?;
                let c = &mut classes[eff];
                c.offered += 1;
                win.offered += 1;
                // Pick the twin shard exactly like round-robin doesn't:
                // earliest-free wins, which is what the latency bound
                // cares about. Rejection applies the real per-shard
                // queue capacity to the twin's backlog.
                let (si, busy) = c
                    .twin
                    .iter()
                    .copied()
                    .enumerate()
                    .min_by(|a, b| a.1.total_cmp(&b.1))
                    .expect("shard groups are never empty");
                let depth = if busy > tau {
                    ((busy - tau) / service).ceil() as usize
                } else {
                    0
                };
                if depth >= queue_cap {
                    c.rejected += 1;
                    win.rejected += 1;
                    continue;
                }
                let finish = busy.max(tau) + service;
                c.twin[si] = finish;
                c.accepted += 1;
                win.accepted += 1;
                c.latencies.push(finish - tau);
                win.latencies.push(finish - tau);
                let (_id, rx) = fleet.submit(c.id, image)?;
                pending.push((eff, rx));
            }
        }

        // Quiesce: every submitted sample answers before time advances.
        for (ci, rx) in pending.drain(..) {
            let resp = rx.recv_timeout(RECV_TIMEOUT).map_err(|_| {
                anyhow!(
                    "scenario pipeline stalled: class '{}' sample unanswered after {:?}",
                    classes[ci].name,
                    RECV_TIMEOUT
                )
            })?;
            classes[ci].completed += 1;
            win.completed += 1;
            if resp.exited_early() {
                classes[ci].edge_exits += 1;
            }
        }

        // Estimator-driven replans landed during the quiesce; pick up
        // any split movement at the tick boundary.
        for c in &mut classes {
            let s = fleet.plan_of(c.id)?.split_after;
            if s != c.split {
                c.split = s;
                c.splits.push((t_end, s));
            }
        }

        // Scaling decisions due by the end of this tick.
        for c in &mut classes {
            drive_scaler(c, &fleet, t_end, cloud_up, tier_up, num_stages)?;
        }

        // Window boundary?
        while t_end + 1e-9 >= (window_idx + 1) as f64 * spec.window_s {
            window_idx += 1;
            flush_window(
                &mut win,
                &mut windows,
                window_idx as f64 * spec.window_s,
                &classes,
            );
        }
    }
    if next_event < spec.events.len() {
        log::warn!(
            "scenario: {} event(s) after the last tick start never fired",
            spec.events.len() - next_event
        );
    }
    let events_applied = next_event;
    if win.offered > 0 || win.completed > 0 {
        flush_window(&mut win, &mut windows, spec.duration_s, &classes);
    }

    let report = fleet.shutdown();
    if let Some(h) = cloud_handle {
        h.stop();
    }
    for h in tier_handles {
        h.stop();
    }

    let checks = evaluate_slo(spec, &classes, &report);
    let passed = checks.iter().all(|c| c.pass);
    let json = emit_json(
        spec,
        seed,
        &classes,
        &report,
        &checks,
        passed,
        &windows,
        events_applied,
        wall_start.elapsed().as_secs_f64(),
    );
    Ok(ScenarioOutcome {
        name: spec.name.clone(),
        seed,
        passed,
        checks,
        json,
    })
}

fn apply_event(
    kind: &EventKind,
    at_s: f64,
    classes: &mut [ClassState],
    fleet: &Fleet,
    cloud_up: &mut bool,
    tier_up: &mut bool,
) -> Result<()> {
    let idx_of = |classes: &[ClassState], name: &str| -> Result<usize> {
        classes
            .iter()
            .position(|c| c.name.eq_ignore_ascii_case(name))
            .ok_or_else(|| anyhow!("event references unknown class '{name}'"))
    };
    match kind {
        EventKind::SetRate { class, rate_rps } => {
            let ci = idx_of(classes, class)?;
            classes[ci].rate = *rate_rps;
            classes[ci].ramp = None;
        }
        EventKind::RampRate {
            class,
            rate_rps,
            over_s,
        } => {
            let ci = idx_of(classes, class)?;
            let from = classes[ci].rate_at(at_s);
            classes[ci].rate = *rate_rps;
            classes[ci].ramp = Some(Ramp {
                from,
                to: *rate_rps,
                t0: at_s,
                t1: at_s + over_s,
            });
        }
        EventKind::SetBandwidth { class, mbps } => {
            let ci = idx_of(classes, class)?;
            let rtt = classes[ci].link.rtt_s;
            classes[ci].link = LinkModel::try_new(*mbps, rtt)?;
            let split = fleet.retune_class(classes[ci].id, *mbps, rtt)?;
            if split != classes[ci].split {
                classes[ci].split = split;
                classes[ci].splits.push((at_s, split));
            }
        }
        EventKind::Reassign { from, to, fraction } => {
            let fi = idx_of(classes, from)?;
            let ti = idx_of(classes, to)?;
            classes[fi].reassign = (*fraction > 0.0).then_some((ti, *fraction));
        }
        EventKind::CloudDown => {
            fleet.set_cloud_available(false);
            *cloud_up = false;
        }
        EventKind::CloudUp => {
            fleet.set_cloud_available(true);
            *cloud_up = true;
        }
        EventKind::TierDown => {
            fleet.set_tier_available(false);
            *tier_up = false;
        }
        EventKind::TierUp => {
            fleet.set_tier_available(true);
            *tier_up = true;
        }
        EventKind::SetExitBias {
            class,
            class1_fraction,
        } => {
            let ci = idx_of(classes, class)?;
            classes[ci].source.set_mix(*class1_fraction);
        }
    }
    Ok(())
}

/// Sample the twin on the autoscaler's schedule and execute decisions
/// through the real fleet — the same window/cooldown state machine
/// [`crate::fleet::Autoscaler`] runs, on the virtual clock.
fn drive_scaler(
    c: &mut ClassState,
    fleet: &Fleet,
    now: f64,
    cloud_up: bool,
    tier_up: bool,
    num_stages: usize,
) -> Result<()> {
    let Some(acfg) = c.acfg.clone() else {
        return Ok(());
    };
    let interval = acfg.interval.as_secs_f64();
    let cooldown = acfg.cooldown.as_secs_f64();
    while c.next_sample_t <= now + 1e-9 {
        let t = c.next_sample_t;
        c.next_sample_t += interval;
        let service = c.service_s(fleet, cloud_up, tier_up, num_stages)?;
        c.window.push(LoadSample {
            shards: c.twin.len(),
            depth_total: c.twin_depth(t, service),
            rejected_total: c.rejected,
            remote_total: 0,
        });
        if c.window.len() < acfg.window || t < c.cooldown_until {
            continue;
        }
        let signal = LoadSignal::from_window(&c.window, &c.prev);
        c.prev = *c.window.last().expect("window is non-empty here");
        c.window.clear();
        match acfg.decide(&signal, c.twin.len()) {
            ScaleDecision::Grow(trigger) => match fleet.grow_class_triggered(c.id, &trigger)? {
                GrowOutcome::Grew(n) => {
                    c.twin.push(0.0);
                    debug_assert_eq!(n, c.twin.len());
                    c.scale_ups += 1;
                    c.high_water = c.high_water.max(n);
                    c.cooldown_until = t + cooldown;
                }
                GrowOutcome::AtClassCap => c.grow_denied_cap += 1,
                GrowOutcome::AtBudget => c.grow_denied_budget += 1,
            },
            ScaleDecision::Shrink(trigger) => {
                // The twin forgives the victim's (near-empty — shrink
                // only fires on quiet windows) virtual backlog; the
                // real victim drains fully before retiring.
                if let Ok(n) = fleet.shrink_class_triggered(c.id, &trigger) {
                    c.twin.pop();
                    debug_assert_eq!(n, c.twin.len());
                    c.scale_downs += 1;
                    c.low_water = c.low_water.min(n);
                    c.cooldown_until = t + cooldown;
                }
            }
            ScaleDecision::Hold => {}
        }
    }
    Ok(())
}

fn flush_window(win: &mut WindowAcc, out: &mut Vec<Json>, t_s: f64, classes: &[ClassState]) {
    let w = std::mem::take(win);
    let shards: usize = classes.iter().map(|c| c.twin.len()).sum();
    out.push(Json::obj(vec![
        ("t_s", Json::num((t_s * 1e3).round() / 1e3)),
        ("offered", Json::num(w.offered as f64)),
        ("accepted", Json::num(w.accepted as f64)),
        ("rejected", Json::num(w.rejected as f64)),
        ("completed", Json::num(w.completed as f64)),
        ("p99_ms", Json::num(ms3(p_or_zero(&w.latencies, 99.0)))),
        ("shards", Json::num(shards as f64)),
    ]));
}

fn evaluate_slo(
    spec: &ScenarioSpec,
    classes: &[ClassState],
    report: &FleetReport,
) -> Vec<SloCheck> {
    let mut checks = Vec::new();
    let mut check = |name: &str, pass: bool, detail: String| {
        checks.push(SloCheck {
            name: name.to_string(),
            pass,
            detail,
        });
    };
    let slo = &spec.slo;
    let offered: u64 = classes.iter().map(|c| c.offered).sum();
    let accepted: u64 = classes.iter().map(|c| c.accepted).sum();
    let rejected: u64 = classes.iter().map(|c| c.rejected).sum();
    let completed: u64 = classes.iter().map(|c| c.completed).sum();
    let all_lats: Vec<f64> = classes.iter().flat_map(|c| c.latencies.iter().copied()).collect();

    // Built-in: the real ledger must balance — every accepted sample
    // was answered by the fleet, nothing shed or failed for real.
    if slo.zero_drops {
        let pass = completed == accepted
            && report.total.rejected == 0
            && report.total.failed == 0;
        check(
            "zero_drops",
            pass,
            format!(
                "accepted {accepted}, completed {completed}, fleet rejected {}, failed {}",
                report.total.rejected, report.total.failed
            ),
        );
    }
    if let Some(target) = slo.p99_ms {
        let p99 = ms3(p_or_zero(&all_lats, 99.0));
        check(
            "p99_ms",
            p99 <= target,
            format!("virtual p99 {p99} ms vs target {target} ms"),
        );
    }
    if let Some(target) = slo.max_rejection_rate {
        let rate = if offered == 0 {
            0.0
        } else {
            rejected as f64 / offered as f64
        };
        check(
            "max_rejection_rate",
            rate <= target,
            format!("rejected {rejected}/{offered} = {rate:.4} vs ceiling {target}"),
        );
    }
    if let Some(floor) = slo.min_completed {
        check(
            "min_completed",
            completed >= floor,
            format!("completed {completed} vs floor {floor}"),
        );
    }
    if slo.expect_rejections {
        check(
            "expect_rejections",
            rejected > 0,
            format!("{rejected} admission rejection(s)"),
        );
    }
    if slo.expect_fallbacks {
        let fallbacks: u64 = report.classes.iter().map(|c| c.aggregate.remote_fallbacks).sum();
        let remote: u64 = report.classes.iter().map(|c| c.aggregate.remote_batches).sum();
        check(
            "expect_fallbacks",
            fallbacks > 0,
            format!("{fallbacks} remote→local fallback(s), {remote} remote completion(s)"),
        );
    }
    if slo.expect_chain_fallbacks {
        let degraded: u64 = report
            .classes
            .iter()
            .map(|c| c.aggregate.chain_fallbacks)
            .sum();
        let remote: u64 = report.classes.iter().map(|c| c.aggregate.remote_batches).sum();
        check(
            "expect_chain_fallbacks",
            degraded > 0,
            format!("{degraded} chain→direct degrade(s), {remote} remote completion(s)"),
        );
    }
    if slo.expect_budget_denial {
        let denied: u64 = classes.iter().map(|c| c.grow_denied_budget).sum();
        let recorded = report.classes.iter().any(|c| {
            c.scaler
                .last_trigger
                .as_deref()
                .is_some_and(|t| t.contains("budget"))
        });
        check(
            "expect_budget_denial",
            denied > 0 && recorded,
            format!("{denied} budget denial(s); last_trigger records budget: {recorded}"),
        );
    }
    if let Some(name) = &slo.expect_max_shards_reached {
        let c = classes.iter().find(|c| c.name.eq_ignore_ascii_case(name));
        let (pass, detail) = match c {
            Some(c) => {
                let cap = c.acfg.as_ref().map(|a| a.max_shards).unwrap_or(0);
                (
                    c.high_water == cap && cap > 0,
                    format!("class '{}' high water {} vs ceiling {}", c.name, c.high_water, cap),
                )
            }
            None => (false, format!("class '{name}' not found")),
        };
        check("expect_max_shards_reached", pass, detail);
    }
    if let Some(name) = &slo.expect_split_change {
        let c = classes.iter().find(|c| c.name.eq_ignore_ascii_case(name));
        let (pass, detail) = match c {
            Some(c) => (
                c.splits.len() >= 2,
                format!(
                    "class '{}' split trajectory {:?}",
                    c.name,
                    c.splits.iter().map(|&(_, s)| s).collect::<Vec<_>>()
                ),
            ),
            None => (false, format!("class '{name}' not found")),
        };
        check("expect_split_change", pass, detail);
    }
    if let Some(floor) = slo.min_estimator_observations {
        let obs: u64 = report
            .classes
            .iter()
            .map(|c| c.planner.estimator_observations)
            .sum();
        check(
            "min_estimator_observations",
            obs >= floor,
            format!("{obs} gate observation(s) vs floor {floor}"),
        );
    }
    // Built-in: the bounds the scenario configured actually held.
    if classes.iter().any(|c| c.acfg.is_some()) {
        let mut pass = true;
        let mut parts = Vec::new();
        for c in classes.iter().filter(|c| c.acfg.is_some()) {
            let a = c.acfg.as_ref().expect("filtered on is_some");
            pass &= c.low_water >= a.min_shards && c.high_water <= a.max_shards;
            parts.push(format!(
                "{}: {}..{} within {}..={}",
                c.name, c.low_water, c.high_water, a.min_shards, a.max_shards
            ));
        }
        check("scaler_bounds", pass, parts.join("; "));
    }
    checks
}

#[allow(clippy::too_many_arguments)]
fn emit_json(
    spec: &ScenarioSpec,
    seed: u64,
    classes: &[ClassState],
    report: &FleetReport,
    checks: &[SloCheck],
    passed: bool,
    windows: &[Json],
    events_applied: usize,
    wall_s: f64,
) -> Json {
    let all_lats: Vec<f64> = classes.iter().flat_map(|c| c.latencies.iter().copied()).collect();
    let offered: u64 = classes.iter().map(|c| c.offered).sum();
    let accepted: u64 = classes.iter().map(|c| c.accepted).sum();
    let rejected: u64 = classes.iter().map(|c| c.rejected).sum();
    let completed: u64 = classes.iter().map(|c| c.completed).sum();
    let edge_exits: u64 = classes.iter().map(|c| c.edge_exits).sum();
    let fallbacks: u64 = report.classes.iter().map(|c| c.aggregate.remote_fallbacks).sum();
    let chain_fallbacks: u64 = report
        .classes
        .iter()
        .map(|c| c.aggregate.chain_fallbacks)
        .sum();
    let mean = if all_lats.is_empty() {
        0.0
    } else {
        all_lats.iter().sum::<f64>() / all_lats.len() as f64
    };
    let max = all_lats.iter().copied().fold(0.0f64, f64::max);

    let class_json: Vec<Json> = classes
        .iter()
        .enumerate()
        .map(|(i, c)| {
            let r = &report.classes[i];
            let mut fields = vec![
                ("name", Json::str(c.name.clone())),
                ("offered", Json::num(c.offered as f64)),
                ("accepted", Json::num(c.accepted as f64)),
                ("rejected", Json::num(c.rejected as f64)),
                ("completed", Json::num(c.completed as f64)),
                ("edge_exits", Json::num(c.edge_exits as f64)),
                ("remote_batches", Json::num(r.aggregate.remote_batches as f64)),
                (
                    "remote_fallbacks",
                    Json::num(r.aggregate.remote_fallbacks as f64),
                ),
                (
                    "chain_fallbacks",
                    Json::num(r.aggregate.chain_fallbacks as f64),
                ),
                ("p99_ms", Json::num(ms3(p_or_zero(&c.latencies, 99.0)))),
                (
                    "splits",
                    Json::arr(
                        c.splits
                            .iter()
                            .map(|&(t, s)| {
                                Json::arr(vec![
                                    Json::num((t * 1e3).round() / 1e3),
                                    Json::num(s as f64),
                                ])
                            })
                            .collect(),
                    ),
                ),
                (
                    "scaler",
                    Json::obj(vec![
                        ("enabled", Json::Bool(c.acfg.is_some())),
                        (
                            "min_shards",
                            Json::num(c.acfg.as_ref().map(|a| a.min_shards).unwrap_or(0) as f64),
                        ),
                        (
                            "max_shards",
                            Json::num(c.acfg.as_ref().map(|a| a.max_shards).unwrap_or(0) as f64),
                        ),
                        ("final_shards", Json::num(c.twin.len() as f64)),
                        ("high_water", Json::num(c.high_water as f64)),
                        ("low_water", Json::num(c.low_water as f64)),
                        ("scale_ups", Json::num(c.scale_ups as f64)),
                        ("scale_downs", Json::num(c.scale_downs as f64)),
                        ("grow_denied_cap", Json::num(c.grow_denied_cap as f64)),
                        (
                            "grow_denied_budget",
                            Json::num(c.grow_denied_budget as f64),
                        ),
                        (
                            "last_trigger",
                            match &r.scaler.last_trigger {
                                Some(t) => Json::str(t.clone()),
                                None => Json::Null,
                            },
                        ),
                    ]),
                ),
                (
                    "estimator_observations",
                    Json::num(r.planner.estimator_observations as f64),
                ),
            ];
            if let Some(cuts) = &r.cuts {
                fields.push((
                    "cuts",
                    Json::arr(cuts.iter().map(|&s| Json::num(s as f64)).collect()),
                ));
            }
            if let Some(p) = r.planner.p_hat {
                fields.push(("p_hat_final", Json::num((p * 1e6).round() / 1e6)));
            }
            Json::obj(fields)
        })
        .collect();

    Json::obj(vec![
        ("bench", Json::str("scenario")),
        ("scenario", Json::str(spec.name.clone())),
        ("source", Json::str("measured")),
        ("seed", Json::num(seed as f64)),
        ("duration_s", Json::num(spec.duration_s)),
        ("tick_ms", Json::num(spec.tick_ms)),
        (
            "slo",
            Json::obj(vec![
                ("pass", Json::Bool(passed)),
                (
                    "checks",
                    Json::arr(
                        checks
                            .iter()
                            .map(|c| {
                                Json::obj(vec![
                                    ("name", Json::str(c.name.clone())),
                                    ("pass", Json::Bool(c.pass)),
                                    ("detail", Json::str(c.detail.clone())),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "totals",
            Json::obj(vec![
                ("offered", Json::num(offered as f64)),
                ("accepted", Json::num(accepted as f64)),
                ("rejected", Json::num(rejected as f64)),
                ("completed", Json::num(completed as f64)),
                ("edge_exits", Json::num(edge_exits as f64)),
                ("cloud_fallbacks", Json::num(fallbacks as f64)),
                ("chain_fallbacks", Json::num(chain_fallbacks as f64)),
                ("p50_ms", Json::num(ms3(p_or_zero(&all_lats, 50.0)))),
                ("p99_ms", Json::num(ms3(p_or_zero(&all_lats, 99.0)))),
                ("mean_ms", Json::num(ms3(mean))),
                ("max_ms", Json::num(ms3(max))),
                // Front-end counters: the harness drives the fleet
                // in-process (no TCP front end registers stats), so
                // these stay 0 here — present so scenario baselines and
                // served-fleet reports share one totals shape.
                (
                    "throttled",
                    Json::num(report.server.map_or(0, |s| s.throttled) as f64),
                ),
                (
                    "conn_peak",
                    Json::num(report.server.map_or(0, |s| s.conn_peak) as f64),
                ),
            ]),
        ),
        ("classes", Json::arr(class_json)),
        ("windows", Json::arr(windows.to_vec())),
        ("events_applied", Json::num(events_applied as f64)),
        // The single nondeterministic field: strip "wall" before
        // comparing two same-seed runs for bit-identity.
        (
            "wall",
            Json::obj(vec![("run_s", Json::num((wall_s * 1e3).round() / 1e3))]),
        ),
    ])
}
