//! The scenario DSL: a `.toml` file describing a timed, fully
//! deterministic traffic-and-faults script plus the SLO block the run
//! is judged by.
//!
//! A scenario file is a *superset* of an ordinary config file. The
//! fleet half — `[fleet]`, `[serve]`, `[branch]`, `[[link_class]]`, … —
//! is read by [`Settings`] exactly as `branchyserve serve --config`
//! would read it; the scenario-only tables are parsed here:
//!
//! - `[scenario]` — name, virtual duration, tick/window sizes, master
//!   seed, and whether to stand up a real loopback cloud-stage server.
//! - `[[workload]]` — one Poisson arrival process per link class, with
//!   its initial rate and label mix.
//! - `[[event]]` — the script: timed `kind = "..."` entries that bend
//!   load curves, churn links, reassign traffic, toggle the cloud, or
//!   drift the exit rate.
//! - `[slo]` — pass/fail assertions evaluated over the finished run.
//!
//! Validation is front-loaded and loud: every rejection names the
//! offending event index, the value it saw, and what would have been
//! accepted, so a scenario that parses is a scenario that can run.

use std::fs;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::json::Json;
use crate::config::settings::Settings;
use crate::config::toml;

/// A parsed, validated scenario: the script plus the fleet settings it
/// runs against.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    /// Scenario name; becomes `BENCH_scenario_<name>.json`, so it is
    /// restricted to `[a-z0-9_-]`.
    pub name: String,
    /// Virtual run length, seconds.
    pub duration_s: f64,
    /// Virtual tick, milliseconds. Arrivals are generated per tick and
    /// the pipeline is quiesced at every tick boundary.
    pub tick_ms: f64,
    /// Metrics window, seconds (one `windows[]` row per window).
    pub window_s: f64,
    /// Master seed; `scenario run --seed` overrides it.
    pub seed: u64,
    /// Start a real loopback cloud-stage server and point every class
    /// at it. Required by `cloud_down` / `cloud_up` events — a brownout
    /// of an in-process cloud is not a thing.
    pub loopback_cloud: bool,
    pub workloads: Vec<WorkloadSpec>,
    /// The script, ordered by `at_s` (validated non-decreasing).
    pub events: Vec<Event>,
    pub slo: SloSpec,
    /// The fleet half of the file, overlaid on [`Settings::default`].
    pub settings: Settings,
}

/// One `[[workload]]` entry: the arrival process driving one class.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// `[[link_class]]` name this process submits to.
    pub class: String,
    /// Initial Poisson arrival rate, requests/second.
    pub rate_rps: f64,
    /// Initial fraction of class-1 (stripes) images, 0..=1.
    pub class1_fraction: f64,
}

/// One `[[event]]` entry: something happens at `at_s`.
#[derive(Debug, Clone)]
pub struct Event {
    pub at_s: f64,
    pub kind: EventKind,
}

/// Everything the script can do. The `kind = "..."` strings are the
/// snake_case names of these variants.
#[derive(Debug, Clone)]
pub enum EventKind {
    /// Step a class's arrival rate.
    SetRate { class: String, rate_rps: f64 },
    /// Ramp a class's rate linearly from its current value to
    /// `rate_rps` over `over_s` seconds (diurnal curves are two of
    /// these back to back).
    RampRate {
        class: String,
        rate_rps: f64,
        over_s: f64,
    },
    /// Re-tune a class's uplink mid-stream: the virtual link changes
    /// and the fleet re-solves the class's partition at the new rate.
    SetBandwidth { class: String, mbps: f64 },
    /// Reroute `fraction` of a class's *future* arrivals to another
    /// class (mid-stream class reassignment).
    Reassign {
        from: String,
        to: String,
        fraction: f64,
    },
    /// Begin a cloud brownout: every remote engine refuses instantly.
    CloudDown,
    /// End the brownout.
    CloudUp,
    /// Begin a *chain-head* brownout: only the first `[[tier]]` server
    /// refuses, so chain-routed classes degrade to a direct single-hop
    /// offload against the (still up) terminal tier.
    TierDown,
    /// End the chain-head brownout.
    TierUp,
    /// Drift the label mix of a class's workload generator — the lever
    /// that moves the *observed* exit rate under online estimation.
    SetExitBias { class: String, class1_fraction: f64 },
}

impl EventKind {
    /// The `kind = "..."` string of this variant.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::SetRate { .. } => "set_rate",
            EventKind::RampRate { .. } => "ramp_rate",
            EventKind::SetBandwidth { .. } => "set_bandwidth",
            EventKind::Reassign { .. } => "reassign",
            EventKind::CloudDown => "cloud_down",
            EventKind::CloudUp => "cloud_up",
            EventKind::TierDown => "tier_down",
            EventKind::TierUp => "tier_up",
            EventKind::SetExitBias { .. } => "set_exit_bias",
        }
    }
}

const KNOWN_KINDS: &str = "set_rate, ramp_rate, set_bandwidth, reassign, cloud_down, \
                           cloud_up, tier_down, tier_up, set_exit_bias";

/// `[slo]`: the assertions a finished run is judged by. Everything is
/// optional; an empty block only checks the built-in ledger invariants.
#[derive(Debug, Clone, Default)]
pub struct SloSpec {
    /// Virtual p99 latency ceiling, milliseconds.
    pub p99_ms: Option<f64>,
    /// Ceiling on rejected/offered over the whole run, 0..=1.
    pub max_rejection_rate: Option<f64>,
    /// Require the real ledger to balance: no shed, no failure, every
    /// accepted request answered. Defaults to true.
    pub zero_drops: bool,
    /// Floor on completed requests over the whole run.
    pub min_completed: Option<u64>,
    /// Require at least one admission rejection (overload scenarios
    /// must actually overload).
    pub expect_rejections: bool,
    /// Require at least one remote→local cloud fallback (brownout
    /// scenarios must actually brown out).
    pub expect_fallbacks: bool,
    /// Require at least one chain→direct degrade (tier-brownout
    /// scenarios must actually lose their chain head).
    pub expect_chain_fallbacks: bool,
    /// Require a grow to have been denied by `fleet.max_total_shards`,
    /// with the denial recorded as a class's `last_trigger`.
    pub expect_budget_denial: bool,
    /// Require this class to have hit its own `max_shards` ceiling.
    pub expect_max_shards_reached: Option<String>,
    /// Require this class's split to have moved at least once.
    pub expect_split_change: Option<String>,
    /// Floor on branch-gate observations consumed by the exit-rate
    /// estimators (summed over classes).
    pub min_estimator_observations: Option<u64>,
}

// ------------------------------------------------------------ helpers

fn req(t: &Json, key: &str, at: &str) -> Result<Json> {
    t.get(key)
        .cloned()
        .ok_or_else(|| anyhow!("{at}: missing required key '{key}'"))
}

fn req_f64(t: &Json, key: &str, at: &str) -> Result<f64> {
    req(t, key, at)?
        .as_f64()
        .ok_or_else(|| anyhow!("{at}: '{key}' must be a number"))
}

fn req_str(t: &Json, key: &str, at: &str) -> Result<String> {
    Ok(req(t, key, at)?
        .as_str()
        .ok_or_else(|| anyhow!("{at}: '{key}' must be a string"))?
        .to_string())
}

fn opt_f64(t: &Json, key: &str, at: &str) -> Result<Option<f64>> {
    match t.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| anyhow!("{at}: '{key}' must be a number")),
    }
}

fn opt_u64(t: &Json, key: &str, at: &str) -> Result<Option<u64>> {
    match opt_f64(t, key, at)? {
        None => Ok(None),
        Some(v) if v >= 0.0 && v.fract() == 0.0 => Ok(Some(v as u64)),
        Some(v) => bail!("{at}: '{key}' must be a non-negative integer, got {v}"),
    }
}

fn opt_bool(t: &Json, key: &str, at: &str) -> Result<Option<bool>> {
    match t.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_bool()
            .map(Some)
            .ok_or_else(|| anyhow!("{at}: '{key}' must be a boolean")),
    }
}

fn opt_str(t: &Json, key: &str, at: &str) -> Result<Option<String>> {
    match t.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_str()
            .map(|s| Some(s.to_string()))
            .ok_or_else(|| anyhow!("{at}: '{key}' must be a string")),
    }
}

// ------------------------------------------------------------ parsing

impl ScenarioSpec {
    /// Read and fully validate a scenario file.
    pub fn load(path: &Path) -> Result<ScenarioSpec> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading scenario file {}", path.display()))?;
        ScenarioSpec::parse_str(&text)
            .with_context(|| format!("in scenario file {}", path.display()))
    }

    /// Parse and fully validate scenario TOML text.
    pub fn parse_str(text: &str) -> Result<ScenarioSpec> {
        let doc = toml::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut settings = Settings::default();
        settings.apply(&doc)?;
        settings.validate()?;
        ScenarioSpec::from_doc(&doc, settings)
    }

    fn from_doc(doc: &Json, settings: Settings) -> Result<ScenarioSpec> {
        let sc = doc
            .get("scenario")
            .ok_or_else(|| anyhow!("missing [scenario] table"))?;
        let name = req_str(sc, "name", "[scenario]")?;
        let duration_s = req_f64(sc, "duration_s", "[scenario]")?;
        let tick_ms = opt_f64(sc, "tick_ms", "[scenario]")?.unwrap_or(20.0);
        let window_s = opt_f64(sc, "window_s", "[scenario]")?.unwrap_or(1.0);
        let seed = opt_u64(sc, "seed", "[scenario]")?.unwrap_or(42);
        let loopback_cloud = opt_bool(sc, "loopback_cloud", "[scenario]")?.unwrap_or(false);

        let workloads = match doc.get("workload") {
            None => Vec::new(),
            Some(w) => {
                let arr = w
                    .as_arr()
                    .ok_or_else(|| anyhow!("[[workload]] must be an array of tables"))?;
                let mut out = Vec::with_capacity(arr.len());
                for (i, t) in arr.iter().enumerate() {
                    let at = format!("workload[{i}]");
                    out.push(WorkloadSpec {
                        class: req_str(t, "class", &at)?,
                        rate_rps: req_f64(t, "rate_rps", &at)?,
                        class1_fraction: opt_f64(t, "class1_fraction", &at)?.unwrap_or(0.5),
                    });
                }
                out
            }
        };

        let events = match doc.get("event") {
            None => Vec::new(),
            Some(e) => {
                let arr = e
                    .as_arr()
                    .ok_or_else(|| anyhow!("[[event]] must be an array of tables"))?;
                let mut out = Vec::with_capacity(arr.len());
                for (i, t) in arr.iter().enumerate() {
                    out.push(parse_event(i, t)?);
                }
                out
            }
        };

        let slo = match doc.get("slo") {
            None => SloSpec {
                zero_drops: true,
                ..SloSpec::default()
            },
            Some(t) => SloSpec {
                p99_ms: opt_f64(t, "p99_ms", "[slo]")?,
                max_rejection_rate: opt_f64(t, "max_rejection_rate", "[slo]")?,
                zero_drops: opt_bool(t, "zero_drops", "[slo]")?.unwrap_or(true),
                min_completed: opt_u64(t, "min_completed", "[slo]")?,
                expect_rejections: opt_bool(t, "expect_rejections", "[slo]")?.unwrap_or(false),
                expect_fallbacks: opt_bool(t, "expect_fallbacks", "[slo]")?.unwrap_or(false),
                expect_chain_fallbacks: opt_bool(t, "expect_chain_fallbacks", "[slo]")?
                    .unwrap_or(false),
                expect_budget_denial: opt_bool(t, "expect_budget_denial", "[slo]")?
                    .unwrap_or(false),
                expect_max_shards_reached: opt_str(t, "expect_max_shards_reached", "[slo]")?,
                expect_split_change: opt_str(t, "expect_split_change", "[slo]")?,
                min_estimator_observations: opt_u64(t, "min_estimator_observations", "[slo]")?,
            },
        };

        let spec = ScenarioSpec {
            name,
            duration_s,
            tick_ms,
            window_s,
            seed,
            loopback_cloud,
            workloads,
            events,
            slo,
            settings,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// The class names a scenario may reference: the `[[link_class]]`
    /// entries, in declaration order (= [`crate::fleet::LinkClass`]
    /// index order).
    pub fn class_names(&self) -> Vec<&str> {
        self.settings
            .link_classes
            .iter()
            .map(|c| c.name.as_str())
            .collect()
    }

    fn check_class(&self, name: &str, at: &str) -> Result<()> {
        if self
            .settings
            .link_classes
            .iter()
            .any(|c| c.name.eq_ignore_ascii_case(name))
        {
            return Ok(());
        }
        bail!(
            "{at}: unknown link class '{name}' (configured classes: {})",
            self.class_names().join(", ")
        );
    }

    fn validate(&self) -> Result<()> {
        if self.name.is_empty()
            || !self
                .name
                .chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' || c == '-')
        {
            bail!(
                "[scenario]: name '{}' must be non-empty [a-z0-9_-] \
                 (it names BENCH_scenario_<name>.json)",
                self.name
            );
        }
        if !(self.duration_s.is_finite() && self.duration_s > 0.0) {
            bail!("[scenario]: duration_s must be positive, got {}", self.duration_s);
        }
        if !(self.tick_ms.is_finite() && self.tick_ms > 0.0) {
            bail!("[scenario]: tick_ms must be positive, got {}", self.tick_ms);
        }
        if !(self.window_s.is_finite() && self.window_s * 1e3 >= self.tick_ms) {
            bail!(
                "[scenario]: window_s ({}) must be at least one tick ({} ms)",
                self.window_s,
                self.tick_ms
            );
        }
        if self.settings.link_classes.is_empty() {
            bail!(
                "a scenario needs at least one [[link_class]] entry — the default \
                 single-class fallback is for `serve`, not for scripted runs"
            );
        }
        if self.workloads.is_empty() {
            bail!("a scenario needs at least one [[workload]] entry");
        }
        for (i, w) in self.workloads.iter().enumerate() {
            let at = format!("workload[{i}]");
            self.check_class(&w.class, &at)?;
            if !(w.rate_rps.is_finite() && w.rate_rps >= 0.0) {
                bail!("{at}: rate_rps must be >= 0, got {}", w.rate_rps);
            }
            if !(0.0..=1.0).contains(&w.class1_fraction) {
                bail!("{at}: class1_fraction must be in 0..=1, got {}", w.class1_fraction);
            }
            if self.workloads[..i]
                .iter()
                .any(|p| p.class.eq_ignore_ascii_case(&w.class))
            {
                bail!("{at}: duplicate workload for class '{}'", w.class);
            }
        }
        if !self.settings.tiers.is_empty() {
            if !self.loopback_cloud {
                bail!(
                    "a scenario with a [[tier]] chain needs [scenario] \
                     loopback_cloud = true — the harness stands up one loopback \
                     server per tier and rewrites the placeholder addrs to them"
                );
            }
            if self.settings.fleet.online_estimation {
                bail!(
                    "a [[tier]] chain is incompatible with [fleet] \
                     online_estimation = true (chain cut vectors are solved once \
                     at startup; estimation re-solves the two-tier split)"
                );
            }
        }
        self.validate_events()?;
        self.validate_slo()
    }

    fn validate_events(&self) -> Result<()> {
        let mut prev_at = 0.0f64;
        // Some(t) while a brownout opened at `t` is still unclosed.
        let mut down_since: Option<f64> = None;
        // Same, for the chain-head brownout window.
        let mut tier_down_since: Option<f64> = None;
        for (i, ev) in self.events.iter().enumerate() {
            let at = format!("event[{i}] ({})", ev.kind.name());
            if !(ev.at_s.is_finite() && ev.at_s >= 0.0 && ev.at_s <= self.duration_s) {
                bail!(
                    "{at}: at_s = {} outside the scenario's 0..={} s",
                    ev.at_s,
                    self.duration_s
                );
            }
            if i > 0 && ev.at_s < prev_at {
                bail!(
                    "{at}: out of order — at_s = {} but event[{}] is at {} \
                     (events must be sorted by at_s)",
                    ev.at_s,
                    i - 1,
                    prev_at
                );
            }
            prev_at = ev.at_s;
            match &ev.kind {
                EventKind::SetRate { class, rate_rps } => {
                    self.check_class(class, &at)?;
                    if !(rate_rps.is_finite() && *rate_rps >= 0.0) {
                        bail!("{at}: rate_rps must be >= 0, got {rate_rps}");
                    }
                }
                EventKind::RampRate {
                    class,
                    rate_rps,
                    over_s,
                } => {
                    self.check_class(class, &at)?;
                    if !(rate_rps.is_finite() && *rate_rps >= 0.0) {
                        bail!("{at}: rate_rps must be >= 0, got {rate_rps}");
                    }
                    if !(over_s.is_finite() && *over_s > 0.0) {
                        bail!("{at}: over_s must be positive, got {over_s}");
                    }
                }
                EventKind::SetBandwidth { class, mbps } => {
                    self.check_class(class, &at)?;
                    if !(mbps.is_finite() && *mbps > 0.0) {
                        bail!("{at}: mbps must be positive, got {mbps}");
                    }
                }
                EventKind::Reassign { from, to, fraction } => {
                    self.check_class(from, &at)?;
                    self.check_class(to, &at)?;
                    if from.eq_ignore_ascii_case(to) {
                        bail!("{at}: cannot reassign class '{from}' to itself");
                    }
                    if !(0.0..=1.0).contains(fraction) {
                        bail!("{at}: fraction must be in 0..=1, got {fraction}");
                    }
                }
                EventKind::CloudDown => {
                    if !self.loopback_cloud {
                        bail!(
                            "{at}: cloud_down requires [scenario] loopback_cloud = true \
                             (an in-process cloud cannot brown out)"
                        );
                    }
                    if let Some(since) = down_since {
                        bail!(
                            "{at}: overlapping brownout windows — cloud already down \
                             since the cloud_down at {since} s (close it with cloud_up first)"
                        );
                    }
                    down_since = Some(ev.at_s);
                }
                EventKind::CloudUp => {
                    if !self.loopback_cloud {
                        bail!("{at}: cloud_up requires [scenario] loopback_cloud = true");
                    }
                    if down_since.take().is_none() {
                        bail!("{at}: cloud_up without a preceding cloud_down — the cloud is up");
                    }
                }
                EventKind::TierDown => {
                    if self.settings.tiers.len() < 2 {
                        bail!(
                            "{at}: tier_down requires a [[tier]] chain (at least 2 \
                             entries) — without one there is no chain head to lose"
                        );
                    }
                    if let Some(since) = tier_down_since {
                        bail!(
                            "{at}: overlapping tier-brownout windows — the chain head is \
                             already down since the tier_down at {since} s (close it \
                             with tier_up first)"
                        );
                    }
                    tier_down_since = Some(ev.at_s);
                }
                EventKind::TierUp => {
                    if self.settings.tiers.len() < 2 {
                        bail!("{at}: tier_up requires a [[tier]] chain (at least 2 entries)");
                    }
                    if tier_down_since.take().is_none() {
                        bail!(
                            "{at}: tier_up without a preceding tier_down — the chain \
                             head is up"
                        );
                    }
                }
                EventKind::SetExitBias {
                    class,
                    class1_fraction,
                } => {
                    self.check_class(class, &at)?;
                    if !(0.0..=1.0).contains(class1_fraction) {
                        bail!("{at}: class1_fraction must be in 0..=1, got {class1_fraction}");
                    }
                }
            }
        }
        Ok(())
    }

    fn validate_slo(&self) -> Result<()> {
        let s = &self.slo;
        if let Some(p) = s.p99_ms {
            if !(p.is_finite() && p > 0.0) {
                bail!("[slo]: p99_ms must be positive, got {p}");
            }
        }
        if let Some(r) = s.max_rejection_rate {
            if !(0.0..=1.0).contains(&r) {
                bail!("[slo]: max_rejection_rate must be in 0..=1, got {r}");
            }
        }
        if let Some(c) = &s.expect_max_shards_reached {
            self.check_class(c, "[slo] expect_max_shards_reached")?;
            if !self.settings.fleet.autoscale {
                bail!(
                    "[slo]: expect_max_shards_reached needs [fleet] autoscale = true — \
                     a fixed fleet never moves toward its ceiling"
                );
            }
        }
        if let Some(c) = &s.expect_split_change {
            self.check_class(c, "[slo] expect_split_change")?;
        }
        if s.expect_budget_denial {
            if self.settings.fleet.max_total_shards.is_none() {
                bail!(
                    "[slo]: expect_budget_denial needs [fleet] max_total_shards — \
                     without a budget nothing can be denied by it"
                );
            }
            if !self.settings.fleet.autoscale {
                bail!("[slo]: expect_budget_denial needs [fleet] autoscale = true");
            }
        }
        if s.expect_fallbacks && !self.loopback_cloud {
            bail!(
                "[slo]: expect_fallbacks needs [scenario] loopback_cloud = true — \
                 an in-process cloud has no remote path to fall back from"
            );
        }
        if s.expect_chain_fallbacks && self.settings.tiers.len() < 2 {
            bail!(
                "[slo]: expect_chain_fallbacks needs a [[tier]] chain (at least 2 \
                 entries) — a two-tier fleet has no chain to degrade from"
            );
        }
        if s.min_estimator_observations.is_some() && !self.settings.fleet.online_estimation {
            bail!(
                "[slo]: min_estimator_observations needs [fleet] online_estimation = true"
            );
        }
        Ok(())
    }
}

fn parse_event(i: usize, t: &Json) -> Result<Event> {
    let at = format!("event[{i}]");
    let at_s = req_f64(t, "at_s", &at)?;
    let kind_s = req_str(t, "kind", &at)?;
    let kind = match kind_s.as_str() {
        "set_rate" => EventKind::SetRate {
            class: req_str(t, "class", &at)?,
            rate_rps: req_f64(t, "rate_rps", &at)?,
        },
        "ramp_rate" => EventKind::RampRate {
            class: req_str(t, "class", &at)?,
            rate_rps: req_f64(t, "rate_rps", &at)?,
            over_s: req_f64(t, "over_s", &at)?,
        },
        "set_bandwidth" => EventKind::SetBandwidth {
            class: req_str(t, "class", &at)?,
            mbps: req_f64(t, "mbps", &at)?,
        },
        "reassign" => EventKind::Reassign {
            from: req_str(t, "from", &at)?,
            to: req_str(t, "to", &at)?,
            fraction: req_f64(t, "fraction", &at)?,
        },
        "cloud_down" => EventKind::CloudDown,
        "cloud_up" => EventKind::CloudUp,
        "tier_down" => EventKind::TierDown,
        "tier_up" => EventKind::TierUp,
        "set_exit_bias" => EventKind::SetExitBias {
            class: req_str(t, "class", &at)?,
            class1_fraction: req_f64(t, "class1_fraction", &at)?,
        },
        other => bail!("{at}: unknown event kind '{other}' (known kinds: {KNOWN_KINDS})"),
    };
    Ok(Event { at_s, kind })
}
