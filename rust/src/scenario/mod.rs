//! Scenario harness: a deterministic fleet digital twin driven by a
//! declarative scenario DSL.
//!
//! [`spec`] parses and validates `.toml` scenario files (timed load
//! curves, link churn, traffic reassignment, cloud brownouts, exit-rate
//! drift, plus an SLO block); [`runner`] replays them against a *real*
//! fleet in lockstep virtual time and emits a
//! `BENCH_scenario_<name>.json` whose only nondeterministic field is
//! the `"wall"` object — same seed, same file ⇒ bit-identical output.
//!
//! Canonical scenarios live in `scenarios/` at the repo root and double
//! as integration tests (`rust/tests/scenario_canonical.rs`); run one
//! with `branchyserve scenario run scenarios/diurnal.toml`.

pub mod runner;
pub mod spec;

pub use runner::{run, ScenarioOutcome, SloCheck};
pub use spec::{Event, EventKind, ScenarioSpec, SloSpec, WorkloadSpec};
