//! Declarative CLI parser (clap is unavailable offline — DESIGN.md §3).
//!
//! Supports subcommands, long/short flags, `--flag value` and
//! `--flag=value` forms, boolean switches, defaults, required flags, and
//! generated `--help` text at both program and subcommand level.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
pub struct Flag {
    pub name: &'static str,
    pub short: Option<char>,
    /// Boolean switch if false; value-taking otherwise.
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub required: bool,
    pub help: &'static str,
}

impl Flag {
    pub fn value(name: &'static str, help: &'static str) -> Flag {
        Flag {
            name,
            short: None,
            takes_value: true,
            default: None,
            required: false,
            help,
        }
    }

    pub fn switch(name: &'static str, help: &'static str) -> Flag {
        Flag {
            name,
            short: None,
            takes_value: false,
            default: None,
            required: false,
            help,
        }
    }

    pub fn short(mut self, c: char) -> Flag {
        self.short = Some(c);
        self
    }

    pub fn default(mut self, v: &'static str) -> Flag {
        self.default = Some(v);
        self
    }

    pub fn required(mut self) -> Flag {
        self.required = true;
        self
    }
}

#[derive(Debug, Clone)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<Flag>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Command {
        Command {
            name,
            about,
            flags: Vec::new(),
        }
    }

    pub fn flag(mut self, f: Flag) -> Command {
        self.flags.push(f);
        self
    }
}

#[derive(Debug, Clone)]
pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    /// Flags valid before/without a subcommand (e.g. --config).
    pub global_flags: Vec<Flag>,
    pub commands: Vec<Command>,
}

/// Parse outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum Parsed {
    /// Help was requested; the rendered text is returned for printing.
    Help(String),
    /// A subcommand was matched.
    Run(Invocation),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Invocation {
    pub command: String,
    pub values: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positionals: Vec<String>,
}

impl Invocation {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        match self.values.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name}: '{v}' is not a number")),
        }
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        match self.values.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name}: '{v}' is not an integer")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[derive(Debug, thiserror::Error, PartialEq)]
#[error("{0}")]
pub struct CliError(pub String);

impl Cli {
    pub fn parse<I: IntoIterator<Item = String>>(&self, args: I) -> Result<Parsed, CliError> {
        let args: Vec<String> = args.into_iter().collect();
        let mut iter = args.into_iter().peekable();

        // Program-level flags until a subcommand shows up.
        let mut global_values = BTreeMap::new();
        let mut global_switches = Vec::new();
        let command = loop {
            match iter.next() {
                None => return Ok(Parsed::Help(self.render_help(None))),
                Some(a) if a == "--help" || a == "-h" || a == "help" => {
                    // `help <cmd>` form:
                    if let Some(next) = iter.peek() {
                        if let Some(cmd) = self.commands.iter().find(|c| c.name == *next) {
                            return Ok(Parsed::Help(self.render_help(Some(cmd))));
                        }
                    }
                    return Ok(Parsed::Help(self.render_help(None)));
                }
                Some(a) if a.starts_with('-') => {
                    self.consume_flag(
                        &self.global_flags,
                        &a,
                        &mut iter,
                        &mut global_values,
                        &mut global_switches,
                    )?;
                }
                Some(a) => break a,
            }
        };

        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == command)
            .ok_or_else(|| {
                CliError(format!(
                    "unknown command '{command}' (try '{} --help')",
                    self.program
                ))
            })?;

        let mut values = global_values;
        let mut switches = global_switches;
        let mut positionals = Vec::new();
        while let Some(a) = iter.next() {
            if a == "--help" || a == "-h" {
                return Ok(Parsed::Help(self.render_help(Some(cmd))));
            }
            if a.starts_with('-') && a.len() > 1 {
                // Try command flags first, then globals.
                let all: Vec<Flag> = cmd
                    .flags
                    .iter()
                    .chain(self.global_flags.iter())
                    .cloned()
                    .collect();
                self.consume_flag(&all, &a, &mut iter, &mut values, &mut switches)?;
            } else {
                positionals.push(a);
            }
        }

        // Defaults + required checks.
        for f in cmd.flags.iter().chain(self.global_flags.iter()) {
            if f.takes_value && !values.contains_key(f.name) {
                if let Some(d) = f.default {
                    values.insert(f.name.to_string(), d.to_string());
                } else if f.required {
                    return Err(CliError(format!(
                        "missing required flag --{} for '{}'",
                        f.name, cmd.name
                    )));
                }
            }
        }

        Ok(Parsed::Run(Invocation {
            command,
            values,
            switches,
            positionals,
        }))
    }

    fn consume_flag(
        &self,
        flags: &[Flag],
        arg: &str,
        iter: &mut std::iter::Peekable<std::vec::IntoIter<String>>,
        values: &mut BTreeMap<String, String>,
        switches: &mut Vec<String>,
    ) -> Result<(), CliError> {
        let (name_part, inline_value) = match arg.split_once('=') {
            Some((n, v)) => (n.to_string(), Some(v.to_string())),
            None => (arg.to_string(), None),
        };
        let flag = flags
            .iter()
            .find(|f| {
                name_part == format!("--{}", f.name)
                    || f.short
                        .map(|c| name_part == format!("-{c}"))
                        .unwrap_or(false)
            })
            .ok_or_else(|| CliError(format!("unknown flag '{name_part}'")))?;

        if flag.takes_value {
            let v = match inline_value {
                Some(v) => v,
                None => iter
                    .next()
                    .ok_or_else(|| CliError(format!("--{} needs a value", flag.name)))?,
            };
            values.insert(flag.name.to_string(), v);
        } else {
            if inline_value.is_some() {
                return Err(CliError(format!("--{} takes no value", flag.name)));
            }
            switches.push(flag.name.to_string());
        }
        Ok(())
    }

    pub fn render_help(&self, cmd: Option<&Command>) -> String {
        let mut s = String::new();
        match cmd {
            None => {
                let _ = writeln!(s, "{} — {}\n", self.program, self.about);
                let _ = writeln!(
                    s,
                    "USAGE: {} [GLOBAL FLAGS] <COMMAND> [FLAGS]\n",
                    self.program
                );
                let _ = writeln!(s, "COMMANDS:");
                for c in &self.commands {
                    let _ = writeln!(s, "  {:<18} {}", c.name, c.about);
                }
                if !self.global_flags.is_empty() {
                    let _ = writeln!(s, "\nGLOBAL FLAGS:");
                    for f in &self.global_flags {
                        Self::render_flag(&mut s, f);
                    }
                }
                let _ = writeln!(
                    s,
                    "\nRun '{} <COMMAND> --help' for command details.",
                    self.program
                );
            }
            Some(c) => {
                let _ = writeln!(s, "{} {} — {}\n", self.program, c.name, c.about);
                let _ = writeln!(s, "FLAGS:");
                for f in &c.flags {
                    Self::render_flag(&mut s, f);
                }
                for f in &self.global_flags {
                    Self::render_flag(&mut s, f);
                }
            }
        }
        s
    }

    fn render_flag(s: &mut String, f: &Flag) {
        let mut head = format!("--{}", f.name);
        if let Some(c) = f.short {
            head = format!("-{c}, {head}");
        }
        if f.takes_value {
            head.push_str(" <v>");
        }
        let mut notes = Vec::new();
        if let Some(d) = f.default {
            notes.push(format!("default: {d}"));
        }
        if f.required {
            notes.push("required".into());
        }
        let notes = if notes.is_empty() {
            String::new()
        } else {
            format!(" [{}]", notes.join(", "))
        };
        let _ = writeln!(s, "  {:<26} {}{}", head, f.help, notes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            program: "branchyserve",
            about: "test",
            global_flags: vec![Flag::value("config", "config file").short('c')],
            commands: vec![
                Command::new("plan", "plan a partition")
                    .flag(Flag::value("gamma", "processing factor").default("100"))
                    .flag(Flag::value("network", "profile").required())
                    .flag(Flag::switch("verbose", "talk more").short('v')),
                Command::new("serve", "run the server")
                    .flag(Flag::value("port", "tcp port").default("7878")),
            ],
        }
    }

    fn parse(args: &[&str]) -> Result<Parsed, CliError> {
        cli().parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_values_defaults_switches() {
        let p = parse(&["plan", "--network", "4g", "-v"]).unwrap();
        let Parsed::Run(inv) = p else { panic!() };
        assert_eq!(inv.command, "plan");
        assert_eq!(inv.get("network"), Some("4g"));
        assert_eq!(inv.get("gamma"), Some("100")); // default applied
        assert!(inv.has("verbose"));
    }

    #[test]
    fn equals_form_and_typed_getters() {
        let p = parse(&["plan", "--network=3g", "--gamma=12.5"]).unwrap();
        let Parsed::Run(inv) = p else { panic!() };
        assert_eq!(inv.get_f64("gamma").unwrap(), Some(12.5));
        assert!(inv.get_usize("gamma").is_err());
    }

    #[test]
    fn missing_required_flag() {
        let e = parse(&["plan"]).unwrap_err();
        assert!(e.0.contains("network"), "{e}");
    }

    #[test]
    fn unknown_command_and_flag() {
        assert!(parse(&["fly"]).is_err());
        assert!(parse(&["serve", "--wings"]).is_err());
    }

    #[test]
    fn global_flag_before_command() {
        let p = parse(&["--config", "x.toml", "serve"]).unwrap();
        let Parsed::Run(inv) = p else { panic!() };
        assert_eq!(inv.get("config"), Some("x.toml"));
        assert_eq!(inv.get("port"), Some("7878"));
    }

    #[test]
    fn help_variants() {
        for args in [
            &["--help"][..],
            &["help"],
            &[],
            &["plan", "--help"],
            &["help", "plan"],
        ] {
            match parse(args).unwrap() {
                Parsed::Help(text) => assert!(text.contains("branchyserve")),
                other => panic!("{args:?} -> {other:?}"),
            }
        }
    }

    #[test]
    fn switch_rejects_value() {
        assert!(parse(&["plan", "--network", "4g", "--verbose=yes"]).is_err());
    }

    #[test]
    fn positionals_collected() {
        let p = parse(&["serve", "extra1", "extra2"]).unwrap();
        let Parsed::Run(inv) = p else { panic!() };
        assert_eq!(inv.positionals, vec!["extra1", "extra2"]);
    }
}
