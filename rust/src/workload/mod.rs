//! Workload generation: synthetic image sources (matching the Python
//! dataset's texture classes), Gaussian blur (the Fig. 6 distortion), and
//! an open-loop Poisson load generator.

pub mod blur;
pub mod images;
pub mod loadgen;

pub use images::ImageSource;
pub use loadgen::{LoadGen, LoadReport};
