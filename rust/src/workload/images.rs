//! Synthetic 3x32x32 image source mirroring `python/compile/data.py`:
//! class 0 = low-frequency Gaussian blobs, class 1 = oriented sinusoid
//! stripes, tinted + noised + standardized identically, so the trained
//! artifacts classify Rust-generated workloads just as well as the
//! Python-generated fixtures.

use crate::runtime::HostTensor;
use crate::util::rng::Pcg32;

pub const IMG: usize = 32;
pub const CHANNELS: usize = 3;

pub struct ImageSource {
    rng: Pcg32,
    /// Probability a sample is class 1 (stripes). 0.5 mirrors
    /// `data.py`; the scenario harness drifts it mid-run to shift the
    /// observed exit rate (stripes exit the side branch far more often
    /// than blobs once the gate is trained on them).
    class1_fraction: f64,
}

impl ImageSource {
    pub fn new(seed: u64) -> ImageSource {
        ImageSource {
            rng: Pcg32::seeded(seed),
            class1_fraction: 0.5,
        }
    }

    /// Change the class mix mid-stream. The label draw consumes one RNG
    /// draw whatever the fraction, so two sources with the same seed
    /// and the same *schedule* of `set_mix` calls stay bit-identical.
    pub fn set_mix(&mut self, class1_fraction: f64) {
        self.class1_fraction = class1_fraction.clamp(0.0, 1.0);
    }

    /// One labeled sample: (CHW tensor, class).
    pub fn sample(&mut self) -> (HostTensor, usize) {
        let label = self.rng.bool(self.class1_fraction) as usize;
        let base = if label == 1 {
            self.stripes()
        } else {
            self.blobs()
        };
        // Cross-contamination like data.py: mix in a faint other-class.
        let other = if label == 1 {
            self.blobs()
        } else {
            self.stripes()
        };
        let mix = self.rng.range_f64(0.0, 0.35) as f32;
        let mixed: Vec<f32> = base
            .iter()
            .zip(&other)
            .map(|(&b, &o)| (1.0 - mix) * b + mix * o)
            .collect();

        let mut data = Vec::with_capacity(CHANNELS * IMG * IMG);
        for _c in 0..CHANNELS {
            let tint = self.rng.range_f64(0.6, 1.0) as f32;
            for &v in &mixed {
                let noise = self.rng.normal(0.0, 0.12) as f32;
                data.push(((v * tint + noise) - 0.45) / 0.3);
            }
        }
        (
            HostTensor::new(vec![CHANNELS, IMG, IMG], data).unwrap(),
            label,
        )
    }

    pub fn batch(&mut self, n: usize) -> (Vec<HostTensor>, Vec<usize>) {
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let (x, y) = self.sample();
            xs.push(x);
            ys.push(y);
        }
        (xs, ys)
    }

    fn blobs(&mut self) -> Vec<f32> {
        let mut img = vec![0f32; IMG * IMG];
        let n_blobs = 3 + self.rng.below(4);
        for _ in 0..n_blobs {
            let cy = self.rng.range_f64(4.0, (IMG - 4) as f64);
            let cx = self.rng.range_f64(4.0, (IMG - 4) as f64);
            let sig = self.rng.range_f64(3.0, 7.0);
            let amp = self.rng.range_f64(0.5, 1.0) as f32;
            for y in 0..IMG {
                for x in 0..IMG {
                    let d2 = (y as f64 - cy).powi(2) + (x as f64 - cx).powi(2);
                    img[y * IMG + x] += amp * (-d2 / (2.0 * sig * sig)).exp() as f32;
                }
            }
        }
        img
    }

    fn stripes(&mut self) -> Vec<f32> {
        let theta = self.rng.range_f64(0.0, std::f64::consts::PI);
        let freq = self.rng.range_f64(0.6, 1.4);
        let phase = self.rng.range_f64(0.0, std::f64::consts::TAU);
        let (s, c) = theta.sin_cos();
        let mut img = vec![0f32; IMG * IMG];
        for y in 0..IMG {
            for x in 0..IMG {
                let proj = c * x as f64 + s * y as f64;
                img[y * IMG + x] = (0.5 + 0.5 * (freq * proj + phase).sin()) as f32;
            }
        }
        img
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let mut a = ImageSource::new(9);
        let mut b = ImageSource::new(9);
        let (xa, ya) = a.sample();
        let (xb, yb) = b.sample();
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
        assert_eq!(xa.shape(), &[3, 32, 32]);
    }

    #[test]
    fn mix_shifts_labels_without_breaking_determinism() {
        let mut a = ImageSource::new(9);
        let mut b = ImageSource::new(9);
        a.set_mix(1.0);
        b.set_mix(1.0);
        let (xa, ya) = a.sample();
        let (xb, yb) = b.sample();
        assert_eq!((xa, ya), (xb, yb));
        // Extreme fractions pin the label entirely.
        let mut src = ImageSource::new(3);
        src.set_mix(1.0);
        assert!(src.batch(16).1.iter().all(|&y| y == 1));
        src.set_mix(0.0);
        assert!(src.batch(16).1.iter().all(|&y| y == 0));
    }

    #[test]
    fn both_classes_generated() {
        let mut src = ImageSource::new(1);
        let (_, ys) = src.batch(64);
        assert!(ys.iter().any(|&y| y == 0));
        assert!(ys.iter().any(|&y| y == 1));
    }

    #[test]
    fn stripes_have_higher_gradient_energy() {
        let mut src = ImageSource::new(2);
        let (xs, ys) = src.batch(128);
        let hf = |t: &HostTensor| -> f32 {
            let d = t.data();
            let mut e = 0.0;
            // channel 0 horizontal gradients
            for y in 0..IMG {
                for x in 0..IMG - 1 {
                    let v = d[y * IMG + x + 1] - d[y * IMG + x];
                    e += v * v;
                }
            }
            e
        };
        let (mut e0, mut n0, mut e1, mut n1) = (0.0, 0, 0.0, 0);
        for (x, y) in xs.iter().zip(&ys) {
            if *y == 0 {
                e0 += hf(x);
                n0 += 1;
            } else {
                e1 += hf(x);
                n1 += 1;
            }
        }
        assert!(e1 / n1 as f32 > 1.5 * (e0 / n0 as f32));
    }
}
