//! Separable Gaussian blur on CHW tensors — the Rust twin of
//! `data.gaussian_blur` (same OpenCV sigma convention, same reflect
//! padding), so the serving path can degrade image quality on the fly
//! for the Fig. 6 serving-mode experiment.

use crate::runtime::HostTensor;

/// OpenCV-convention sigma for a kernel size.
pub fn sigma_for(ksize: usize) -> f64 {
    0.3 * ((ksize as f64 - 1.0) * 0.5 - 1.0) + 0.8
}

/// Normalized 1-D Gaussian taps.
pub fn kernel1d(ksize: usize) -> Vec<f32> {
    let sigma = sigma_for(ksize);
    let r = (ksize - 1) / 2;
    let mut k: Vec<f32> = (0..ksize)
        .map(|i| {
            let t = i as f64 - r as f64;
            (-t * t / (2.0 * sigma * sigma)).exp() as f32
        })
        .collect();
    let sum: f32 = k.iter().sum();
    for v in &mut k {
        *v /= sum;
    }
    k
}

/// Reflect-pad index (repeated reflection for kernels larger than axis).
fn reflect(mut i: i64, n: i64) -> usize {
    // Mirror without repeating the edge sample (np.pad mode="reflect").
    loop {
        if i < 0 {
            i = -i;
        } else if i >= n {
            i = 2 * (n - 1) - i;
        } else {
            return i as usize;
        }
    }
}

/// Blur a CHW tensor. `ksize <= 1` is the identity.
pub fn gaussian_blur(t: &HostTensor, ksize: usize) -> HostTensor {
    if ksize <= 1 {
        return t.clone();
    }
    let shape = t.shape().to_vec();
    assert_eq!(shape.len(), 3, "expected CHW");
    let (c, h, w) = (shape[0], shape[1], shape[2]);
    let k = kernel1d(ksize);
    let r = (ksize - 1) as i64 / 2;

    let src = t.data();
    let mut mid = vec![0f32; c * h * w];
    // Vertical pass.
    for ch in 0..c {
        for y in 0..h {
            for x in 0..w {
                let mut acc = 0f32;
                for (ki, &tap) in k.iter().enumerate() {
                    let yy = reflect(y as i64 + ki as i64 - r, h as i64);
                    acc += tap * src[ch * h * w + yy * w + x];
                }
                mid[ch * h * w + y * w + x] = acc;
            }
        }
    }
    // Horizontal pass.
    let mut out = vec![0f32; c * h * w];
    for ch in 0..c {
        for y in 0..h {
            for x in 0..w {
                let mut acc = 0f32;
                for (ki, &tap) in k.iter().enumerate() {
                    let xx = reflect(x as i64 + ki as i64 - r, w as i64);
                    acc += tap * mid[ch * h * w + y * w + xx];
                }
                out[ch * h * w + y * w + x] = acc;
            }
        }
    }
    HostTensor::new(shape, out).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::images::ImageSource;

    #[test]
    fn kernel_normalized_and_symmetric() {
        for ks in [3, 5, 15, 65] {
            let k = kernel1d(ks);
            assert_eq!(k.len(), ks);
            let sum: f32 = k.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            for i in 0..ks / 2 {
                assert!((k[i] - k[ks - 1 - i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn identity_below_threshold() {
        let mut src = ImageSource::new(3);
        let (img, _) = src.sample();
        assert_eq!(gaussian_blur(&img, 0), img);
        assert_eq!(gaussian_blur(&img, 1), img);
    }

    #[test]
    fn variance_decreases_with_ksize() {
        let mut src = ImageSource::new(4);
        let (img, _) = src.sample();
        let var = |t: &HostTensor| {
            let d = t.data();
            let m: f32 = d.iter().sum::<f32>() / d.len() as f32;
            d.iter().map(|v| (v - m) * (v - m)).sum::<f32>() / d.len() as f32
        };
        let v0 = var(&img);
        let v5 = var(&gaussian_blur(&img, 5));
        let v15 = var(&gaussian_blur(&img, 15));
        let v65 = var(&gaussian_blur(&img, 65));
        assert!(v0 > v5 && v5 > v15 && v15 > v65, "{v0} {v5} {v15} {v65}");
    }

    #[test]
    fn mean_preserved() {
        let mut src = ImageSource::new(5);
        let (img, _) = src.sample();
        let mean = |t: &HostTensor| t.data().iter().sum::<f32>() / t.len() as f32;
        assert!((mean(&img) - mean(&gaussian_blur(&img, 15))).abs() < 0.05);
    }

    #[test]
    fn reflect_indexing() {
        assert_eq!(reflect(-1, 5), 1);
        assert_eq!(reflect(-2, 5), 2);
        assert_eq!(reflect(5, 5), 3);
        assert_eq!(reflect(6, 5), 2);
        assert_eq!(reflect(0, 5), 0);
        // Kernel larger than the axis: repeated reflection terminates.
        assert_eq!(reflect(13, 5), 3);
        assert_eq!(reflect(-9, 5), 1);
    }

    #[test]
    fn matches_python_convention_sigma() {
        assert!((sigma_for(5) - 1.1).abs() < 1e-12);
        assert!((sigma_for(65) - 10.1).abs() < 1e-9);
    }
}
