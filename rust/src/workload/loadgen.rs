//! Open-loop Poisson load generator driving a [`Coordinator`] directly
//! (the serve example drives the TCP front-end instead).
//!
//! Open-loop means arrivals are independent of completions — the honest
//! way to measure a serving system's latency under load (closed-loop
//! generators hide queueing collapse).

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::coordinator::{Coordinator, InferenceResponse};
use crate::util::rng::Pcg32;
use crate::util::stats::percentile;

use super::images::ImageSource;

pub struct LoadGen {
    pub rate_rps: f64,
    pub duration: Duration,
    pub seed: u64,
}

/// Outcome of a load run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub offered: u64,
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub edge_exits: u64,
    pub correct: u64,
    /// Latencies of completed requests, seconds.
    pub latencies: Vec<f64>,
    pub wall_s: f64,
}

impl LoadReport {
    pub fn p(&self, q: f64) -> f64 {
        if self.latencies.is_empty() {
            f64::NAN
        } else {
            percentile(&self.latencies, q)
        }
    }

    pub fn mean_latency(&self) -> f64 {
        if self.latencies.is_empty() {
            f64::NAN
        } else {
            self.latencies.iter().sum::<f64>() / self.latencies.len() as f64
        }
    }

    pub fn throughput(&self) -> f64 {
        self.completed as f64 / self.wall_s.max(1e-9)
    }

    pub fn accuracy(&self) -> f64 {
        if self.completed == 0 {
            f64::NAN
        } else {
            self.correct as f64 / self.completed as f64
        }
    }

    pub fn exit_rate(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.edge_exits as f64 / self.completed as f64
        }
    }
}

impl LoadGen {
    /// Drive the coordinator with Poisson arrivals; block until all
    /// accepted requests complete (or the 30 s grace period lapses).
    pub fn run(&self, coordinator: &Coordinator) -> LoadReport {
        let mut rng = Pcg32::seeded(self.seed);
        let mut source = ImageSource::new(self.seed.wrapping_add(1));
        let start = Instant::now();
        let mut offered = 0u64;
        let mut accepted = 0u64;
        let mut rejected = 0u64;
        let mut pending: Vec<(mpsc::Receiver<InferenceResponse>, usize)> = Vec::new();

        let mut next_arrival = start;
        while start.elapsed() < self.duration {
            let now = Instant::now();
            if now < next_arrival {
                std::thread::sleep(next_arrival - now);
            }
            next_arrival += Duration::from_secs_f64(rng.exponential(self.rate_rps));
            offered += 1;
            let (img, label) = source.sample();
            match coordinator.submit(img) {
                Ok((_, rx)) => {
                    accepted += 1;
                    pending.push((rx, label));
                }
                Err(_) => rejected += 1,
            }
        }

        // Collect completions.
        let mut latencies = Vec::with_capacity(pending.len());
        let mut completed = 0u64;
        let mut edge_exits = 0u64;
        let mut correct = 0u64;
        let grace = Duration::from_secs(30);
        for (rx, label) in pending {
            match rx.recv_timeout(grace) {
                Ok(resp) => {
                    completed += 1;
                    if resp.exited_early() {
                        edge_exits += 1;
                    }
                    if resp.class == label {
                        correct += 1;
                    }
                    latencies.push(resp.latency_s);
                }
                Err(_) => {}
            }
        }

        LoadReport {
            offered,
            accepted,
            rejected,
            completed,
            edge_exits,
            correct,
            latencies,
            wall_s: start.elapsed().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_statistics() {
        let r = LoadReport {
            offered: 10,
            accepted: 9,
            rejected: 1,
            completed: 8,
            edge_exits: 4,
            correct: 6,
            latencies: (1..=8).map(|i| i as f64 * 0.01).collect(),
            wall_s: 2.0,
        };
        assert!((r.mean_latency() - 0.045).abs() < 1e-12);
        assert!((r.throughput() - 4.0).abs() < 1e-12);
        assert!((r.accuracy() - 0.75).abs() < 1e-12);
        assert!((r.exit_rate() - 0.5).abs() < 1e-12);
        assert!(r.p(50.0) > 0.0);
    }

    #[test]
    fn empty_report_is_sane() {
        let r = LoadReport {
            offered: 0,
            accepted: 0,
            rejected: 0,
            completed: 0,
            edge_exits: 0,
            correct: 0,
            latencies: vec![],
            wall_s: 1.0,
        };
        assert!(r.mean_latency().is_nan());
        assert_eq!(r.exit_rate(), 0.0);
    }
}
