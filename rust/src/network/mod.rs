//! Network substrate: the paper's bandwidth profiles (§VI), a link delay
//! model, time-varying bandwidth traces, the simulated edge→cloud
//! channel used by the serving coordinator, and the wire encodings of
//! the activation transfer.

pub mod bandwidth;
pub mod channel;
pub mod encoding;
pub mod trace;

pub use bandwidth::{LinkModel, Profile};
pub use channel::Channel;
pub use encoding::WireEncoding;
pub use trace::BandwidthTrace;
