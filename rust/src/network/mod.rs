//! Network substrate: the paper's bandwidth profiles (§VI), a link delay
//! model, time-varying bandwidth traces, and the simulated edge→cloud
//! channel used by the serving coordinator.

pub mod bandwidth;
pub mod channel;
pub mod trace;

pub use bandwidth::{LinkModel, Profile};
pub use channel::Channel;
pub use trace::BandwidthTrace;
