//! Bandwidth profiles and the link delay model.
//!
//! The paper evaluates with average uplink rates of 1.10 Mbps (3G),
//! 5.85 Mbps (4G) and 18.80 Mbps (Wi-Fi), taken from DADS [6], and models
//! the communication time of layer v_i as `t_i^net = alpha_i / B`.

use anyhow::{bail, Result};

/// The paper's named uplink profiles (§VI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    ThreeG,
    FourG,
    WiFi,
}

impl Profile {
    pub const ALL: [Profile; 3] = [Profile::ThreeG, Profile::FourG, Profile::WiFi];

    /// Average uplink rate in Mbps (paper §VI, after [6]).
    pub fn uplink_mbps(&self) -> f64 {
        match self {
            Profile::ThreeG => 1.10,
            Profile::FourG => 5.85,
            Profile::WiFi => 18.80,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Profile::ThreeG => "3G",
            Profile::FourG => "4G",
            Profile::WiFi => "WiFi",
        }
    }

    pub fn parse(s: &str) -> Result<Profile> {
        match s.to_ascii_lowercase().as_str() {
            "3g" => Ok(Profile::ThreeG),
            "4g" => Ok(Profile::FourG),
            "wifi" | "wi-fi" => Ok(Profile::WiFi),
            _ => bail!("unknown network profile '{s}' (expected 3g|4g|wifi)"),
        }
    }
}

/// Deterministic link delay model: serialization at `uplink_mbps` plus a
/// fixed one-way base latency. This is what the *planner* uses; the
/// serving-path [`super::channel::Channel`] adds jitter on top.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    pub uplink_mbps: f64,
    /// One-way base latency in seconds (0 reproduces the paper exactly —
    /// its model is pure serialization delay).
    pub rtt_s: f64,
}

impl LinkModel {
    pub fn new(uplink_mbps: f64, rtt_s: f64) -> LinkModel {
        assert!(uplink_mbps > 0.0, "bandwidth must be positive");
        assert!(rtt_s >= 0.0);
        LinkModel { uplink_mbps, rtt_s }
    }

    pub fn from_profile(p: Profile) -> LinkModel {
        LinkModel::new(p.uplink_mbps(), 0.0)
    }

    /// t^net = alpha / B (+ base latency): seconds to upload `bytes`.
    #[inline]
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        bytes as f64 * 8.0 / (self.uplink_mbps * 1e6) + self.rtt_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_rates() {
        assert_eq!(Profile::ThreeG.uplink_mbps(), 1.10);
        assert_eq!(Profile::FourG.uplink_mbps(), 5.85);
        assert_eq!(Profile::WiFi.uplink_mbps(), 18.80);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Profile::parse("3g").unwrap(), Profile::ThreeG);
        assert_eq!(Profile::parse("Wi-Fi").unwrap(), Profile::WiFi);
        assert!(Profile::parse("5g").is_err());
    }

    #[test]
    fn transfer_time_formula() {
        // 12288-byte raw image over 3G: 12288*8 / 1.10e6 s ≈ 89.37 ms.
        let l = LinkModel::from_profile(Profile::ThreeG);
        let t = l.transfer_time(12_288);
        assert!((t - 12_288.0 * 8.0 / 1.10e6).abs() < 1e-12);
        assert!((t - 0.08937).abs() < 1e-4);
    }

    #[test]
    fn rtt_added_once() {
        let l = LinkModel::new(8.0, 0.05);
        // 1e6 bytes at 8 Mbps = 1 s + 50 ms RTT.
        assert!((l.transfer_time(1_000_000) - 1.05).abs() < 1e-9);
        assert!((l.transfer_time(0) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn faster_profile_shorter_time() {
        let bytes = 57_600;
        let t3 = LinkModel::from_profile(Profile::ThreeG).transfer_time(bytes);
        let t4 = LinkModel::from_profile(Profile::FourG).transfer_time(bytes);
        let tw = LinkModel::from_profile(Profile::WiFi).transfer_time(bytes);
        assert!(t3 > t4 && t4 > tw);
    }

    #[test]
    #[should_panic]
    fn zero_bandwidth_panics() {
        LinkModel::new(0.0, 0.0);
    }
}
