//! Bandwidth profiles and the link delay model.
//!
//! The paper evaluates with average uplink rates of 1.10 Mbps (3G),
//! 5.85 Mbps (4G) and 18.80 Mbps (Wi-Fi), taken from DADS [6], and models
//! the communication time of layer v_i as `t_i^net = alpha_i / B`.

use anyhow::{bail, Result};

/// The paper's named uplink profiles (§VI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    ThreeG,
    FourG,
    WiFi,
}

impl Profile {
    pub const ALL: [Profile; 3] = [Profile::ThreeG, Profile::FourG, Profile::WiFi];

    /// Average uplink rate in Mbps (paper §VI, after [6]).
    pub fn uplink_mbps(&self) -> f64 {
        match self {
            Profile::ThreeG => 1.10,
            Profile::FourG => 5.85,
            Profile::WiFi => 18.80,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Profile::ThreeG => "3G",
            Profile::FourG => "4G",
            Profile::WiFi => "WiFi",
        }
    }

    pub fn parse(s: &str) -> Result<Profile> {
        match s.to_ascii_lowercase().as_str() {
            "3g" => Ok(Profile::ThreeG),
            "4g" => Ok(Profile::FourG),
            "wifi" | "wi-fi" => Ok(Profile::WiFi),
            _ => bail!("unknown network profile '{s}' (expected 3g|4g|wifi)"),
        }
    }
}

/// Floor applied by [`LinkModel::new`] to degenerate bandwidth samples:
/// 1 kbit/s. A measured 0 Mbps (dead uplink in a trace) or a NaN from a
/// broken estimator becomes "effectively offline but finite", so
/// trace-driven replanning keeps running — the planner simply concludes
/// everything should stay on the edge — instead of panicking and
/// killing the replan thread.
pub const MIN_UPLINK_MBPS: f64 = 1e-3;

/// Ceiling applied by [`LinkModel::new`]: 1 Tbit/s. A +inf sample (e.g.
/// a rate computed over a zero elapsed interval) means "arbitrarily
/// fast", so it clamps *up* to an effectively-free link — not down to
/// the dead-link floor.
pub const MAX_UPLINK_MBPS: f64 = 1e6;

/// RTT ceiling applied by [`LinkModel::new`]: 60 s. Symmetric with the
/// bandwidth rule: a +inf RTT means "arbitrarily slow" and clamps up
/// to an effectively-unusable latency; only NaN falls back to 0.
pub const MAX_RTT_S: f64 = 60.0;

/// Deterministic link delay model: serialization at `uplink_mbps` plus a
/// fixed one-way base latency. This is what the *planner* uses; the
/// serving-path [`super::channel::Channel`] adds jitter on top.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    pub uplink_mbps: f64,
    /// One-way base latency in seconds (0 reproduces the paper exactly —
    /// its model is pure serialization delay).
    pub rtt_s: f64,
}

impl LinkModel {
    /// Clamping constructor: NaN or non-positive bandwidth clamps to
    /// [`MIN_UPLINK_MBPS`] (dead link), +inf or anything above the
    /// ceiling clamps to [`MAX_UPLINK_MBPS`] (free link); NaN or
    /// negative RTT clamps to 0, +inf or anything above [`MAX_RTT_S`]
    /// to that ceiling. Use [`LinkModel::try_new`] to reject bad
    /// inputs instead.
    pub fn new(uplink_mbps: f64, rtt_s: f64) -> LinkModel {
        let uplink_mbps = if uplink_mbps.is_nan() {
            MIN_UPLINK_MBPS
        } else {
            uplink_mbps.clamp(MIN_UPLINK_MBPS, MAX_UPLINK_MBPS)
        };
        let rtt_s = if rtt_s.is_nan() {
            0.0
        } else {
            rtt_s.clamp(0.0, MAX_RTT_S)
        };
        LinkModel { uplink_mbps, rtt_s }
    }

    /// Strict constructor: errors on non-finite/non-positive bandwidth
    /// or non-finite/negative RTT (for config validation paths that
    /// should fail fast rather than silently clamp).
    pub fn try_new(uplink_mbps: f64, rtt_s: f64) -> Result<LinkModel> {
        if !(uplink_mbps.is_finite() && uplink_mbps > 0.0) {
            bail!("bandwidth must be positive and finite, got {uplink_mbps}");
        }
        if !(rtt_s.is_finite() && rtt_s >= 0.0) {
            bail!("rtt must be non-negative and finite, got {rtt_s}");
        }
        Ok(LinkModel { uplink_mbps, rtt_s })
    }

    pub fn from_profile(p: Profile) -> LinkModel {
        LinkModel::new(p.uplink_mbps(), 0.0)
    }

    /// t^net = alpha / B (+ base latency): seconds to upload `bytes`.
    #[inline]
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        bytes as f64 * 8.0 / (self.uplink_mbps * 1e6) + self.rtt_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_rates() {
        assert_eq!(Profile::ThreeG.uplink_mbps(), 1.10);
        assert_eq!(Profile::FourG.uplink_mbps(), 5.85);
        assert_eq!(Profile::WiFi.uplink_mbps(), 18.80);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Profile::parse("3g").unwrap(), Profile::ThreeG);
        assert_eq!(Profile::parse("Wi-Fi").unwrap(), Profile::WiFi);
        assert!(Profile::parse("5g").is_err());
    }

    #[test]
    fn transfer_time_formula() {
        // 12288-byte raw image over 3G: 12288*8 / 1.10e6 s ≈ 89.37 ms.
        let l = LinkModel::from_profile(Profile::ThreeG);
        let t = l.transfer_time(12_288);
        assert!((t - 12_288.0 * 8.0 / 1.10e6).abs() < 1e-12);
        assert!((t - 0.08937).abs() < 1e-4);
    }

    #[test]
    fn rtt_added_once() {
        let l = LinkModel::new(8.0, 0.05);
        // 1e6 bytes at 8 Mbps = 1 s + 50 ms RTT.
        assert!((l.transfer_time(1_000_000) - 1.05).abs() < 1e-9);
        assert!((l.transfer_time(0) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn faster_profile_shorter_time() {
        let bytes = 57_600;
        let t3 = LinkModel::from_profile(Profile::ThreeG).transfer_time(bytes);
        let t4 = LinkModel::from_profile(Profile::FourG).transfer_time(bytes);
        let tw = LinkModel::from_profile(Profile::WiFi).transfer_time(bytes);
        assert!(t3 > t4 && t4 > tw);
    }

    #[test]
    fn degenerate_bandwidth_clamps_to_floor() {
        assert_eq!(LinkModel::new(0.0, 0.0).uplink_mbps, MIN_UPLINK_MBPS);
        assert_eq!(LinkModel::new(-3.0, 0.0).uplink_mbps, MIN_UPLINK_MBPS);
        assert_eq!(LinkModel::new(f64::NAN, 0.0).uplink_mbps, MIN_UPLINK_MBPS);
        // +inf means "arbitrarily fast", so it clamps UP, not down.
        assert_eq!(
            LinkModel::new(f64::INFINITY, 0.0).uplink_mbps,
            MAX_UPLINK_MBPS
        );
        assert_eq!(LinkModel::new(1e9, 0.0).uplink_mbps, MAX_UPLINK_MBPS);
        assert_eq!(LinkModel::new(5.0, f64::NAN).rtt_s, 0.0);
        assert_eq!(LinkModel::new(5.0, -0.1).rtt_s, 0.0);
        // +inf RTT means "arbitrarily slow": clamps up, not to zero.
        assert_eq!(LinkModel::new(5.0, f64::INFINITY).rtt_s, MAX_RTT_S);
        // A dead-uplink sample still yields finite transfer times.
        assert!(LinkModel::new(0.0, 0.0).transfer_time(12_288).is_finite());
        // In-range values are untouched.
        assert_eq!(LinkModel::new(5.85, 0.02).uplink_mbps, 5.85);
    }

    #[test]
    fn try_new_rejects_bad_links() {
        assert!(LinkModel::try_new(0.0, 0.0).is_err());
        assert!(LinkModel::try_new(-1.0, 0.0).is_err());
        assert!(LinkModel::try_new(f64::NAN, 0.0).is_err());
        assert!(LinkModel::try_new(f64::INFINITY, 0.0).is_err());
        assert!(LinkModel::try_new(5.85, -1.0).is_err());
        assert!(LinkModel::try_new(5.85, f64::NAN).is_err());
        let l = LinkModel::try_new(5.85, 0.01).unwrap();
        assert_eq!((l.uplink_mbps, l.rtt_s), (5.85, 0.01));
    }
}
