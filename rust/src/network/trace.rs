//! Time-varying bandwidth traces: piecewise-constant uplink rate over
//! time, loaded from CSV (`t_seconds,mbps`) or generated synthetically.
//! Drives the adaptive re-planning example (the "network conditions
//! change" scenario Neurosurgeon [3] motivates and §VII points to).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::rng::Pcg32;

/// Piecewise-constant bandwidth over time.
#[derive(Debug, Clone)]
pub struct BandwidthTrace {
    /// (start_time_s, mbps), sorted by time; first entry must be t = 0.
    points: Vec<(f64, f64)>,
}

impl BandwidthTrace {
    pub fn new(points: Vec<(f64, f64)>) -> Result<BandwidthTrace> {
        if points.is_empty() {
            bail!("trace must have at least one point");
        }
        if points[0].0 != 0.0 {
            bail!("trace must start at t = 0");
        }
        for w in points.windows(2) {
            if w[1].0 <= w[0].0 {
                bail!("trace times must be strictly increasing");
            }
        }
        if points.iter().any(|&(_, b)| b <= 0.0 || !b.is_finite()) {
            bail!("trace bandwidths must be positive and finite");
        }
        Ok(BandwidthTrace { points })
    }

    pub fn constant(mbps: f64) -> BandwidthTrace {
        BandwidthTrace::new(vec![(0.0, mbps)]).unwrap()
    }

    /// Load "t_seconds,mbps" CSV ('#' comments allowed).
    pub fn load(path: &Path) -> Result<BandwidthTrace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<BandwidthTrace> {
        let mut points = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (t, b) = line
                .split_once(',')
                .with_context(|| format!("trace line {}: expected 't,mbps'", i + 1))?;
            points.push((
                t.trim()
                    .parse()
                    .with_context(|| format!("trace line {}: bad time", i + 1))?,
                b.trim()
                    .parse()
                    .with_context(|| format!("trace line {}: bad bandwidth", i + 1))?,
            ));
        }
        BandwidthTrace::new(points)
    }

    /// Bandwidth at absolute time `t` (clamped to the trace ends).
    pub fn mbps_at(&self, t: f64) -> f64 {
        match self
            .points
            .partition_point(|&(pt, _)| pt <= t.max(0.0))
        {
            0 => self.points[0].1,
            i => self.points[i - 1].1,
        }
    }

    pub fn duration(&self) -> f64 {
        self.points.last().unwrap().0
    }

    pub fn points(&self) -> &[(f64, f64)] {
        &self.points
    }

    /// Synthetic random-walk trace: `steps` segments of `dt` seconds,
    /// multiplicative jitter around `base_mbps`, clamped to [lo, hi].
    pub fn random_walk(
        base_mbps: f64,
        dt: f64,
        steps: usize,
        lo: f64,
        hi: f64,
        seed: u64,
    ) -> BandwidthTrace {
        assert!(steps >= 1 && dt > 0.0 && lo > 0.0 && hi >= lo);
        let mut rng = Pcg32::seeded(seed);
        let mut points = Vec::with_capacity(steps);
        let mut b = base_mbps;
        for i in 0..steps {
            points.push((i as f64 * dt, b));
            b = (b * (1.0 + rng.normal(0.0, 0.25))).clamp(lo, hi);
        }
        BandwidthTrace::new(points).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_semantics() {
        let t = BandwidthTrace::new(vec![(0.0, 5.0), (10.0, 1.0), (20.0, 18.0)]).unwrap();
        assert_eq!(t.mbps_at(-5.0), 5.0);
        assert_eq!(t.mbps_at(0.0), 5.0);
        assert_eq!(t.mbps_at(9.999), 5.0);
        assert_eq!(t.mbps_at(10.0), 1.0);
        assert_eq!(t.mbps_at(100.0), 18.0);
        assert_eq!(t.duration(), 20.0);
    }

    #[test]
    fn csv_roundtrip() {
        let t = BandwidthTrace::parse("# demo\n0, 5.85\n30, 1.10\n\n60, 18.8 # wifi\n").unwrap();
        assert_eq!(t.points().len(), 3);
        assert_eq!(t.mbps_at(45.0), 1.10);
    }

    #[test]
    fn rejects_bad_traces() {
        assert!(BandwidthTrace::new(vec![]).is_err());
        assert!(BandwidthTrace::new(vec![(1.0, 5.0)]).is_err()); // not at 0
        assert!(BandwidthTrace::new(vec![(0.0, 5.0), (0.0, 6.0)]).is_err());
        assert!(BandwidthTrace::new(vec![(0.0, -1.0)]).is_err());
        assert!(BandwidthTrace::parse("0 5.85").is_err());
    }

    #[test]
    fn random_walk_bounds() {
        let t = BandwidthTrace::random_walk(5.85, 1.0, 200, 0.5, 20.0, 7);
        assert_eq!(t.points().len(), 200);
        for &(_, b) in t.points() {
            assert!((0.5..=20.0).contains(&b));
        }
    }
}
