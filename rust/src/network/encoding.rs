//! Wire encodings for the edge→cloud activation transfer.
//!
//! The paper's whole argument is that the transfer term `alpha_s / B`
//! dominates E[T(s)] on constrained uplinks, which makes the byte count
//! itself a planning dimension: quantizing the activation payload
//! shrinks alpha, and a smaller alpha can relocate the optimal split
//! (Edgent, arXiv:1806.07840, makes the same observation). This module
//! defines the encoding identities shared by the codec
//! ([`crate::server::protocol`]) and the planner
//! ([`crate::planner`] / [`crate::timing`]): **the planner must charge
//! exactly the bytes the codec ships**, so both sides call
//! [`WireEncoding::payload_bytes`] and can never drift apart.
//!
//! Payload layouts (after the per-tensor dims header):
//!
//! | encoding | payload                                   | bytes (n f32 elems) |
//! |----------|-------------------------------------------|---------------------|
//! | raw      | `f32 data[n]` (bit-exact)                 | `4n`                |
//! | q8       | `f32 scale \| f32 zero \| u8 q[n]`        | `8 + n`             |
//! | q4       | `f32 scale \| f32 zero \| u8 packed[⌈n/2⌉]` | `8 + ⌈n/2⌉`       |
//!
//! Quantization is per-tensor linear: `scale = (max − min) / levels`,
//! `zero = min`, `q = round((v − zero) / scale)`; dequantized values
//! are `zero + q·scale`, so the round-trip error is at most `scale / 2`
//! — 1/510 of the value range for q8, 1/30 for q4 (both comfortably
//! inside the 1/255 and 1/15 bounds the tests assert).
//!
//! The codec additionally knows a *sparse* q8 variant (zero bitmap +
//! q8 of the nonzeros) it may substitute when the activation is mostly
//! post-ReLU zeros and the sparse form is strictly smaller; the dense
//! `8 + n` figure here is therefore an upper bound on what q8 actually
//! ships, which keeps the planner's cost model conservative.

use anyhow::{bail, Result};

/// How an INFER_PARTIAL activation payload is encoded on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum WireEncoding {
    /// Bit-exact little-endian f32 — the pre-compression wire format.
    #[default]
    Raw,
    /// 8-bit per-tensor linear quantization (scale + zero-point).
    Q8,
    /// 4-bit per-tensor linear quantization, two values per byte.
    Q4,
}

impl WireEncoding {
    /// Every encoding, in wire-tag order — handy for iteration in
    /// benches and per-encoding counters.
    pub const ALL: [WireEncoding; 3] = [WireEncoding::Raw, WireEncoding::Q8, WireEncoding::Q4];

    pub fn as_str(&self) -> &'static str {
        match self {
            WireEncoding::Raw => "raw",
            WireEncoding::Q8 => "q8",
            WireEncoding::Q4 => "q4",
        }
    }

    /// Parse a config/CLI spelling (`[fleet] wire_encoding` /
    /// `--wire-encoding`).
    pub fn parse(s: &str) -> Result<WireEncoding> {
        match s.to_ascii_lowercase().as_str() {
            "raw" | "f32" => Ok(WireEncoding::Raw),
            "q8" | "int8" => Ok(WireEncoding::Q8),
            "q4" | "int4" => Ok(WireEncoding::Q4),
            _ => bail!("unknown wire encoding '{s}' (expected 'raw', 'q8' or 'q4')"),
        }
    }

    /// Payload bytes shipped for an activation whose raw f32 form is
    /// `raw_bytes` — the encoding-parameterized alpha the planner
    /// charges. Deterministic and shared with the codec: for `n = ⌈raw
    /// / 4⌉` elements, raw ships `4n`, q8 ships `8 + n` (scale + zero +
    /// one byte per value), q4 ships `8 + ⌈n/2⌉` (two values per byte).
    pub fn payload_bytes(&self, raw_bytes: u64) -> u64 {
        let elems = raw_bytes.div_ceil(4);
        match self {
            WireEncoding::Raw => raw_bytes,
            WireEncoding::Q8 => 8 + elems,
            WireEncoding::Q4 => 8 + elems.div_ceil(2),
        }
    }
}

impl std::fmt::Display for WireEncoding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_aliases_case_insensitively() {
        assert_eq!(WireEncoding::parse("raw").unwrap(), WireEncoding::Raw);
        assert_eq!(WireEncoding::parse("F32").unwrap(), WireEncoding::Raw);
        assert_eq!(WireEncoding::parse("q8").unwrap(), WireEncoding::Q8);
        assert_eq!(WireEncoding::parse("INT8").unwrap(), WireEncoding::Q8);
        assert_eq!(WireEncoding::parse("q4").unwrap(), WireEncoding::Q4);
        assert!(WireEncoding::parse("gzip").is_err());
        assert_eq!(WireEncoding::default(), WireEncoding::Raw);
    }

    #[test]
    fn payload_bytes_match_the_documented_layouts() {
        // 1024 f32 elements = 4096 raw bytes.
        assert_eq!(WireEncoding::Raw.payload_bytes(4096), 4096);
        assert_eq!(WireEncoding::Q8.payload_bytes(4096), 8 + 1024);
        assert_eq!(WireEncoding::Q4.payload_bytes(4096), 8 + 512);
        // Odd element count: q4 rounds the nibble pair up.
        assert_eq!(WireEncoding::Q4.payload_bytes(3 * 4), 8 + 2);
        // Degenerate empty tensor.
        for e in WireEncoding::ALL {
            assert_eq!(e.payload_bytes(0), if e == WireEncoding::Raw { 0 } else { 8 });
        }
    }

    #[test]
    fn compression_is_monotone_for_real_payloads() {
        for raw in [4u64, 400, 4096, 1 << 20] {
            let r = WireEncoding::Raw.payload_bytes(raw);
            let q8 = WireEncoding::Q8.payload_bytes(raw);
            let q4 = WireEncoding::Q4.payload_bytes(raw);
            if raw >= 16 {
                assert!(q8 < r, "raw {raw}");
                assert!(q4 < q8, "raw {raw}");
            }
        }
        // The asymptotic ratios the bench banks on: ~4x for q8, ~8x q4.
        let raw = 1 << 20;
        assert!(WireEncoding::Raw.payload_bytes(raw) as f64
            / WireEncoding::Q8.payload_bytes(raw) as f64
            > 3.9);
    }
}
