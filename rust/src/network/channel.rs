//! Simulated edge→cloud channel for the serving path.
//!
//! The planner uses the deterministic [`super::bandwidth::LinkModel`];
//! the *runtime* channel adds what a real uplink has: a time-varying rate
//! (optionally trace-driven), log-normal-ish jitter, and an actual
//! blocking delay (`std::thread::sleep`) so end-to-end serving latencies
//! are physically consistent with the model the partitioner optimized.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::rng::Pcg32;

use super::bandwidth::LinkModel;
use super::trace::BandwidthTrace;

#[derive(Debug)]
struct ChannelState {
    rng: Pcg32,
    transferred_bytes: u64,
    transfers: u64,
    busy_s: f64,
}

/// Thread-safe simulated uplink.
#[derive(Debug)]
pub struct Channel {
    trace: BandwidthTrace,
    rtt_s: f64,
    /// Multiplicative jitter stddev (0 = deterministic).
    jitter: f64,
    /// If false, delays are accounted but not slept — for fast tests.
    real_time: bool,
    epoch: Instant,
    state: Mutex<ChannelState>,
}

impl Channel {
    pub fn new(trace: BandwidthTrace, rtt_s: f64, jitter: f64, seed: u64) -> Channel {
        assert!((0.0..1.0).contains(&jitter));
        assert!(rtt_s >= 0.0);
        Channel {
            trace,
            rtt_s,
            jitter,
            real_time: true,
            epoch: Instant::now(),
            state: Mutex::new(ChannelState {
                rng: Pcg32::seeded(seed),
                transferred_bytes: 0,
                transfers: 0,
                busy_s: 0.0,
            }),
        }
    }

    pub fn from_link(link: LinkModel) -> Channel {
        Channel::new(BandwidthTrace::constant(link.uplink_mbps), link.rtt_s, 0.0, 0)
    }

    /// Disable real sleeping (simulation-time mode for tests/benches).
    pub fn simulated_time(mut self) -> Channel {
        self.real_time = false;
        self
    }

    /// Current nominal link model (bandwidth from the trace at now).
    pub fn current_link(&self) -> LinkModel {
        let t = self.epoch.elapsed().as_secs_f64();
        LinkModel::new(self.trace.mbps_at(t), self.rtt_s)
    }

    /// Compute the delay a transfer of `bytes` experiences right now.
    pub fn sample_delay(&self, bytes: u64) -> Duration {
        let base = self.current_link().transfer_time(bytes);
        let mut st = self.state.lock().unwrap();
        let factor = if self.jitter > 0.0 {
            (1.0 + st.rng.normal(0.0, self.jitter)).max(0.1)
        } else {
            1.0
        };
        st.transferred_bytes += bytes;
        st.transfers += 1;
        let d = base * factor;
        st.busy_s += d;
        Duration::from_secs_f64(d)
    }

    /// Transfer `bytes`: blocks for the sampled delay (or just accounts
    /// it in simulated-time mode) and returns the delay.
    pub fn transfer(&self, bytes: u64) -> Duration {
        let d = self.sample_delay(bytes);
        if self.real_time {
            std::thread::sleep(d);
        }
        d
    }

    /// (transferred_bytes, transfer_count, total_busy_seconds).
    pub fn stats(&self) -> (u64, u64, f64) {
        let st = self.state.lock().unwrap();
        (st.transferred_bytes, st.transfers, st.busy_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::bandwidth::Profile;

    #[test]
    fn deterministic_without_jitter() {
        let ch = Channel::from_link(LinkModel::from_profile(Profile::FourG)).simulated_time();
        let d1 = ch.transfer(57_600);
        let d2 = ch.transfer(57_600);
        assert_eq!(d1, d2);
        let want = 57_600.0 * 8.0 / 5.85e6;
        assert!((d1.as_secs_f64() - want).abs() < 1e-9);
        let (bytes, count, busy) = ch.stats();
        assert_eq!(bytes, 115_200);
        assert_eq!(count, 2);
        assert!((busy - 2.0 * want).abs() < 1e-9);
    }

    #[test]
    fn jitter_varies_but_stays_positive() {
        let ch = Channel::new(BandwidthTrace::constant(5.85), 0.0, 0.3, 42).simulated_time();
        let delays: Vec<f64> = (0..50).map(|_| ch.transfer(10_000).as_secs_f64()).collect();
        assert!(delays.iter().all(|&d| d > 0.0));
        let distinct = delays.windows(2).filter(|w| w[0] != w[1]).count();
        assert!(distinct > 40, "jitter should vary delays");
        // Mean within 20% of nominal.
        let nominal = 10_000.0 * 8.0 / 5.85e6;
        let mean = delays.iter().sum::<f64>() / delays.len() as f64;
        assert!((mean / nominal - 1.0).abs() < 0.2, "mean {mean} vs {nominal}");
    }

    #[test]
    fn rtt_added() {
        let ch = Channel::new(BandwidthTrace::constant(8.0), 0.05, 0.0, 0).simulated_time();
        let d = ch.transfer(0);
        assert!((d.as_secs_f64() - 0.05).abs() < 1e-9);
    }

    #[test]
    fn real_time_mode_actually_sleeps() {
        let ch = Channel::new(BandwidthTrace::constant(1.0), 0.0, 0.0, 0);
        let t0 = Instant::now();
        ch.transfer(2_500); // 2500*8/1e6 = 20 ms
        assert!(t0.elapsed() >= Duration::from_millis(18));
    }
}
