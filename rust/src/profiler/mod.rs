//! Per-layer profiler: measures `t_i^c` — the processing time of every
//! stage (and the side branch) on this machine's PJRT runtime — exactly
//! the role Google Colab played in the paper's §VI. Results serialize to
//! `profile.json` so planning runs don't re-measure.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::json::Json;
use crate::runtime::{HostTensor, InferenceEngine};
use crate::timing::DelayProfile;
use crate::util::stats::trimmed_mean;

/// Measurement parameters.
#[derive(Debug, Clone, Copy)]
pub struct ProfileOptions {
    /// Warmup executions per stage (excluded from stats).
    pub warmup: usize,
    /// Measured executions per stage.
    pub iters: usize,
    /// Tail-trim fraction for the trimmed mean.
    pub trim: f64,
    /// Batch size to profile at (per-sample time = t / batch).
    pub batch: usize,
}

impl Default for ProfileOptions {
    fn default() -> Self {
        ProfileOptions {
            warmup: 3,
            iters: 15,
            trim: 0.1,
            batch: 1,
        }
    }
}

/// One stage's measurement.
#[derive(Debug, Clone)]
pub struct StageMeasurement {
    pub name: String,
    /// Trimmed-mean seconds per *sample*.
    pub t_cloud_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

/// Full measurement report.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    pub stages: Vec<StageMeasurement>,
    pub branch: StageMeasurement,
    pub batch: usize,
    pub iters: usize,
}

impl ProfileReport {
    /// Convert to the planning profile with the paper's gamma model.
    pub fn to_delay_profile(&self, gamma: f64) -> DelayProfile {
        DelayProfile::from_cloud_times(
            self.stages.iter().map(|s| s.t_cloud_s).collect(),
            self.branch.t_cloud_s,
            gamma,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("batch", Json::num(self.batch as f64)),
            ("iters", Json::num(self.iters as f64)),
            (
                "stages",
                Json::arr(
                    self.stages
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("name", Json::str(s.name.clone())),
                                ("t_cloud_s", Json::num(s.t_cloud_s)),
                                ("min_s", Json::num(s.min_s)),
                                ("max_s", Json::num(s.max_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "branch",
                Json::obj(vec![
                    ("name", Json::str(self.branch.name.clone())),
                    ("t_cloud_s", Json::num(self.branch.t_cloud_s)),
                    ("min_s", Json::num(self.branch.min_s)),
                    ("max_s", Json::num(self.branch.max_s)),
                ]),
            ),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<ProfileReport> {
        let doc = Json::parse(
            &std::fs::read_to_string(path)
                .with_context(|| format!("reading {}", path.display()))?,
        )?;
        Self::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<ProfileReport> {
        let stage_of = |j: &Json| -> Result<StageMeasurement> {
            Ok(StageMeasurement {
                name: j
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("measurement missing name"))?
                    .to_string(),
                t_cloud_s: j
                    .get("t_cloud_s")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("measurement missing t_cloud_s"))?,
                min_s: j.get("min_s").and_then(Json::as_f64).unwrap_or(0.0),
                max_s: j.get("max_s").and_then(Json::as_f64).unwrap_or(0.0),
            })
        };
        Ok(ProfileReport {
            stages: doc
                .get("stages")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("profile missing stages"))?
                .iter()
                .map(stage_of)
                .collect::<Result<_>>()?,
            branch: stage_of(
                doc.get("branch")
                    .ok_or_else(|| anyhow!("profile missing branch"))?,
            )?,
            batch: doc.get("batch").and_then(Json::as_usize).unwrap_or(1),
            iters: doc.get("iters").and_then(Json::as_usize).unwrap_or(0),
        })
    }
}

fn time_fn(
    warmup: usize,
    iters: usize,
    trim: f64,
    mut f: impl FnMut() -> Result<()>,
) -> Result<(f64, f64, f64)> {
    for _ in 0..warmup {
        f()?;
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f()?;
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = trimmed_mean(&samples, trim);
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    Ok((mean, min, max))
}

/// Measure every stage + the branch of the engine's manifest.
pub fn measure(engine: &InferenceEngine, opts: ProfileOptions) -> Result<ProfileReport> {
    let m = engine.manifest();
    let b = opts.batch;
    anyhow::ensure!(
        m.batch_sizes.contains(&b),
        "profile batch {b} not exported"
    );

    let mut stages = Vec::with_capacity(m.num_stages());
    let mut input_shape = vec![b];
    input_shape.extend(&m.input_shape);
    let mut x = HostTensor::zeros(input_shape);

    for i in 1..=m.num_stages() {
        let name = m.stages[i - 1].name.clone();
        let (mean, min, max) = time_fn(opts.warmup, opts.iters, opts.trim, || {
            engine.run_stages(i, i, &x).map(|_| ())
        })?;
        log::info!("profiled {name}: {:.3} ms/batch", mean * 1e3);
        stages.push(StageMeasurement {
            name,
            t_cloud_s: mean / b as f64,
            min_s: min / b as f64,
            max_s: max / b as f64,
        });
        // Feed the real activation forward so shapes stay correct.
        x = engine.run_stages(i, i, &x)?;
        if i == m.branch.after_stage {
            // nothing: branch profiled below on saved activations
        }
    }

    // Branch: profile on activations at its attach point.
    let mut bx = HostTensor::zeros({
        let mut s = vec![b];
        s.extend(&m.branch.in_shape);
        s
    });
    bx = engine
        .run_stages(1, m.branch.after_stage, &{
            let mut s = vec![b];
            s.extend(&m.input_shape);
            HostTensor::zeros(s)
        })
        .unwrap_or(bx);
    let (mean, min, max) = time_fn(opts.warmup, opts.iters, opts.trim, || {
        engine.run_branch(&bx).map(|_| ())
    })?;
    let branch = StageMeasurement {
        name: m.branch.name.clone(),
        t_cloud_s: mean / b as f64,
        min_s: min / b as f64,
        max_s: max / b as f64,
    };

    Ok(ProfileReport {
        stages,
        branch,
        batch: b,
        iters: opts.iters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_roundtrip() {
        let r = ProfileReport {
            stages: vec![
                StageMeasurement {
                    name: "conv1".into(),
                    t_cloud_s: 1.5e-3,
                    min_s: 1e-3,
                    max_s: 2e-3,
                },
                StageMeasurement {
                    name: "fc".into(),
                    t_cloud_s: 2e-4,
                    min_s: 1e-4,
                    max_s: 3e-4,
                },
            ],
            branch: StageMeasurement {
                name: "b1".into(),
                t_cloud_s: 1e-4,
                min_s: 9e-5,
                max_s: 2e-4,
            },
            batch: 8,
            iters: 15,
        };
        let parsed = ProfileReport::from_json(&Json::parse(&r.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(parsed.stages.len(), 2);
        assert_eq!(parsed.stages[0].name, "conv1");
        assert!((parsed.stages[0].t_cloud_s - 1.5e-3).abs() < 1e-12);
        assert_eq!(parsed.batch, 8);

        let dp = parsed.to_delay_profile(100.0);
        assert!((dp.t_edge[0] - 0.15).abs() < 1e-9);
        assert!((dp.branch_t_edge - 0.01).abs() < 1e-9);
    }

    #[test]
    fn time_fn_counts_iters() {
        let mut calls = 0;
        let (mean, min, max) = time_fn(2, 10, 0.1, || {
            calls += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(calls, 12);
        assert!(mean >= 0.0 && min <= mean && mean <= max.max(mean));
    }
}
