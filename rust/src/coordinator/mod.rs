//! The serving coordinator: everything between "a request arrived" and
//! "a class came back".
//!
//! ```text
//! submit -> admission -> [batcher] -> edge worker: stages 1..=s
//!                                        '- branch b_k -> entropy gate
//!                                             exit? respond : transfer
//!                                     -> [channel delay] -> cloud worker:
//!                                        stages s+1..=N -> respond
//! ```
//!
//! Threads + channels (tokio is unavailable offline; a thread-per-node
//! pipeline with bounded queues is the right shape for two pipeline
//! stages anyway). The partition plan decides how much work each node
//! does; `split_after = 0` degenerates to pure cloud serving (the edge
//! node forwards raw inputs), `= N` to pure edge serving.
//!
//! The cloud worker's compute is a [`CloudExec`]: an in-process engine
//! (single-machine deployment, simulated uplink), or a remote
//! cloud-stage server reached over the wire protocol — then the
//! partition spans real machines and the local engine only runs as a
//! fallback when the network path fails.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;

pub use engine::{AdmitError, ChainRoute, CloudExec, Coordinator, CoordinatorConfig, ExitObserver};
pub use metrics::MetricsSnapshot;
pub use request::{CompletionSink, InferenceRequest, InferenceResponse, ReplyTo};
