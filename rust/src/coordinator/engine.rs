//! Coordinator engine: edge worker + cloud worker threads around the
//! dynamic batcher, realizing a [`PartitionPlan`] over the runtime —
//! with a simulated uplink in between, or (via [`CloudExec::Remote`]) a
//! real network link to a cloud-stage server on another machine.
//!
//! Early-exit pipeline semantics (the real BranchyNet control flow, not
//! the batched-both-paths shortcut the Python reference uses):
//! stages `1..=k` run on the edge, the side branch classifies, samples
//! under the entropy threshold are answered immediately, and only the
//! *survivors* continue through stages `k+1..=s`, the uplink, and the
//! cloud stages — so an exited sample truly never pays transfer or cloud
//! time, which is exactly the effect Eq. 5 models.
//!
//! Transfers are pipelined: the edge worker samples the channel delay and
//! stamps each survivor with a "transfer completes at" instant; the cloud
//! worker waits for that instant before computing. Edge compute is never
//! blocked by the (simulated) uplink.
//!
//! Plans are resolved **per request**: a request may carry its own
//! [`PartitionPlan`] override (per-request planning — the fleet solved
//! the split at the instantaneous link estimate at admission); requests
//! without one execute under the coordinator's current plan. The edge
//! worker groups each batch by effective split so one executable batch
//! never mixes splits, and every transferred sample is stamped with the
//! split it was cut at — the cloud worker runs `split+1..=N` from the
//! stamp, so a concurrent plan switch can never make a sample skip or
//! repeat a stage mid-flight.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::model::Manifest;
use crate::network::{Channel, WireEncoding};
use crate::partition::PartitionPlan;
use crate::runtime::{HostTensor, InferenceEngine};
use crate::server::protocol::{BRANCH_GATED, BRANCH_PENDING};
use crate::server::remote::RemoteCloudEngine;

use super::batcher::{Batcher, SubmitError};
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{ExitPoint, InferenceRequest, InferenceResponse, ReplyTo};

/// Typed admission failure, for front ends that must distinguish
/// backpressure (answer a THROTTLE frame, count `rejected`) from a
/// terminal condition (answer an ERROR, count `failed`). The string
/// errors the blocking [`Coordinator::submit`] path returns are derived
/// from these, so the two can't drift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// The admission queue is full — transient; retry after backoff.
    Busy,
    /// The coordinator is shut down — terminal.
    Closed,
}

/// The cloud half of the pipeline: where the suffix stages of
/// transferred samples execute. In-process for the single-machine
/// (simulated-uplink) deployment; remote when the partition is
/// physically real — then a local engine rides along as the fallback so
/// the edge keeps serving through cloud outages.
#[derive(Clone)]
pub enum CloudExec {
    /// Suffix stages run in-process on this engine.
    Local(InferenceEngine),
    /// Suffix stages ship to a remote
    /// [`CloudStageServer`](crate::server::CloudStageServer) as
    /// INFER_PARTIAL frames; on any remote failure (connect/IO error,
    /// backoff window, in-flight saturation) the group runs on
    /// `fallback` instead, counted in `metrics.remote_fallbacks`.
    ///
    /// The uplink is then real, so the coordinator skips the simulated
    /// channel wait for transferred groups and reports each sample's
    /// `transfer_s` as the *measured* wire time of its round-trip
    /// (round-trip minus server compute). The class channel keeps its
    /// planning role — it is the model of the uplink the splits are
    /// solved against.
    Remote {
        remote: Arc<RemoteCloudEngine>,
        fallback: InferenceEngine,
        /// Multi-tier route: when set (with a non-empty tail), groups
        /// ship as INFER_CHAIN_SEQ frames through `remote` (the chain
        /// head) instead of plain partials, and a failed head degrades
        /// to the route's direct terminal engine before the local
        /// fallback.
        chain: Option<ChainRoute>,
    },
}

/// The chain topology a remote [`CloudExec`] routes through: the fixed
/// cut tail every frame carries (the *head* cut is stamped per sample),
/// plus the degraded path.
#[derive(Clone)]
pub struct ChainRoute {
    /// `cuts[1..]` of the solved chain plan — where each downstream
    /// tier hands off. Tail cuts equal to N mean "this tier runs to the
    /// end"; the receiving server serves those as ordinary partials.
    pub tail: Arc<Vec<usize>>,
    /// Direct single-hop engine to the terminal tier: when the chain
    /// head fails, the group ships here with the *same* stamped split
    /// (counted in `metrics.chain_fallbacks`) so chain brownouts
    /// degrade to two-tier service instead of dropping to local-only.
    pub direct: Option<Arc<RemoteCloudEngine>>,
}

impl From<InferenceEngine> for CloudExec {
    fn from(engine: InferenceEngine) -> CloudExec {
        CloudExec::Local(engine)
    }
}

impl CloudExec {
    /// The manifest the cloud side executes (the local or fallback
    /// engine's — a remote server is assumed to serve the same model).
    pub fn manifest(&self) -> &Manifest {
        match self {
            CloudExec::Local(e) => e.manifest(),
            CloudExec::Remote { fallback, .. } => fallback.manifest(),
        }
    }
}

/// Called once per branch-gate decision with `true` when the sample
/// exited early at the side branch — the hook the fleet's online
/// exit-rate estimation feeds on. Invoked on the edge worker thread;
/// keep it cheap.
pub type ExitObserver = Arc<dyn Fn(bool) + Send + Sync>;

/// Work item crossing the edge->cloud boundary.
struct TransferredSample {
    id: u64,
    reply: ReplyTo,
    enqueued: Instant,
    activation: HostTensor,
    entropy: f32,
    edge_s: f64,
    transfer_s: f64,
    /// The split this sample was cut at: the cloud runs `split+1..=N`
    /// regardless of what the coordinator's plan says by then.
    split: usize,
    /// The (simulated) instant the upload completes.
    ready_at: Instant,
}

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub entropy_threshold: f32,
    pub max_batch: usize,
    pub batch_timeout: Duration,
    pub queue_capacity: usize,
    /// Cloud worker threads sharing this pipeline's transfer queue.
    /// More than one lets cloud compute (and the simulated transfer
    /// waits) overlap across batches; all workers share one engine
    /// handle, so with a single PJRT client compute still serializes.
    pub cloud_workers: usize,
    /// Wire encoding the activation transfer is priced at: the
    /// simulated channel charges
    /// [`WireEncoding::payload_bytes`] of the raw activation per
    /// sample — the same size map a remote engine configured with this
    /// encoding actually ships, so simulated and physical deployments
    /// pay the same wire.
    pub wire_encoding: WireEncoding,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            entropy_threshold: 0.3,
            max_batch: 8,
            batch_timeout: Duration::from_millis(2),
            queue_capacity: 1024,
            cloud_workers: 1,
            wire_encoding: WireEncoding::Raw,
        }
    }
}

pub struct Coordinator {
    edge_engine: InferenceEngine,
    channel: Arc<Channel>,
    plan: Arc<RwLock<PartitionPlan>>,
    /// Kept for introspection (`config()`); workers copy what they need.
    cfg: CoordinatorConfig,
    ingress: Arc<Batcher<InferenceRequest>>,
    cloud_queue: Arc<Batcher<TransferredSample>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    started: Instant,
    /// Mutex so [`Coordinator::drain`] can join through a shared handle
    /// (`&self`) — the autoscaler retires one shard of a live set
    /// without ever owning it.
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Coordinator {
    /// Start the pipeline. `edge_engine` and `cloud` are the two nodes'
    /// compute handles — pass two distinct engines for true pipelining
    /// (separate PJRT clients), two clones of one engine to share a
    /// single client (compute then serializes), or a
    /// [`CloudExec::Remote`] to run the suffix stages on another
    /// machine (a plain [`InferenceEngine`] converts into
    /// [`CloudExec::Local`]).
    pub fn start(
        edge_engine: InferenceEngine,
        cloud: impl Into<CloudExec>,
        channel: Arc<Channel>,
        plan: PartitionPlan,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        Self::start_observed(edge_engine, cloud, channel, plan, cfg, None)
    }

    /// [`Coordinator::start`] with an exit observer: `observer` is
    /// called once per branch-gate decision (`true` = early exit), the
    /// signal an online exit-rate estimator consumes. Samples that never
    /// reach the branch (cloud-only plans, splits at or before the
    /// branch) produce no observations — an unevaluated branch has no
    /// observable exit behaviour.
    pub fn start_observed(
        edge_engine: InferenceEngine,
        cloud: impl Into<CloudExec>,
        channel: Arc<Channel>,
        plan: PartitionPlan,
        cfg: CoordinatorConfig,
        observer: Option<ExitObserver>,
    ) -> Coordinator {
        let cloud = cloud.into();
        let plan = Arc::new(RwLock::new(plan));
        let ingress = Arc::new(Batcher::new(
            cfg.queue_capacity,
            cfg.max_batch,
            cfg.batch_timeout,
        ));
        let cloud_queue = Arc::new(Batcher::new(
            cfg.queue_capacity,
            cfg.max_batch,
            cfg.batch_timeout,
        ));
        let metrics = Arc::new(Metrics::new());

        let mut workers = Vec::new();
        {
            let engine = edge_engine.clone();
            let channel = channel.clone();
            let plan = plan.clone();
            let ingress = ingress.clone();
            let cloud_queue = cloud_queue.clone();
            let metrics = metrics.clone();
            let threshold = cfg.entropy_threshold;
            let encoding = cfg.wire_encoding;
            let observer = observer.clone();
            workers.push(
                std::thread::Builder::new()
                    .name("edge-worker".into())
                    .spawn(move || {
                        edge_loop(
                            engine,
                            channel,
                            plan,
                            ingress,
                            cloud_queue,
                            metrics,
                            threshold,
                            encoding,
                            observer,
                        )
                    })
                    .expect("spawn edge worker"),
            );
        }
        for i in 0..cfg.cloud_workers.max(1) {
            let exec = cloud.clone();
            let cloud_queue = cloud_queue.clone();
            let metrics = metrics.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("cloud-worker-{i}"))
                    .spawn(move || cloud_loop(exec, cloud_queue, metrics))
                    .expect("spawn cloud worker"),
            );
        }

        Coordinator {
            edge_engine,
            channel,
            plan,
            cfg,
            ingress,
            cloud_queue,
            metrics,
            next_id: AtomicU64::new(1),
            started: Instant::now(),
            workers: Mutex::new(workers),
        }
    }

    pub fn engine(&self) -> &InferenceEngine {
        &self.edge_engine
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    pub fn plan(&self) -> PartitionPlan {
        self.plan.read().unwrap().clone()
    }

    /// Swap the active partition plan (adaptive re-planning). In-flight
    /// batches finish under the old plan; new batches use the new one.
    /// A switch that actually moves the split is counted in
    /// `metrics.plan_switches`.
    pub fn set_plan(&self, plan: PartitionPlan) {
        let mut current = self.plan.write().unwrap();
        if current.split_after != plan.split_after {
            self.metrics.plan_switches.fetch_add(1, Ordering::Relaxed);
        }
        *current = plan;
    }

    pub fn channel(&self) -> &Channel {
        &self.channel
    }

    /// Requests waiting in the admission queue — the load signal a
    /// least-loaded fleet router keys on.
    pub fn queue_depth(&self) -> usize {
        self.ingress.len()
    }

    /// Transferred samples waiting for a cloud worker.
    pub fn cloud_queue_depth(&self) -> usize {
        self.cloud_queue.len()
    }

    /// Cumulative admitted-then-rejected requests (one atomic load —
    /// the autoscaler's sampling tick reads this per shard, so it must
    /// not pay a full metrics snapshot).
    pub fn rejected_total(&self) -> u64 {
        self.metrics.rejected.load(Ordering::Relaxed)
    }

    /// Submit one image; the response arrives on the returned receiver.
    pub fn submit(&self, image: HostTensor) -> Result<(u64, mpsc::Receiver<InferenceResponse>)> {
        self.submit_with_plan(image, None)
    }

    /// Submit one image with a per-request plan override: this sample
    /// executes under `plan` (solved by the caller at the instantaneous
    /// link estimate) regardless of the coordinator's current plan. The
    /// edge worker groups batches by effective split, so overridden and
    /// default samples sharing a batch window each run their own split.
    pub fn submit_planned(
        &self,
        image: HostTensor,
        plan: PartitionPlan,
    ) -> Result<(u64, mpsc::Receiver<InferenceResponse>)> {
        self.submit_with_plan(image, Some(plan))
    }

    fn submit_with_plan(
        &self,
        image: HostTensor,
        plan: Option<PartitionPlan>,
    ) -> Result<(u64, mpsc::Receiver<InferenceResponse>)> {
        let (tx, rx) = mpsc::channel();
        match self.submit_reply(image, plan, ReplyTo::Channel(tx)) {
            Ok(id) => Ok((id, rx)),
            Err(AdmitError::Busy) => Err(anyhow!("admission queue full")),
            Err(AdmitError::Closed) => Err(anyhow!("coordinator shut down")),
        }
    }

    /// Submit one image to an arbitrary reply destination, with a typed
    /// rejection. Every submit path funnels through here, so the
    /// metrics ledger (`submitted`, `rejected`, `failed`) is accounted
    /// identically whether the caller is a blocking channel waiter or a
    /// multiplexing reactor sink.
    pub fn submit_reply(
        &self,
        image: HostTensor,
        plan: Option<PartitionPlan>,
        reply: ReplyTo,
    ) -> std::result::Result<u64, AdmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if plan.is_some() {
            self.metrics.plan_overrides.fetch_add(1, Ordering::Relaxed);
        }
        let req = InferenceRequest {
            id,
            image,
            enqueued: Instant::now(),
            reply,
            plan,
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        match self.ingress.submit(req) {
            Ok(()) => Ok(id),
            Err(SubmitError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(AdmitError::Busy)
            }
            Err(SubmitError::Closed(_)) => {
                // Terminal, but not backpressure (the autoscaler reads
                // `rejected` as a load signal): counted in `failed` so
                // the drain ledger stays balanced.
                self.metrics.failed.fetch_add(1, Ordering::Relaxed);
                Err(AdmitError::Closed)
            }
        }
    }

    /// Convenience: submit and block for the response.
    pub fn infer_sync(&self, image: HostTensor) -> Result<InferenceResponse> {
        let (_, rx) = self.submit(image)?;
        rx.recv().map_err(|_| anyhow!("response channel dropped"))
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot(self.started)
    }

    /// Drain and stop this pipeline through a *shared* handle: wait
    /// until every admitted request has been answered (or rejected),
    /// close the queues, join the workers, and return the final
    /// metrics. The caller must have stopped routing new requests here
    /// first — the fleet's shard set does that by removing the shard
    /// under its write lock — or the wait never converges. Idempotent:
    /// a second call finds no in-flight work and no workers to join.
    ///
    /// The in-flight check is on the request ledger (`submitted ==
    /// completed + rejected + failed`), not queue emptiness: a sample
    /// the edge worker has popped but not yet answered or re-queued for
    /// the cloud is in neither queue, and closing under it would drop
    /// it.
    pub fn drain(&self) -> MetricsSnapshot {
        loop {
            // The terminal counters read before `submitted`: a racing
            // submit can only make the ledger look *less* settled,
            // never prematurely balanced.
            let done = self.metrics.completed.load(Ordering::Relaxed)
                + self.metrics.rejected.load(Ordering::Relaxed)
                + self.metrics.failed.load(Ordering::Relaxed);
            if self.metrics.submitted.load(Ordering::Relaxed) == done
                && self.ingress.is_empty()
                && self.cloud_queue.is_empty()
            {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        self.ingress.close();
        self.cloud_queue.close();
        let workers = std::mem::take(&mut *self.workers.lock().unwrap());
        for w in workers {
            let _ = w.join();
        }
        self.metrics.snapshot(self.started)
    }

    /// Drain and stop the workers (owning-handle convenience over
    /// [`Coordinator::drain`]).
    pub fn shutdown(self) -> MetricsSnapshot {
        self.drain()
    }
}

#[allow(clippy::too_many_arguments)]
fn edge_loop(
    engine: InferenceEngine,
    channel: Arc<Channel>,
    plan: Arc<RwLock<PartitionPlan>>,
    ingress: Arc<Batcher<InferenceRequest>>,
    cloud_queue: Arc<Batcher<TransferredSample>>,
    metrics: Arc<Metrics>,
    threshold: f32,
    encoding: WireEncoding,
    observer: Option<ExitObserver>,
) {
    let max_exec = engine.max_batch();

    while let Some(batch) = ingress.next_batch() {
        metrics.edge_batches.fetch_add(1, Ordering::Relaxed);
        let current = plan.read().unwrap().clone();
        // Group by effective plan (per-request overrides vs the current
        // plan): one executable batch never mixes split points. Requests
        // without overrides — the common case — form a single group, so
        // this is a no-op for fleets without per-request planning.
        let mut groups: Vec<(PartitionPlan, Vec<InferenceRequest>)> = Vec::new();
        for mut req in batch {
            let p = req.plan.take().unwrap_or_else(|| current.clone());
            match groups
                .iter_mut()
                .find(|(g, _)| g.split_after == p.split_after)
            {
                Some((_, reqs)) => reqs.push(req),
                None => groups.push((p, vec![req])),
            }
        }
        for (group_plan, mut batch) in groups {
            // Chunk to the largest exported executable size.
            while !batch.is_empty() {
                let take = batch.len().min(max_exec);
                let chunk: Vec<InferenceRequest> = batch.drain(..take).collect();
                let n = chunk.len();
                let mut answered = 0usize;
                if let Err(e) = process_edge_chunk(
                    &engine,
                    &channel,
                    &group_plan,
                    chunk,
                    &cloud_queue,
                    &metrics,
                    threshold,
                    encoding,
                    observer.as_ref(),
                    &mut answered,
                ) {
                    log::error!("edge chunk failed: {e:#}");
                    // Every fallible step precedes the transfer loop, so
                    // a failed chunk reached the cloud queue with nothing:
                    // its unanswered requests are terminal (their reply
                    // senders just dropped). Account them as failed so
                    // the drain ledger settles — `rejected` stays a pure
                    // load signal for the autoscaler.
                    metrics
                        .failed
                        .fetch_add((n - answered) as u64, Ordering::Relaxed);
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn process_edge_chunk(
    engine: &InferenceEngine,
    channel: &Channel,
    plan: &PartitionPlan,
    chunk: Vec<InferenceRequest>,
    cloud_queue: &Batcher<TransferredSample>,
    metrics: &Metrics,
    threshold: f32,
    encoding: WireEncoding,
    observer: Option<&ExitObserver>,
    answered: &mut usize,
) -> Result<()> {
    let n = chunk.len();
    let manifest = engine.manifest();
    let num_stages = manifest.num_stages();
    let s = plan.split_after;
    let branch_pos = manifest.branch.after_stage;
    let branch_active = plan.active_branches.contains(&branch_pos);

    let t_edge0 = Instant::now();
    let images: Vec<HostTensor> = chunk.iter().map(|r| r.image.clone()).collect();
    let stacked = HostTensor::stack(&images)?;
    let exec_b = engine.bucket_batch(n);
    let mut x = stacked.pad_batch(exec_b);

    // Survivor bookkeeping: request index -> still alive.
    let mut alive: Vec<usize> = (0..n).collect();
    let mut entropies = vec![f32::NAN; n];

    if s > 0 && branch_active {
        // Stages 1..=k, then the branch gate.
        x = engine.run_stages(1, branch_pos, &x)?;
        let out = engine.run_branch(&x)?;
        let classes = InferenceEngine::argmax_classes(&out.probs);
        let edge_s_so_far = t_edge0.elapsed().as_secs_f64();

        let mut survivors = Vec::new();
        for (idx, req_i) in alive.iter().copied().enumerate() {
            entropies[req_i] = out.entropy[idx];
            let exited = out.entropy[idx] < threshold;
            // Every gate decision is an exit-rate observation — exits
            // and survivors alike; the latter are known non-exits the
            // moment the gate passes them, wherever they finish.
            if let Some(obs) = observer {
                obs(exited);
            }
            if exited {
                // Early exit: answer from the branch.
                let req = &chunk[req_i];
                *answered += 1;
                metrics.edge_exits.fetch_add(1, Ordering::Relaxed);
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                let latency = req.enqueued.elapsed().as_secs_f64();
                metrics.record_latency(latency);
                req.reply.send(InferenceResponse {
                    id: req.id,
                    class: classes[idx],
                    exit: ExitPoint::EdgeBranch,
                    entropy: out.entropy[idx],
                    latency_s: latency,
                    edge_s: edge_s_so_far,
                    transfer_s: 0.0,
                    cloud_s: 0.0,
                });
            } else {
                survivors.push(req_i);
            }
        }
        if survivors.is_empty() {
            return Ok(());
        }
        // Re-pack survivors and continue through stages k+1..=s.
        let kept: Vec<HostTensor> = {
            let per_sample = x.unstack();
            survivors.iter().map(|&i| {
                // position of i within `alive`
                let pos = alive.iter().position(|&a| a == i).unwrap();
                per_sample[pos].clone()
            }).collect()
        };
        alive = survivors;
        let stacked = HostTensor::stack(&kept)?;
        let exec_b = engine.bucket_batch(alive.len());
        x = stacked.pad_batch(exec_b);
        if s > branch_pos {
            x = engine.run_stages(branch_pos + 1, s, &x)?;
        }
    } else if s > 0 {
        x = engine.run_stages(1, s, &x)?;
    }

    let edge_s = t_edge0.elapsed().as_secs_f64();

    if s == num_stages {
        // Edge-only: answer from the main output.
        let classes = InferenceEngine::argmax_classes(&x);
        for (idx, req_i) in alive.iter().copied().enumerate() {
            let req = &chunk[req_i];
            metrics.completed.fetch_add(1, Ordering::Relaxed);
            let latency = req.enqueued.elapsed().as_secs_f64();
            metrics.record_latency(latency);
            req.reply.send(InferenceResponse {
                id: req.id,
                class: classes[idx],
                exit: ExitPoint::MainOutput,
                entropy: entropies[req_i],
                latency_s: latency,
                edge_s,
                transfer_s: 0.0,
                cloud_s: 0.0,
            });
        }
        return Ok(());
    }

    // Transfer survivors to the cloud (pipelined: stamp ready_at).
    // The channel is charged what the wire encoding actually ships per
    // sample, not the raw f32 size — q8/q4 shrink the simulated upload
    // exactly as they shrink a physical one.
    let per_sample = x.unstack();
    let sample_bytes: u64 = per_sample
        .first()
        .map(|t| encoding.payload_bytes(t.size_bytes()))
        .unwrap_or(0);
    let total_bytes = sample_bytes * alive.len() as u64;
    let delay = channel.sample_delay(total_bytes);
    metrics
        .transferred_bytes
        .fetch_add(total_bytes, Ordering::Relaxed);
    let ready_at = Instant::now() + delay;
    let transfer_s = delay.as_secs_f64();

    for (idx, req_i) in alive.iter().copied().enumerate() {
        let req = &chunk[req_i];
        let item = TransferredSample {
            id: req.id,
            reply: req.reply.clone(),
            enqueued: req.enqueued,
            activation: per_sample[idx].clone(),
            entropy: entropies[req_i],
            edge_s,
            transfer_s,
            split: s,
            ready_at,
        };
        if let Err(SubmitError::Full(item)) = cloud_queue.submit(item) {
            // Shed: answer with the branch-less fallback? No — reject.
            metrics.rejected.fetch_add(1, Ordering::Relaxed);
            drop(item);
        }
    }
    Ok(())
}

fn cloud_loop(
    exec: CloudExec,
    cloud_queue: Arc<Batcher<TransferredSample>>,
    metrics: Arc<Metrics>,
) {
    let manifest = exec.manifest().clone();
    let num_stages = manifest.num_stages();
    let branch_pos = manifest.branch.after_stage;
    // With an in-process cloud the uplink is simulated: honor the
    // stamped transfer-completion instants. With a remote cloud the
    // genuine TCP round-trip *is* the transfer — sleeping the model's
    // delay on top would double-count the network.
    let simulate_uplink = matches!(&exec, CloudExec::Local(_));

    while let Some(batch) = cloud_queue.next_batch() {
        metrics.cloud_batches.fetch_add(1, Ordering::Relaxed);
        // Each sample carries the split it was cut at, so a batch drawn
        // from the shared queue may mix splits (per-request planning, or
        // a plan switch racing in-flight transfers). Group and run each
        // split's samples together — never under a split they weren't
        // cut at.
        let mut groups: Vec<(usize, Vec<TransferredSample>)> = Vec::new();
        for item in batch {
            match groups.iter_mut().find(|(s, _)| *s == item.split) {
                Some((_, items)) => items.push(item),
                None => groups.push((item.split, vec![item])),
            }
        }
        // Earliest-ready group first, so one late transfer never delays
        // a group whose upload already finished.
        groups.sort_by_key(|(_, items)| items.iter().map(|t| t.ready_at).max());
        for (split, group) in groups {
            // Honor the (simulated) transfer completion time of *this*
            // group only — a fast-link sample must not wait out a
            // slow-link sample that merely shared the batch window.
            if simulate_uplink {
                if let Some(latest) = group.iter().map(|t| t.ready_at).max() {
                    let now = Instant::now();
                    if latest > now {
                        std::thread::sleep(latest - now);
                    }
                }
            }
            debug_assert!(split < num_stages, "edge-only sample transferred");
            match run_cloud_group(&exec, branch_pos, split, &group, &metrics) {
                Ok((classes, cloud_s, wire_s)) => {
                    for (idx, item) in group.iter().enumerate() {
                        metrics.completed.fetch_add(1, Ordering::Relaxed);
                        metrics
                            .cloud_completions
                            .fetch_add(1, Ordering::Relaxed);
                        let latency = item.enqueued.elapsed().as_secs_f64();
                        metrics.record_latency(latency);
                        item.reply.send(InferenceResponse {
                            id: item.id,
                            class: classes[idx],
                            exit: ExitPoint::MainOutput,
                            entropy: item.entropy,
                            latency_s: latency,
                            edge_s: item.edge_s,
                            // Remote-served samples report the measured
                            // wire time; simulated ones the modeled one.
                            transfer_s: wire_s.unwrap_or(item.transfer_s),
                            cloud_s,
                        });
                    }
                }
                Err(e) => {
                    log::error!("cloud batch failed: {e:#}");
                    // Terminal for the whole group (both the remote path
                    // and its local fallback failed): no replies are
                    // coming, so balance the drain ledger. `failed`, not
                    // `rejected` — a broken cloud must not read as
                    // admission pressure and grow the shard set.
                    metrics
                        .failed
                        .fetch_add(group.len() as u64, Ordering::Relaxed);
                }
            }
        }
    }
}

/// Execute one split-group's suffix stages `split+1..=N`: over the wire
/// when a remote cloud is configured (falling back to the local engine
/// on any remote failure, counted in `metrics.remote_fallbacks`),
/// in-process otherwise. Returns one class per sample, the cloud
/// compute seconds (server-measured for the remote path — network time
/// is not compute time), and the wire seconds actually paid:
/// `Some(round-trip − server compute)` for remote-served groups,
/// `Some(0.0)` for remote-mode fallbacks (nothing crossed the wire),
/// `None` for the in-process path (the edge-stamped simulated transfer
/// applies).
fn run_cloud_group(
    exec: &CloudExec,
    branch_pos: usize,
    split: usize,
    group: &[TransferredSample],
    metrics: &Metrics,
) -> Result<(Vec<usize>, f64, Option<f64>)> {
    let tensors: Vec<HostTensor> = group.iter().map(|t| t.activation.clone()).collect();
    let stacked = HostTensor::stack(&tensors)?;
    match exec {
        CloudExec::Local(engine) => {
            let (classes, cloud_s) = local_suffix(engine, split, &stacked, group.len())?;
            Ok((classes, cloud_s, None))
        }
        CloudExec::Remote {
            remote,
            fallback,
            chain,
        } => {
            // Samples cut after the branch already passed the gate on
            // the edge (the active-branch rule: position < split);
            // samples cut at or before it never saw a gate.
            let branch_state = if split > branch_pos {
                BRANCH_GATED
            } else {
                BRANCH_PENDING
            };
            let route = chain.as_ref().filter(|r| !r.tail.is_empty());
            let t0 = Instant::now();
            // Primary wire attempt: a chain frame when a multi-tier
            // route is configured, a plain partial otherwise. The tail
            // is clamped up to the stamped split so a plan switch
            // racing in-flight samples can't produce a decreasing
            // vector.
            let primary = match route {
                Some(r) => {
                    let mut cuts = Vec::with_capacity(r.tail.len() + 1);
                    cuts.push(split as u32);
                    cuts.extend(r.tail.iter().map(|&c| c.max(split) as u32));
                    remote.infer_chain(&cuts, branch_state, &stacked)
                }
                None => remote.infer_partial(split, branch_state, &stacked),
            };
            // Degraded chain service: the same stamped split ships
            // straight to the terminal tier, so a middle-tier brownout
            // costs the middle tier's compute placement — never the
            // request.
            let primary = match primary {
                Err(e) => match route.and_then(|r| r.direct.as_ref()) {
                    Some(direct) => {
                        metrics.chain_fallbacks.fetch_add(1, Ordering::Relaxed);
                        log::warn!(
                            "chain head failed ({e:#}); degrading split {split} group \
                             to the direct cloud"
                        );
                        direct.infer_partial(split, branch_state, &stacked)
                    }
                    None => Err(e),
                },
                ok => ok,
            };
            match primary {
                Ok(out) if out.samples.len() == group.len() => {
                    metrics.remote_batches.fetch_add(1, Ordering::Relaxed);
                    let wire_s = (t0.elapsed().as_secs_f64() - out.cloud_s).max(0.0);
                    let classes = out.samples.iter().map(|s| s.class as usize).collect();
                    Ok((classes, out.cloud_s, Some(wire_s)))
                }
                // Fallback groups never touched the wire and (remote
                // mode) never slept a simulated delay either: their
                // transfer time is genuinely zero, not the modeled one.
                Ok(out) => {
                    metrics.remote_fallbacks.fetch_add(1, Ordering::Relaxed);
                    log::warn!(
                        "cloud server answered {} records for {} samples; running locally",
                        out.samples.len(),
                        group.len()
                    );
                    let (classes, cloud_s) =
                        local_suffix(fallback, split, &stacked, group.len())?;
                    Ok((classes, cloud_s, Some(0.0)))
                }
                Err(e) => {
                    metrics.remote_fallbacks.fetch_add(1, Ordering::Relaxed);
                    log::warn!("cloud offload failed ({e:#}); running split {split} group locally");
                    let (classes, cloud_s) =
                        local_suffix(fallback, split, &stacked, group.len())?;
                    Ok((classes, cloud_s, Some(0.0)))
                }
            }
        }
    }
}

/// The in-process suffix path: run `split+1..=N` on the group via the
/// shared [`InferenceEngine::run_suffix_classes`], timing the compute.
fn local_suffix(
    engine: &InferenceEngine,
    split: usize,
    stacked: &HostTensor,
    n: usize,
) -> Result<(Vec<usize>, f64)> {
    let t0 = Instant::now();
    let classes = engine.run_suffix_classes(split + 1, stacked, n)?;
    Ok((classes, t0.elapsed().as_secs_f64()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::Strategy;
    use crate::model::Manifest;
    use crate::network::trace::BandwidthTrace;

    fn sim_setup() -> (Manifest, InferenceEngine, InferenceEngine, Arc<Channel>) {
        let manifest =
            Manifest::synthetic_sim("sim-eng", vec![4], &[16, 8, 2], 1, 2, vec![1, 2, 4, 8])
                .unwrap();
        let edge = InferenceEngine::open_sim(manifest.clone(), "eng-e").unwrap();
        let cloud = InferenceEngine::open_sim(manifest.clone(), "eng-c").unwrap();
        let channel =
            Arc::new(Channel::new(BandwidthTrace::constant(100.0), 0.0, 0.0, 1).simulated_time());
        (manifest, edge, cloud, channel)
    }

    fn plan_at(manifest: &Manifest, split: usize) -> PartitionPlan {
        PartitionPlan::from_split(split, 0.0, Strategy::ShortestPath, &manifest.to_desc(0.5))
    }

    fn cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            entropy_threshold: 0.0, // nothing exits unless a test raises it
            batch_timeout: Duration::from_millis(1),
            ..Default::default()
        }
    }

    #[test]
    fn per_request_overrides_execute_their_own_split() {
        let (manifest, edge, cloud, channel) = sim_setup();
        let n_stages = manifest.num_stages();
        // Base plan: edge-only. Odd requests override to cloud-only.
        let c = Coordinator::start(edge, cloud, channel, plan_at(&manifest, n_stages), cfg());
        let mut pending = Vec::new();
        for i in 0..8 {
            let img = HostTensor::new(vec![4], vec![0.1 * i as f32, -0.2, 0.3, 0.4]).unwrap();
            let handle = if i % 2 == 1 {
                c.submit_planned(img, plan_at(&manifest, 0)).unwrap()
            } else {
                c.submit(img).unwrap()
            };
            pending.push((i, handle));
        }
        for (i, (_, rx)) in pending {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            if i % 2 == 1 {
                assert!(r.transfer_s > 0.0, "override sample {i} skipped the uplink");
            } else {
                assert_eq!(r.transfer_s, 0.0, "default sample {i} paid a transfer");
                assert_eq!(r.cloud_s, 0.0, "default sample {i} paid cloud compute");
            }
        }
        // The base plan never moved, and every override was counted.
        assert!(c.plan().is_edge_only(n_stages));
        let m = c.shutdown();
        assert_eq!(m.completed, 8);
        assert_eq!(m.plan_overrides, 4);
        assert_eq!(m.plan_switches, 0);
    }

    #[test]
    fn drain_through_shared_handle_answers_everything_first() {
        let (manifest, edge, cloud, channel) = sim_setup();
        let c = Arc::new(Coordinator::start(
            edge,
            cloud,
            channel,
            plan_at(&manifest, 2),
            cfg(),
        ));
        let mut pending = Vec::new();
        for _ in 0..6 {
            pending.push(c.submit(HostTensor::zeros(vec![4])).unwrap());
        }
        // Drain via one clone while another handle stays live (the
        // autoscaler's shrink shape: the shard was popped from the
        // routed set but other owners may exist).
        let snap = c.clone().drain();
        assert_eq!(snap.completed, 6);
        assert_eq!(
            snap.submitted,
            snap.completed + snap.rejected + snap.failed
        );
        for (_, rx) in pending {
            rx.recv_timeout(Duration::from_secs(1))
                .expect("drained request lost its answer");
        }
        // Post-drain submits fail closed — counted as `failed` (not
        // `rejected`: shutdown is not load) so the ledger stays
        // balanced for any later drain call.
        assert!(c.submit(HostTensor::zeros(vec![4])).is_err());
        let m = c.metrics();
        assert_eq!(m.submitted, 7);
        assert_eq!(m.rejected, 0);
        assert_eq!(m.failed, 1);
        assert_eq!(m.completed + m.rejected + m.failed, 7);
        // Idempotent: nothing left to wait for or join.
        assert_eq!(c.drain().completed, 6);
    }

    #[test]
    fn simulated_channel_charges_encoded_bytes_not_raw() {
        // Split 1 on the sim model transfers a 16-element (64-byte raw)
        // activation per sample; the channel must be billed what the
        // configured encoding would actually put on the wire.
        for (enc, want_per_sample) in [
            (WireEncoding::Raw, 64u64),
            (WireEncoding::Q8, 8 + 16),
            (WireEncoding::Q4, 8 + 8),
        ] {
            let (manifest, edge, cloud, channel) = sim_setup();
            let c = Coordinator::start(
                edge,
                cloud,
                channel,
                plan_at(&manifest, 1),
                CoordinatorConfig {
                    wire_encoding: enc,
                    ..cfg()
                },
            );
            for _ in 0..3 {
                c.infer_sync(HostTensor::zeros(vec![4])).unwrap();
            }
            let m = c.shutdown();
            assert_eq!(m.transferred_bytes, 3 * want_per_sample, "{enc:?}");
        }
    }

    #[test]
    fn exit_observer_sees_every_gate_decision() {
        let exits = Arc::new(AtomicU64::new(0));
        let survivals = Arc::new(AtomicU64::new(0));
        let (e2, s2) = (exits.clone(), survivals.clone());
        let observer: ExitObserver = Arc::new(move |exited| {
            if exited {
                e2.fetch_add(1, Ordering::Relaxed);
            } else {
                s2.fetch_add(1, Ordering::Relaxed);
            }
        });

        // Threshold above the entropy ceiling: every gated sample exits.
        let (manifest, edge, cloud, channel) = sim_setup();
        let c = Coordinator::start_observed(
            edge,
            cloud,
            channel,
            plan_at(&manifest, 2), // branch (after stage 1) active
            CoordinatorConfig {
                entropy_threshold: 10.0,
                ..cfg()
            },
            Some(observer.clone()),
        );
        for _ in 0..5 {
            let r = c.infer_sync(HostTensor::zeros(vec![4])).unwrap();
            assert!(r.exited_early());
        }
        let m = c.shutdown();
        assert_eq!(m.edge_exits, 5);
        assert_eq!(exits.load(Ordering::Relaxed), 5);
        assert_eq!(survivals.load(Ordering::Relaxed), 0);

        // Threshold zero: every gated sample survives — and a cloud-only
        // plan produces no observations at all (no branch, no signal).
        let (manifest, edge, cloud, channel) = sim_setup();
        let c = Coordinator::start_observed(
            edge,
            cloud,
            channel,
            plan_at(&manifest, 2),
            cfg(),
            Some(observer.clone()),
        );
        for _ in 0..3 {
            let r = c.infer_sync(HostTensor::zeros(vec![4])).unwrap();
            assert!(!r.exited_early());
        }
        c.set_plan(plan_at(&manifest, 0));
        let _ = c.infer_sync(HostTensor::zeros(vec![4])).unwrap();
        c.shutdown();
        assert_eq!(exits.load(Ordering::Relaxed), 5, "no new exits");
        assert_eq!(
            survivals.load(Ordering::Relaxed),
            3,
            "cloud-only sample must not be observed"
        );
    }
}
