//! Request/response types flowing through the coordinator.

use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::partition::plan::PartitionPlan;
use crate::runtime::HostTensor;

/// A shared completion funnel: many in-flight requests deliver into one
/// consumer. The reactor front end implements this with a lock-guarded
/// queue plus an eventfd wake so thousands of connections multiplex
/// onto one readiness loop instead of one blocked thread each.
pub trait CompletionSink: Send + Sync {
    /// Deliver one finished request. `tag` is the submitter's own
    /// correlation key (echoed from [`ReplyTo::Sink`]), independent of
    /// the coordinator-assigned response id — shard-local ids are not
    /// unique across a fleet, tags are.
    fn complete(&self, tag: u64, resp: InferenceResponse);
}

/// Where a request's answer goes. The blocking path keeps its
/// per-request channel; the reactor path funnels tagged completions
/// into a shared sink.
#[derive(Clone)]
pub enum ReplyTo {
    /// One dedicated channel per request; the submitter blocks on (or
    /// polls) its own receiver.
    Channel(mpsc::Sender<InferenceResponse>),
    /// Shared sink: the completion is delivered as `(tag, response)` to
    /// a consumer multiplexing many requests.
    Sink { sink: Arc<dyn CompletionSink>, tag: u64 },
}

impl ReplyTo {
    /// Deliver the response. Send failures (a blocking submitter that
    /// gave up and dropped its receiver) are deliberately ignored, as
    /// they always were on the channel path.
    pub fn send(&self, resp: InferenceResponse) {
        match self {
            ReplyTo::Channel(tx) => {
                let _ = tx.send(resp);
            }
            ReplyTo::Sink { sink, tag } => sink.complete(*tag, resp),
        }
    }
}

impl std::fmt::Debug for ReplyTo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReplyTo::Channel(_) => f.write_str("ReplyTo::Channel"),
            ReplyTo::Sink { tag, .. } => write!(f, "ReplyTo::Sink(tag={tag})"),
        }
    }
}

/// Where a sample's classification came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExitPoint {
    /// Classified by the side branch on the edge device.
    EdgeBranch,
    /// Classified by the main-branch output (in the cloud, or on the edge
    /// when the plan is edge-only).
    MainOutput,
}

#[derive(Debug)]
pub struct InferenceRequest {
    pub id: u64,
    /// One sample, CHW (no batch dim).
    pub image: HostTensor,
    pub enqueued: Instant,
    /// Response destination (one response per request).
    pub reply: ReplyTo,
    /// Per-request partition plan override (per-request planning: the
    /// fleet solved this sample's split at the instantaneous link).
    /// `None` executes under the coordinator's current plan.
    pub plan: Option<PartitionPlan>,
}

#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    pub class: usize,
    pub exit: ExitPoint,
    /// Branch entropy of this sample (NaN when the plan has no active
    /// branch on the edge).
    pub entropy: f32,
    /// End-to-end latency, seconds.
    pub latency_s: f64,
    /// Time spent in edge compute / transfer / cloud compute, seconds.
    pub edge_s: f64,
    pub transfer_s: f64,
    pub cloud_s: f64,
}

impl InferenceResponse {
    pub fn exited_early(&self) -> bool {
        self.exit == ExitPoint::EdgeBranch
    }
}
