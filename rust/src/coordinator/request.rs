//! Request/response types flowing through the coordinator.

use std::sync::mpsc;
use std::time::Instant;

use crate::partition::plan::PartitionPlan;
use crate::runtime::HostTensor;

/// Where a sample's classification came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExitPoint {
    /// Classified by the side branch on the edge device.
    EdgeBranch,
    /// Classified by the main-branch output (in the cloud, or on the edge
    /// when the plan is edge-only).
    MainOutput,
}

#[derive(Debug)]
pub struct InferenceRequest {
    pub id: u64,
    /// One sample, CHW (no batch dim).
    pub image: HostTensor,
    pub enqueued: Instant,
    /// Response channel (one response per request).
    pub reply: mpsc::Sender<InferenceResponse>,
    /// Per-request partition plan override (per-request planning: the
    /// fleet solved this sample's split at the instantaneous link).
    /// `None` executes under the coordinator's current plan.
    pub plan: Option<PartitionPlan>,
}

#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    pub class: usize,
    pub exit: ExitPoint,
    /// Branch entropy of this sample (NaN when the plan has no active
    /// branch on the edge).
    pub entropy: f32,
    /// End-to-end latency, seconds.
    pub latency_s: f64,
    /// Time spent in edge compute / transfer / cloud compute, seconds.
    pub edge_s: f64,
    pub transfer_s: f64,
    pub cloud_s: f64,
}

impl InferenceResponse {
    pub fn exited_early(&self) -> bool {
        self.exit == ExitPoint::EdgeBranch
    }
}
