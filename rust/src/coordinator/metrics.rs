//! Serving metrics: lock-free counters on the hot path (atomics), with
//! mutex-guarded latency histograms sampled per response.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::LatencyHistogram;
use crate::util::timefmt::{format_rate, format_secs};

#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    /// Requests shed by backpressure: admission-queue or transfer-queue
    /// overflow. This is the autoscaler's scale-up signal, so it must
    /// mean *load* — terminal failures live in `failed` instead.
    pub rejected: AtomicU64,
    /// Requests that terminated without an answer for non-load reasons:
    /// submits after close, and the (rare) batch whose compute failed
    /// outright. `submitted == completed + rejected + failed` is the
    /// ledger `Coordinator::drain` settles on.
    pub failed: AtomicU64,
    pub completed: AtomicU64,
    pub edge_exits: AtomicU64,
    pub cloud_completions: AtomicU64,
    pub transferred_bytes: AtomicU64,
    pub edge_batches: AtomicU64,
    pub cloud_batches: AtomicU64,
    /// Live partition-plan switches applied by adaptive replanning
    /// (incremented by `Coordinator::set_plan` when the split moves).
    pub plan_switches: AtomicU64,
    /// Requests admitted with a per-request plan override
    /// (`Coordinator::submit_planned` — fleet per-request planning).
    pub plan_overrides: AtomicU64,
    /// Split-groups served by a remote cloud-stage server.
    pub remote_batches: AtomicU64,
    /// Split-groups that fell back to local execution after a remote
    /// failure (connect/IO error, backoff window, in-flight cap).
    pub remote_fallbacks: AtomicU64,
    /// Chain-routed groups that degraded to the direct (single-hop)
    /// remote after the chain head failed — the samples still complete
    /// in the cloud, just without the middle tier(s).
    pub chain_fallbacks: AtomicU64,
    latency: Mutex<LatencyHistogram>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    #[inline]
    pub fn record_latency(&self, secs: f64) {
        self.latency.lock().unwrap().push(secs);
    }

    pub fn snapshot(&self, since: Instant) -> MetricsSnapshot {
        let elapsed = since.elapsed().as_secs_f64().max(1e-9);
        let completed = self.completed.load(Ordering::Relaxed);
        // Fixed-size clone (~80 buckets + scalars): snapshots stay cheap
        // no matter how long the shard has been serving, and a fleet can
        // merge them losslessly.
        let hist = self.latency.lock().unwrap().clone();
        // A window that served nothing reports zeros, not NaN: snapshots
        // of idle shards get aggregated, serialized and formatted, and a
        // NaN poisons every one of those paths.
        let (p50_s, p99_s) = if hist.count() == 0 {
            (0.0, 0.0)
        } else {
            (hist.quantile(0.5), hist.quantile(0.99))
        };
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            completed,
            edge_exits: self.edge_exits.load(Ordering::Relaxed),
            cloud_completions: self.cloud_completions.load(Ordering::Relaxed),
            transferred_bytes: self.transferred_bytes.load(Ordering::Relaxed),
            edge_batches: self.edge_batches.load(Ordering::Relaxed),
            cloud_batches: self.cloud_batches.load(Ordering::Relaxed),
            plan_switches: self.plan_switches.load(Ordering::Relaxed),
            plan_overrides: self.plan_overrides.load(Ordering::Relaxed),
            remote_batches: self.remote_batches.load(Ordering::Relaxed),
            remote_fallbacks: self.remote_fallbacks.load(Ordering::Relaxed),
            chain_fallbacks: self.chain_fallbacks.load(Ordering::Relaxed),
            throughput_rps: completed as f64 / elapsed,
            mean_latency_s: hist.mean(),
            p50_s,
            p99_s,
            elapsed_s: elapsed,
            latency_hist: hist,
        }
    }
}

/// Point-in-time view for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    /// Backpressure sheds (queue overflow) — the load signal.
    pub rejected: u64,
    /// Terminal non-load failures (post-close submits, failed batches).
    pub failed: u64,
    pub completed: u64,
    pub edge_exits: u64,
    pub cloud_completions: u64,
    pub transferred_bytes: u64,
    pub edge_batches: u64,
    pub cloud_batches: u64,
    pub plan_switches: u64,
    /// Requests admitted with a per-request plan override.
    pub plan_overrides: u64,
    /// Split-groups served by a remote cloud-stage server.
    pub remote_batches: u64,
    /// Split-groups that fell back to local execution after a remote
    /// failure.
    pub remote_fallbacks: u64,
    /// Chain-routed groups that degraded to the direct single-hop
    /// remote after the chain head failed.
    pub chain_fallbacks: u64,
    pub throughput_rps: f64,
    pub mean_latency_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub elapsed_s: f64,
    /// Full-run latency distribution (fixed-size log histogram; merging
    /// these is how fleet aggregates stay accurate over long runs).
    pub latency_hist: LatencyHistogram,
}

impl MetricsSnapshot {
    /// An all-zero snapshot (the identity element of [`Self::aggregate`]).
    pub fn zero() -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: 0,
            rejected: 0,
            failed: 0,
            completed: 0,
            edge_exits: 0,
            cloud_completions: 0,
            transferred_bytes: 0,
            edge_batches: 0,
            cloud_batches: 0,
            plan_switches: 0,
            plan_overrides: 0,
            remote_batches: 0,
            remote_fallbacks: 0,
            chain_fallbacks: 0,
            throughput_rps: 0.0,
            mean_latency_s: 0.0,
            p50_s: 0.0,
            p99_s: 0.0,
            elapsed_s: 0.0,
            latency_hist: LatencyHistogram::new(),
        }
    }

    /// Combine per-shard (or per-class) snapshots into one view:
    /// counters add, the latency histograms merge losslessly (so the
    /// aggregate's mean/p50/p99 cover the *whole* run, exactly like each
    /// shard's own), and throughput is total completions over the
    /// longest window (the shards ran concurrently, not back to back).
    /// Empty input — and shards that served nothing — aggregate to
    /// zeros, not NaN.
    pub fn aggregate(parts: &[MetricsSnapshot]) -> MetricsSnapshot {
        let mut out = MetricsSnapshot::zero();
        for p in parts {
            out.submitted += p.submitted;
            out.rejected += p.rejected;
            out.failed += p.failed;
            out.completed += p.completed;
            out.edge_exits += p.edge_exits;
            out.cloud_completions += p.cloud_completions;
            out.transferred_bytes += p.transferred_bytes;
            out.edge_batches += p.edge_batches;
            out.cloud_batches += p.cloud_batches;
            out.plan_switches += p.plan_switches;
            out.plan_overrides += p.plan_overrides;
            out.remote_batches += p.remote_batches;
            out.remote_fallbacks += p.remote_fallbacks;
            out.chain_fallbacks += p.chain_fallbacks;
            out.elapsed_s = out.elapsed_s.max(p.elapsed_s);
            out.latency_hist.merge(&p.latency_hist);
        }
        if out.elapsed_s > 0.0 {
            out.throughput_rps = out.completed as f64 / out.elapsed_s;
        }
        out.mean_latency_s = out.latency_hist.mean();
        if out.latency_hist.count() > 0 {
            out.p50_s = out.latency_hist.quantile(0.5);
            out.p99_s = out.latency_hist.quantile(0.99);
        }
        out
    }

    /// Flat JSON for the server's METRICS response.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"completed\":{},\"edge_exits\":{},\"rejected\":{},\"failed\":{},\
             \"remote_batches\":{},\"remote_fallbacks\":{},\"chain_fallbacks\":{},\
             \"throughput_rps\":{:.3},\"p50_s\":{:.6},\"p99_s\":{:.6}}}",
            self.completed,
            self.edge_exits,
            self.rejected,
            self.failed,
            self.remote_batches,
            self.remote_fallbacks,
            self.chain_fallbacks,
            self.throughput_rps,
            self.p50_s,
            self.p99_s
        )
    }

    pub fn exit_rate(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.edge_exits as f64 / self.completed as f64
        }
    }

    pub fn summary(&self) -> String {
        let remote = if self.remote_batches + self.remote_fallbacks > 0 {
            let chain = if self.chain_fallbacks > 0 {
                format!(", {} chain-degraded", self.chain_fallbacks)
            } else {
                String::new()
            };
            format!(
                ", remote cloud batches {} ({} fell back local{chain})",
                self.remote_batches, self.remote_fallbacks
            )
        } else {
            String::new()
        };
        // Failures are rare and alarming; only show them when nonzero.
        let failed = if self.failed > 0 {
            format!(" (+{} failed)", self.failed)
        } else {
            String::new()
        };
        format!(
            "completed {} ({} early-exit, {:.1}%), rejected {}{failed}, throughput {}, \
             latency mean {} p50 {} p99 {}, transferred {} bytes, plan switches {}{}",
            self.completed,
            self.edge_exits,
            self.exit_rate() * 100.0,
            self.rejected,
            format_rate(self.throughput_rps),
            format_secs(self.mean_latency_s),
            format_secs(self.p50_s),
            format_secs(self.p99_s),
            self.transferred_bytes,
            self.plan_switches,
            remote,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_snapshot() {
        let m = Metrics::new();
        let t0 = Instant::now();
        m.submitted.fetch_add(10, Ordering::Relaxed);
        m.completed.fetch_add(8, Ordering::Relaxed);
        m.edge_exits.fetch_add(3, Ordering::Relaxed);
        for i in 0..8 {
            m.record_latency(0.001 * (i + 1) as f64);
        }
        let s = m.snapshot(t0);
        assert_eq!(s.submitted, 10);
        assert_eq!(s.completed, 8);
        assert!((s.exit_rate() - 0.375).abs() < 1e-12);
        assert!((s.mean_latency_s - 0.0045).abs() < 1e-12);
        assert!(s.p50_s > 0.0);
        assert!(s.summary().contains("completed 8"));
    }

    #[test]
    fn empty_snapshot_is_sane() {
        // A shard that has served nothing yet must report clean zeros —
        // no NaN in any statistic, a renderable summary, valid JSON.
        let m = Metrics::new();
        let s = m.snapshot(Instant::now());
        assert_eq!(s.exit_rate(), 0.0);
        assert_eq!(s.mean_latency_s, 0.0);
        assert_eq!(s.p50_s, 0.0);
        assert_eq!(s.p99_s, 0.0);
        assert!(!s.summary().contains("NaN"), "{}", s.summary());
        assert!(s.to_json().contains("\"completed\":0"));
    }

    #[test]
    fn aggregate_pools_counters_and_latencies() {
        let t0 = Instant::now();
        let a = Metrics::new();
        a.completed.fetch_add(4, Ordering::Relaxed);
        a.edge_exits.fetch_add(1, Ordering::Relaxed);
        for v in [0.010, 0.020, 0.030, 0.040] {
            a.record_latency(v);
        }
        let b = Metrics::new();
        b.completed.fetch_add(2, Ordering::Relaxed);
        for v in [0.050, 0.060] {
            b.record_latency(v);
        }
        let idle = Metrics::new(); // zero-request shard rides along

        std::thread::sleep(std::time::Duration::from_millis(5));
        let parts = [a.snapshot(t0), b.snapshot(t0), idle.snapshot(t0)];
        let total = MetricsSnapshot::aggregate(&parts);
        assert_eq!(total.completed, 6);
        assert_eq!(total.edge_exits, 1);
        assert_eq!(total.latency_hist.count(), 6);
        assert!((total.mean_latency_s - 0.035).abs() < 1e-12);
        assert!(total.p50_s > 0.0 && total.p99_s >= total.p50_s);
        // Concurrent windows: elapsed is the max, not the sum.
        let max_elapsed = parts.iter().map(|p| p.elapsed_s).fold(0.0, f64::max);
        assert_eq!(total.elapsed_s, max_elapsed);
        assert!((total.throughput_rps - 6.0 / max_elapsed).abs() < 1e-9);

        // Identity: aggregating nothing is the zero snapshot.
        let z = MetricsSnapshot::aggregate(&[]);
        assert_eq!(z.completed, 0);
        assert_eq!(z.mean_latency_s, 0.0);
    }
}
