//! Serving metrics: lock-free counters on the hot path (atomics), with
//! mutex-guarded latency histograms sampled per response.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::LatencyHistogram;
use crate::util::timefmt::{format_rate, format_secs};

#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub edge_exits: AtomicU64,
    pub cloud_completions: AtomicU64,
    pub transferred_bytes: AtomicU64,
    pub edge_batches: AtomicU64,
    pub cloud_batches: AtomicU64,
    /// Live partition-plan switches applied by adaptive replanning
    /// (incremented by `Coordinator::set_plan` when the split moves).
    pub plan_switches: AtomicU64,
    latency: Mutex<LatencyHistogram>,
    latency_samples: Mutex<Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    #[inline]
    pub fn record_latency(&self, secs: f64) {
        self.latency.lock().unwrap().push(secs);
        let mut v = self.latency_samples.lock().unwrap();
        // Reservoir cap to bound memory on long runs.
        if v.len() < 100_000 {
            v.push(secs);
        }
    }

    pub fn snapshot(&self, since: Instant) -> MetricsSnapshot {
        let elapsed = since.elapsed().as_secs_f64().max(1e-9);
        let completed = self.completed.load(Ordering::Relaxed);
        let samples = self.latency_samples.lock().unwrap().clone();
        let hist = self.latency.lock().unwrap().clone();
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed,
            edge_exits: self.edge_exits.load(Ordering::Relaxed),
            cloud_completions: self.cloud_completions.load(Ordering::Relaxed),
            transferred_bytes: self.transferred_bytes.load(Ordering::Relaxed),
            edge_batches: self.edge_batches.load(Ordering::Relaxed),
            cloud_batches: self.cloud_batches.load(Ordering::Relaxed),
            plan_switches: self.plan_switches.load(Ordering::Relaxed),
            throughput_rps: completed as f64 / elapsed,
            mean_latency_s: if samples.is_empty() {
                f64::NAN
            } else {
                samples.iter().sum::<f64>() / samples.len() as f64
            },
            p50_s: hist.quantile(0.5),
            p99_s: hist.quantile(0.99),
            elapsed_s: elapsed,
            samples,
        }
    }
}

/// Point-in-time view for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub edge_exits: u64,
    pub cloud_completions: u64,
    pub transferred_bytes: u64,
    pub edge_batches: u64,
    pub cloud_batches: u64,
    pub plan_switches: u64,
    pub throughput_rps: f64,
    pub mean_latency_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub elapsed_s: f64,
    pub samples: Vec<f64>,
}

impl MetricsSnapshot {
    pub fn exit_rate(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.edge_exits as f64 / self.completed as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "completed {} ({} early-exit, {:.1}%), rejected {}, throughput {}, \
             latency mean {} p50 {} p99 {}, transferred {} bytes, plan switches {}",
            self.completed,
            self.edge_exits,
            self.exit_rate() * 100.0,
            self.rejected,
            format_rate(self.throughput_rps),
            format_secs(self.mean_latency_s),
            format_secs(self.p50_s),
            format_secs(self.p99_s),
            self.transferred_bytes,
            self.plan_switches,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_snapshot() {
        let m = Metrics::new();
        let t0 = Instant::now();
        m.submitted.fetch_add(10, Ordering::Relaxed);
        m.completed.fetch_add(8, Ordering::Relaxed);
        m.edge_exits.fetch_add(3, Ordering::Relaxed);
        for i in 0..8 {
            m.record_latency(0.001 * (i + 1) as f64);
        }
        let s = m.snapshot(t0);
        assert_eq!(s.submitted, 10);
        assert_eq!(s.completed, 8);
        assert!((s.exit_rate() - 0.375).abs() < 1e-12);
        assert!((s.mean_latency_s - 0.0045).abs() < 1e-12);
        assert!(s.p50_s > 0.0);
        assert!(s.summary().contains("completed 8"));
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let m = Metrics::new();
        let s = m.snapshot(Instant::now());
        assert_eq!(s.exit_rate(), 0.0);
        assert!(s.mean_latency_s.is_nan());
    }
}
