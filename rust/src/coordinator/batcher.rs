//! Dynamic batcher: size-or-deadline batching with bounded-queue
//! admission control (the backpressure point of the serving path).
//!
//! Semantics:
//! * `submit` rejects when the queue is at capacity (admission control);
//! * a worker's `next_batch` blocks until at least one item is queued,
//!   then collects up to `max_batch` items, waiting at most
//!   `batch_timeout` after the *first* item arrived (classic
//!   deadline-based dynamic batching a la vLLM/Triton);
//! * `close` wakes all workers; drained-and-closed returns `None`.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug)]
struct State<T> {
    queue: VecDeque<(Instant, T)>,
    closed: bool,
}

#[derive(Debug)]
pub struct Batcher<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    capacity: usize,
    max_batch: usize,
    batch_timeout: Duration,
}

/// Why a submit was rejected.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError<T> {
    /// Queue full — caller should shed load or retry later.
    Full(T),
    /// Batcher closed.
    Closed(T),
}

impl<T> Batcher<T> {
    pub fn new(capacity: usize, max_batch: usize, batch_timeout: Duration) -> Batcher<T> {
        assert!(capacity > 0 && max_batch > 0);
        Batcher {
            state: Mutex::new(State {
                queue: VecDeque::with_capacity(capacity),
                closed: false,
            }),
            not_empty: Condvar::new(),
            capacity,
            max_batch,
            batch_timeout,
        }
    }

    pub fn submit(&self, item: T) -> Result<(), SubmitError<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(SubmitError::Closed(item));
        }
        if st.queue.len() >= self.capacity {
            return Err(SubmitError::Full(item));
        }
        st.queue.push_back((Instant::now(), item));
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking batch pull; `None` only after `close` with a drained queue.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        let mut st = self.state.lock().unwrap();
        'restart: loop {
            // Wait for the first item (or close).
            loop {
                if !st.queue.is_empty() {
                    break;
                }
                if st.closed {
                    return None;
                }
                st = self.not_empty.wait(st).unwrap();
            }
            // Deadline anchored at the oldest queued item.
            let deadline = st.queue.front().unwrap().0 + self.batch_timeout;
            loop {
                if st.queue.len() >= self.max_batch || st.closed {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (next, timeout) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
                st = next;
                if timeout.timed_out() {
                    break;
                }
                // With multiple consumers, a sibling may have drained the
                // queue while we slept; re-anchor on the (new) oldest item.
                if st.queue.is_empty() {
                    continue 'restart;
                }
            }
            // Same race on the deadline/timeout exits.
            if st.queue.is_empty() {
                if st.closed {
                    return None;
                }
                continue 'restart;
            }
            let n = st.queue.len().min(self.max_batch);
            return Some(st.queue.drain(..n).map(|(_, item)| item).collect());
        }
    }

    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn batches_up_to_max() {
        let b = Batcher::new(100, 4, Duration::from_millis(50));
        for i in 0..10 {
            b.submit(i).unwrap();
        }
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
        assert_eq!(b.next_batch().unwrap(), vec![8, 9]); // deadline flush
    }

    #[test]
    fn deadline_flush_partial_batch() {
        let b = Arc::new(Batcher::new(100, 8, Duration::from_millis(30)));
        let b2 = b.clone();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(5));
        b.submit(42).unwrap();
        let batch = h.join().unwrap().unwrap();
        assert_eq!(batch, vec![42]);
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(30), "{dt:?}");
        assert!(dt < Duration::from_millis(300), "{dt:?}");
    }

    #[test]
    fn full_batch_returns_before_deadline() {
        let b = Arc::new(Batcher::new(100, 2, Duration::from_secs(10)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || {
            let t0 = Instant::now();
            let batch = b2.next_batch().unwrap();
            (batch, t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(10));
        b.submit(1).unwrap();
        b.submit(2).unwrap();
        let (batch, dt) = h.join().unwrap();
        assert_eq!(batch, vec![1, 2]);
        assert!(dt < Duration::from_secs(1), "must not wait out the deadline");
    }

    #[test]
    fn capacity_backpressure() {
        let b = Batcher::new(2, 8, Duration::from_millis(1));
        b.submit(1).unwrap();
        b.submit(2).unwrap();
        match b.submit(3) {
            Err(SubmitError::Full(3)) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn close_semantics() {
        let b = Batcher::new(10, 4, Duration::from_millis(1));
        b.submit(7).unwrap();
        b.close();
        assert!(matches!(b.submit(8), Err(SubmitError::Closed(8))));
        // Drain what's left, then None.
        assert_eq!(b.next_batch().unwrap(), vec![7]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn close_wakes_blocked_worker() {
        let b = Arc::new(Batcher::<u32>::new(10, 4, Duration::from_secs(100)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(10));
        b.close();
        assert!(h.join().unwrap().is_none());
    }
}
