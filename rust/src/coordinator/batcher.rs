//! Dynamic batcher: size-or-deadline batching with bounded-queue
//! admission control (the backpressure point of the serving path).
//!
//! Semantics:
//! * `submit` rejects when the queue is at capacity (admission control);
//! * a worker's `next_batch` blocks until at least one item is queued,
//!   then collects up to `max_batch` items, waiting at most
//!   `batch_timeout` after the *first* item arrived (classic
//!   deadline-based dynamic batching a la vLLM/Triton);
//! * `close` wakes all workers; drained-and-closed returns `None`.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug)]
struct State<T> {
    queue: VecDeque<(Instant, T)>,
    closed: bool,
}

#[derive(Debug)]
pub struct Batcher<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    capacity: usize,
    max_batch: usize,
    batch_timeout: Duration,
}

/// Why a submit was rejected.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError<T> {
    /// Queue full — caller should shed load or retry later.
    Full(T),
    /// Batcher closed.
    Closed(T),
}

impl<T> Batcher<T> {
    pub fn new(capacity: usize, max_batch: usize, batch_timeout: Duration) -> Batcher<T> {
        assert!(capacity > 0 && max_batch > 0);
        Batcher {
            state: Mutex::new(State {
                queue: VecDeque::with_capacity(capacity),
                closed: false,
            }),
            not_empty: Condvar::new(),
            capacity,
            max_batch,
            batch_timeout,
        }
    }

    pub fn submit(&self, item: T) -> Result<(), SubmitError<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(SubmitError::Closed(item));
        }
        if st.queue.len() >= self.capacity {
            return Err(SubmitError::Full(item));
        }
        st.queue.push_back((Instant::now(), item));
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking batch pull; `None` only after `close` with a drained queue.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        let mut st = self.state.lock().unwrap();
        loop {
            // Wait for the first item (or close).
            while st.queue.is_empty() {
                if st.closed {
                    return None;
                }
                st = self.not_empty.wait(st).unwrap();
            }
            // Collect until the batch fills or the oldest queued item's
            // deadline lapses. The deadline is re-derived from the
            // *current* front every iteration: with sibling consumers
            // (M cloud workers share one queue) the item we anchored on
            // may have been drained by another worker while we slept,
            // and a deadline cached from a consumed item would flush a
            // fresh item's batch early.
            loop {
                if st.queue.is_empty() {
                    if st.closed {
                        return None;
                    }
                    break; // sibling drained it; back to the outer wait
                }
                if st.queue.len() >= self.max_batch || st.closed {
                    return Some(Self::drain_locked(&mut st, self.max_batch));
                }
                let deadline = st.queue.front().unwrap().0 + self.batch_timeout;
                let now = Instant::now();
                if now >= deadline {
                    return Some(Self::drain_locked(&mut st, self.max_batch));
                }
                let (next, _) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
                st = next;
            }
        }
    }

    fn drain_locked(st: &mut State<T>, max_batch: usize) -> Vec<T> {
        let n = st.queue.len().min(max_batch);
        st.queue.drain(..n).map(|(_, item)| item).collect()
    }

    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn batches_up_to_max() {
        let b = Batcher::new(100, 4, Duration::from_millis(50));
        for i in 0..10 {
            b.submit(i).unwrap();
        }
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
        assert_eq!(b.next_batch().unwrap(), vec![8, 9]); // deadline flush
    }

    #[test]
    fn deadline_flush_partial_batch() {
        let b = Arc::new(Batcher::new(100, 8, Duration::from_millis(30)));
        let b2 = b.clone();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(5));
        b.submit(42).unwrap();
        let batch = h.join().unwrap().unwrap();
        assert_eq!(batch, vec![42]);
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(30), "{dt:?}");
        assert!(dt < Duration::from_millis(300), "{dt:?}");
    }

    #[test]
    fn full_batch_returns_before_deadline() {
        let b = Arc::new(Batcher::new(100, 2, Duration::from_secs(10)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || {
            let t0 = Instant::now();
            let batch = b2.next_batch().unwrap();
            (batch, t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(10));
        b.submit(1).unwrap();
        b.submit(2).unwrap();
        let (batch, dt) = h.join().unwrap();
        assert_eq!(batch, vec![1, 2]);
        assert!(dt < Duration::from_secs(1), "must not wait out the deadline");
    }

    #[test]
    fn capacity_backpressure() {
        let b = Batcher::new(2, 8, Duration::from_millis(1));
        b.submit(1).unwrap();
        b.submit(2).unwrap();
        match b.submit(3) {
            Err(SubmitError::Full(3)) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn close_semantics() {
        let b = Batcher::new(10, 4, Duration::from_millis(1));
        b.submit(7).unwrap();
        b.close();
        assert!(matches!(b.submit(8), Err(SubmitError::Closed(8))));
        // Drain what's left, then None.
        assert_eq!(b.next_batch().unwrap(), vec![7]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn close_wakes_blocked_worker() {
        let b = Arc::new(Batcher::<u32>::new(10, 4, Duration::from_secs(100)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(10));
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn deadline_anchored_at_first_item_not_extended_by_later_ones() {
        // Items trickle in faster than the timeout; the batch must flush
        // at (first item + timeout), not slide to the newest arrival.
        let b = Arc::new(Batcher::new(100, 64, Duration::from_millis(60)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || {
            let batch = b2.next_batch().unwrap();
            (batch, Instant::now())
        });
        let t0 = Instant::now();
        for i in 0..10 {
            b.submit(i).unwrap();
            std::thread::sleep(Duration::from_millis(15));
        }
        let (batch, flushed_at) = h.join().unwrap();
        let dt = flushed_at - t0;
        assert!(!batch.is_empty() && batch[0] == 0);
        assert!(
            batch.len() < 10,
            "a per-item deadline would have collected all 10: {batch:?}"
        );
        assert!(dt >= Duration::from_millis(55), "flushed too early: {dt:?}");
        assert!(
            dt < Duration::from_millis(140),
            "deadline slid with later arrivals: {dt:?}"
        );
    }

    #[test]
    fn close_flushes_worker_waiting_on_deadline() {
        // A worker holding a partial batch inside the (very long)
        // deadline wait must be woken by close() and return the batch —
        // not sleep out the timeout or lose the items.
        let b = Arc::new(Batcher::new(10, 8, Duration::from_secs(100)));
        b.submit(7u32).unwrap();
        let b2 = b.clone();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert_eq!(h.join().unwrap().unwrap(), vec![7]);
        assert!(t0.elapsed() < Duration::from_secs(5));
        // Drained and closed: the next pull reports shutdown.
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn sibling_consumers_share_one_queue_without_losing_items() {
        // Two workers on one queue (the M-cloud-workers shape): every
        // item is delivered exactly once across the pair.
        let b = Arc::new(Batcher::new(1000, 4, Duration::from_millis(2)));
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let b2 = b.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(batch) = b2.next_batch() {
                        got.extend(batch);
                    }
                    got
                })
            })
            .collect();
        for i in 0..200u32 {
            b.submit(i).unwrap();
            if i % 16 == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        // Let the queue drain before closing.
        while !b.is_empty() {
            std::thread::sleep(Duration::from_millis(1));
        }
        b.close();
        let mut all: Vec<u32> = workers
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..200).collect::<Vec<_>>());
    }
}
