//! Per-stage delay vectors: `t_i^e` (edge) and `t_i^c` (cloud), plus the
//! side-branch evaluation cost.
//!
//! The paper obtains `t_c` by measuring each layer on the cloud device
//! (§VI; our `profiler` does the same against the PJRT runtime) and sets
//! `t_i^e = gamma * t_i^c` with the processing factor gamma spanning edge
//! hardware classes (Jetson ~ low gamma, Raspberry Pi ~ high gamma).

use anyhow::{bail, Result};

#[derive(Debug, Clone)]
pub struct DelayProfile {
    /// Processing time of stage i on the edge device, seconds (t_i^e).
    pub t_edge: Vec<f64>,
    /// Processing time of stage i on the cloud server, seconds (t_i^c).
    pub t_cloud: Vec<f64>,
    /// Side-branch evaluation time on the edge device, seconds.
    ///
    /// The paper's Eq. 5 folds branch compute into the layer times (it
    /// never appears as a separate term); keeping it separate lets the
    /// estimator either reproduce the paper exactly
    /// (`include_branch_cost = false`) or model the real serving system
    /// (`true`). Applied per side branch.
    pub branch_t_edge: f64,
    /// The gamma used to derive `t_edge`, kept for reporting.
    pub gamma: f64,
}

impl DelayProfile {
    /// Build from measured cloud times with the paper's proportionality
    /// model `t_e = gamma * t_c` (§VI).
    pub fn from_cloud_times(t_cloud: Vec<f64>, branch_t_cloud: f64, gamma: f64) -> DelayProfile {
        assert!(gamma >= 1.0, "gamma must be >= 1, got {gamma}");
        DelayProfile {
            t_edge: t_cloud.iter().map(|t| t * gamma).collect(),
            branch_t_edge: branch_t_cloud * gamma,
            t_cloud,
            gamma,
        }
    }

    /// Re-derive for a different gamma (cheap; used by the Fig. 5 sweep).
    pub fn with_gamma(&self, gamma: f64) -> DelayProfile {
        assert!(gamma >= 1.0);
        DelayProfile {
            t_edge: self.t_cloud.iter().map(|t| t * gamma).collect(),
            branch_t_edge: self.branch_t_edge / self.gamma * gamma,
            t_cloud: self.t_cloud.clone(),
            gamma,
        }
    }

    pub fn num_stages(&self) -> usize {
        self.t_cloud.len()
    }

    /// Total cloud time of stages `s+1..=N` (the T_c of Eq. 2 for a split
    /// after stage s). O(N); hot paths use [`CloudSuffix`].
    pub fn cloud_suffix(&self, split_after: usize) -> f64 {
        self.t_cloud[split_after..].iter().sum()
    }

    /// Total edge time of stages `1..=s` ignoring exits (Eq. 1's T_e).
    pub fn edge_prefix(&self, split_after: usize) -> f64 {
        self.t_edge[..split_after].iter().sum()
    }

    pub fn validate(&self, n_stages: usize) -> Result<()> {
        if self.t_edge.len() != n_stages || self.t_cloud.len() != n_stages {
            bail!(
                "profile has {} edge / {} cloud stages, expected {n_stages}",
                self.t_edge.len(),
                self.t_cloud.len()
            );
        }
        for (i, (&e, &c)) in self.t_edge.iter().zip(&self.t_cloud).enumerate() {
            if !(e.is_finite() && e >= 0.0 && c.is_finite() && c >= 0.0) {
                bail!("stage {} has invalid times edge={e} cloud={c}", i + 1);
            }
        }
        if !(self.branch_t_edge.is_finite() && self.branch_t_edge >= 0.0) {
            bail!("invalid branch time {}", self.branch_t_edge);
        }
        Ok(())
    }
}

/// Precomputed suffix sums of cloud times for O(1) `T_c(s)` lookups in
/// the brute-force baseline and the graph construction.
#[derive(Debug, Clone)]
pub struct CloudSuffix {
    /// suffix[s] = sum of t_cloud[s..]; suffix[N] = 0.
    suffix: Vec<f64>,
}

impl CloudSuffix {
    pub fn new(profile: &DelayProfile) -> CloudSuffix {
        let n = profile.num_stages();
        let mut suffix = vec![0.0; n + 1];
        for i in (0..n).rev() {
            suffix[i] = suffix[i + 1] + profile.t_cloud[i];
        }
        CloudSuffix { suffix }
    }

    #[inline]
    pub fn from_split(&self, split_after: usize) -> f64 {
        self.suffix[split_after]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> DelayProfile {
        DelayProfile::from_cloud_times(vec![1e-3, 2e-3, 4e-3], 5e-4, 10.0)
    }

    #[test]
    fn gamma_scaling() {
        let p = profile();
        assert_eq!(p.t_edge, vec![1e-2, 2e-2, 4e-2]);
        assert_eq!(p.branch_t_edge, 5e-3);
        let q = p.with_gamma(100.0);
        assert!((q.t_edge[0] - 0.1).abs() < 1e-12);
        assert!((q.branch_t_edge - 5e-2).abs() < 1e-12);
        assert_eq!(q.t_cloud, p.t_cloud); // cloud unchanged
    }

    #[test]
    fn prefix_suffix_sums() {
        let p = profile();
        assert!((p.cloud_suffix(0) - 7e-3).abs() < 1e-12);
        assert!((p.cloud_suffix(2) - 4e-3).abs() < 1e-12);
        assert_eq!(p.cloud_suffix(3), 0.0);
        assert_eq!(p.edge_prefix(0), 0.0);
        assert!((p.edge_prefix(3) - 7e-2).abs() < 1e-12);

        let cs = CloudSuffix::new(&p);
        for s in 0..=3 {
            assert!((cs.from_split(s) - p.cloud_suffix(s)).abs() < 1e-15);
        }
    }

    #[test]
    fn validation() {
        profile().validate(3).unwrap();
        assert!(profile().validate(4).is_err());
        let mut p = profile();
        p.t_edge[1] = f64::NAN;
        assert!(p.validate(3).is_err());
    }

    #[test]
    #[should_panic]
    fn gamma_below_one_panics() {
        DelayProfile::from_cloud_times(vec![1e-3], 0.0, 0.5);
    }
}
