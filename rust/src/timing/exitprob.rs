//! Exit-probability chain — the paper's Eq. 4.
//!
//! For side branches b_1..b_m with *conditional* exit probabilities p_k
//! (P[exit at b_k | reached b_k]), the unconditional probability of
//! exiting at b_k is
//!
//! ```text
//! p_Y(k) = p_k * prod_{i<k} (1 - p_i)
//! ```
//!
//! and the survival probability past the first j branches is
//! S_j = prod_{i<=j} (1 - p_i). These weight the edge/cloud/transfer
//! delays in Eq. 5 and the link weights in G'_BDNN (Eq. 8).

use crate::model::BranchyNetDesc;

/// Survival/exit probabilities for a BranchyNet description.
#[derive(Debug, Clone)]
pub struct ExitChain {
    /// Branch positions (1-based stage index each branch follows), sorted.
    positions: Vec<usize>,
    /// Conditional exit probability of each branch.
    cond: Vec<f64>,
    /// survival[j] = P[sample not classified by the first j branches].
    /// survival[0] = 1.
    survival: Vec<f64>,
}

impl ExitChain {
    pub fn new(desc: &BranchyNetDesc) -> ExitChain {
        let mut branches: Vec<(usize, f64)> = desc
            .branches
            .iter()
            .map(|b| (b.after_stage, b.exit_prob))
            .collect();
        branches.sort_by_key(|&(pos, _)| pos);
        let positions: Vec<usize> = branches.iter().map(|&(p, _)| p).collect();
        let cond: Vec<f64> = branches.iter().map(|&(_, p)| p).collect();
        let mut survival = Vec::with_capacity(cond.len() + 1);
        survival.push(1.0);
        for &p in &cond {
            let last = *survival.last().unwrap();
            survival.push(last * (1.0 - p));
        }
        ExitChain {
            positions,
            cond,
            survival,
        }
    }

    pub fn num_branches(&self) -> usize {
        self.positions.len()
    }

    pub fn positions(&self) -> &[usize] {
        &self.positions
    }

    /// Unconditional exit probability at the j-th branch (0-based) — Eq. 4.
    pub fn exit_prob(&self, j: usize) -> f64 {
        self.survival[j] * self.cond[j]
    }

    /// P[not exited at any of the first j branches] (S_j; j may be m).
    pub fn survival_after(&self, j: usize) -> f64 {
        self.survival[j]
    }

    /// Survival probability at the input of stage `i` (1-based): the
    /// product over branches strictly before stage i (position < i).
    pub fn survival_before_stage(&self, i: usize) -> f64 {
        let j = self.positions.partition_point(|&pos| pos < i);
        self.survival[j]
    }

    /// Survival probability relevant to a cut after stage `s`: branches
    /// with position < s are active (paper §IV-B: B = {b_1..b_{s-1}};
    /// a branch exactly at the cut is discarded).
    pub fn survival_at_split(&self, s: usize) -> f64 {
        let j = self.positions.partition_point(|&pos| pos < s);
        self.survival[j]
    }

    /// Number of active branches for a split after stage `s`.
    pub fn active_branches(&self, s: usize) -> usize {
        self.positions.partition_point(|&pos| pos < s)
    }

    /// Total exit probability over all branches (must be <= 1).
    pub fn total_exit_prob(&self) -> f64 {
        1.0 - self.survival[self.num_branches()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BranchDesc, BranchyNetDesc};

    fn desc(branches: Vec<(usize, f64)>) -> BranchyNetDesc {
        BranchyNetDesc {
            stage_names: (1..=6).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![10; 6],
            input_bytes: 10,
            branches: branches
                .into_iter()
                .map(|(after_stage, exit_prob)| BranchDesc {
                    after_stage,
                    exit_prob,
                })
                .collect(),
        }
    }

    #[test]
    fn eq4_matches_hand_computation() {
        // p = (0.5, 0.4, 0.1) at stages 1, 3, 4.
        let c = ExitChain::new(&desc(vec![(1, 0.5), (3, 0.4), (4, 0.1)]));
        assert!((c.exit_prob(0) - 0.5).abs() < 1e-12);
        assert!((c.exit_prob(1) - 0.5 * 0.4).abs() < 1e-12);
        assert!((c.exit_prob(2) - 0.5 * 0.6 * 0.1).abs() < 1e-12);
        // Exit probs + final survival sum to 1.
        let total: f64 = (0..3).map(|j| c.exit_prob(j)).sum::<f64>() + c.survival_after(3);
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn survival_before_stage_boundaries() {
        let c = ExitChain::new(&desc(vec![(2, 0.5)]));
        // Branch after stage 2: stages 1,2 see survival 1; stage 3+ sees 0.5.
        assert_eq!(c.survival_before_stage(1), 1.0);
        assert_eq!(c.survival_before_stage(2), 1.0);
        assert_eq!(c.survival_before_stage(3), 0.5);
        assert_eq!(c.survival_before_stage(6), 0.5);
    }

    #[test]
    fn split_at_branch_position_discards_that_branch() {
        // Paper: B = {b_1..b_{s-1}} — a branch exactly at the cut point
        // is not processed on the edge.
        let c = ExitChain::new(&desc(vec![(2, 0.5)]));
        assert_eq!(c.survival_at_split(2), 1.0); // cut after stage 2: b@2 inactive
        assert_eq!(c.survival_at_split(3), 0.5); // cut after stage 3: b@2 active
        assert_eq!(c.active_branches(2), 0);
        assert_eq!(c.active_branches(3), 1);
    }

    #[test]
    fn unsorted_branches_are_sorted() {
        let c = ExitChain::new(&desc(vec![(4, 0.1), (1, 0.5)]));
        assert_eq!(c.positions(), &[1, 4]);
        assert!((c.exit_prob(1) - 0.5 * 0.1).abs() < 1e-12);
    }

    #[test]
    fn extreme_probabilities() {
        let c = ExitChain::new(&desc(vec![(1, 1.0), (2, 0.7)]));
        assert_eq!(c.exit_prob(0), 1.0);
        assert_eq!(c.exit_prob(1), 0.0); // nothing survives past b1
        assert_eq!(c.survival_after(2), 0.0);
        assert!((c.total_exit_prob() - 1.0).abs() < 1e-12);

        let c = ExitChain::new(&desc(vec![(1, 0.0)]));
        assert_eq!(c.total_exit_prob(), 0.0);
        assert_eq!(c.survival_at_split(5), 1.0);
    }

    #[test]
    fn no_branches() {
        let c = ExitChain::new(&desc(vec![]));
        assert_eq!(c.num_branches(), 0);
        assert_eq!(c.survival_before_stage(3), 1.0);
        assert_eq!(c.total_exit_prob(), 0.0);
    }
}
