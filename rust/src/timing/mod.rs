//! The paper's inference-time model (§IV):
//!
//! * [`profile`] — the `(t_i^e, t_i^c)` delay vectors (Eq. 1–2 inputs);
//! * [`exitprob`] — the exit-probability chain `p_Y(k)` (Eq. 4);
//! * [`estimate`] — closed-form expected inference time `E[T_inf(s)]`
//!   for every split point (Eq. 3, 5, 6), generalized to any number of
//!   side branches.
//!
//! The estimator is the single source of truth for "what does a partition
//! cost": the brute-force baseline evaluates it directly, and the
//! G'_BDNN shortest-path construction (`partition::gprime`) is proven
//! equivalent to it by property tests.

pub mod estimate;
pub mod exitprob;
pub mod montecarlo;
pub mod profile;

pub use estimate::Estimator;
pub use profile::DelayProfile;
