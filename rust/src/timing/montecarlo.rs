//! Monte-Carlo validation of the closed-form expectation (Eq. 5/6).
//!
//! The estimator's algebra is easy to get subtly wrong (survival factors,
//! the discarded-branch-at-the-cut rule, branch-cost accounting), so this
//! module simulates the *per-sample stochastic process the model
//! describes* — walk the edge stages, draw a Bernoulli exit at each
//! active branch, pay transfer + cloud only on survival — and checks that
//! the sample mean converges to `Estimator::expected_time`. It also
//! yields the latency *distribution* (variance, quantiles), which the
//! closed form does not provide and the serving SLO analysis wants.

use crate::model::BranchyNetDesc;
use crate::network::bandwidth::LinkModel;
use crate::timing::profile::DelayProfile;
use crate::util::rng::Pcg32;
use crate::util::stats::Welford;

/// Simulation result for one split point.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub split_after: usize,
    pub samples: u64,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    /// Fraction of samples that exited at some side branch.
    pub exit_fraction: f64,
}

/// Simulate `samples` independent inferences through split `split_after`.
///
/// `include_branch_cost` mirrors the estimator's mode. Deterministic in
/// `seed`.
pub fn simulate(
    desc: &BranchyNetDesc,
    profile: &DelayProfile,
    link: LinkModel,
    split_after: usize,
    include_branch_cost: bool,
    samples: u64,
    seed: u64,
) -> SimResult {
    desc.validate().expect("invalid desc");
    profile
        .validate(desc.num_stages())
        .expect("profile mismatch");
    let n = desc.num_stages();
    assert!(split_after <= n);

    // Sorted active branches (position < split, per §IV-B).
    let mut branches: Vec<(usize, f64)> = desc
        .branches
        .iter()
        .filter(|b| b.after_stage < split_after)
        .map(|b| (b.after_stage, b.exit_prob))
        .collect();
    branches.sort_by_key(|&(pos, _)| pos);

    let cloud_suffix: f64 = profile.t_cloud[split_after..].iter().sum();
    let transfer = if split_after < n {
        link.transfer_time(desc.transfer_bytes(split_after))
    } else {
        0.0
    };

    let mut rng = Pcg32::seeded(seed);
    let mut acc = Welford::new();
    let mut exits = 0u64;

    for _ in 0..samples {
        let mut t = 0.0;
        let mut exited = false;
        let mut b_iter = branches.iter().peekable();
        for i in 1..=split_after {
            t += profile.t_edge[i - 1];
            if let Some(&&(pos, p)) = b_iter.peek() {
                if pos == i {
                    b_iter.next();
                    if include_branch_cost {
                        t += profile.branch_t_edge;
                    }
                    if rng.bool(p) {
                        exited = true;
                        break;
                    }
                }
            }
        }
        if !exited && split_after < n {
            t += transfer + cloud_suffix;
        }
        if exited {
            exits += 1;
        }
        acc.push(t);
    }

    SimResult {
        split_after,
        samples,
        mean_s: acc.mean(),
        std_s: acc.stddev(),
        min_s: acc.min(),
        max_s: acc.max(),
        exit_fraction: exits as f64 / samples as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic;
    use crate::testing::property;
    use crate::timing::Estimator;

    #[test]
    fn sample_mean_converges_to_closed_form() {
        property("Monte Carlo == Eq. 5/6", 40, |g| {
            let n = g.usize_in(1, 12);
            let desc = synthetic::random_desc(g, n, 3);
            let gamma = g.f64_in(1.0, 200.0);
            let profile = synthetic::random_profile(g, &desc, gamma);
            let link = LinkModel::new(g.f64_in(0.1, 50.0), 0.0);
            let split = g.usize_in(0, n);
            let branch_cost = g.bool(0.5);

            let est = Estimator::new(&desc, &profile, link);
            let est = if branch_cost { est } else { est.paper_mode() };
            let want = est.expected_time(split);

            let sim = simulate(&desc, &profile, link, split, branch_cost, 40_000, g.u64());
            // 40k samples: allow 5 sigma-of-the-mean plus tiny abs slack.
            let tol = 5.0 * sim.std_s / (sim.samples as f64).sqrt() + 1e-12;
            assert!(
                (sim.mean_s - want).abs() <= tol.max(1e-9 * want.abs()),
                "split {split}: sim {} vs closed form {want} (tol {tol})",
                sim.mean_s
            );
        });
    }

    #[test]
    fn exit_fraction_matches_total_exit_probability() {
        property("exit fraction == 1 - survival", 30, |g| {
            let n = g.usize_in(2, 12);
            let desc = synthetic::random_desc(g, n, 3);
            let profile = synthetic::random_profile(g, &desc, 10.0);
            let link = LinkModel::new(1.0, 0.0);
            let split = g.usize_in(0, n);
            let est = Estimator::new(&desc, &profile, link);
            let want = 1.0 - est.exit_chain().survival_at_split(split);
            let sim = simulate(&desc, &profile, link, split, false, 30_000, g.u64());
            assert!(
                (sim.exit_fraction - want).abs() < 0.02,
                "split {split}: simulated {} vs analytic {want}",
                sim.exit_fraction
            );
        });
    }

    #[test]
    fn deterministic_cases_have_zero_variance() {
        let mut g = crate::testing::Gen::replay(2);
        let mut desc = synthetic::random_desc(&mut g, 5, 1);
        // No active branch -> every sample takes the identical path.
        desc.branches.clear();
        let profile = synthetic::random_profile(&mut g, &desc, 10.0);
        let link = LinkModel::new(1.0, 0.0);
        let sim = simulate(&desc, &profile, link, 3, false, 1000, 7);
        assert_eq!(sim.std_s, 0.0);
        assert_eq!(sim.exit_fraction, 0.0);
    }

    #[test]
    fn variance_peaks_at_intermediate_probability() {
        // With one branch, latency is a two-point distribution; its
        // variance p(1-p)*gap^2 is maximal at p = 0.5.
        let mut g = crate::testing::Gen::replay(3);
        let base = synthetic::random_desc(&mut g, 6, 0);
        let profile = synthetic::random_profile(&mut g, &base, 10.0);
        let link = LinkModel::new(1.0, 0.0);
        let mut stds = Vec::new();
        for p in [0.05, 0.5, 0.95] {
            let mut desc = base.clone();
            desc.branches = vec![crate::model::BranchDesc {
                after_stage: 2,
                exit_prob: p,
            }];
            let sim = simulate(&desc, &profile, link, 6, false, 50_000, 11);
            stds.push(sim.std_s);
        }
        assert!(stds[1] > stds[0] && stds[1] > stds[2], "{stds:?}");
    }
}
