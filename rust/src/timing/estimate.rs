//! Closed-form expected inference time — Eqs. 3, 5 and 6 of the paper,
//! generalized from one side branch to any number of them.
//!
//! For a split after stage `s` (s = 0: cloud-only; s = N: edge-only),
//! with active branches (position k < s, per §IV-B) and survival
//! probabilities S(.) from [`super::exitprob::ExitChain`]:
//!
//! ```text
//! E[T(s)] =   sum_{i=1..s}  S(before stage i) * t_i^e        edge compute
//!           [+ sum_{active j} S(before branch j) * t_b^e]    branch compute*
//!           + S(split s) * ( t_net(alpha_s) + sum_{i>s} t_i^c )
//! ```
//!
//! *the bracketed branch-compute term is optional: the paper's Eq. 5
//! omits it (branch cost folded into nothing), so `paper_mode()` — used
//! by the Fig. 4/5 reproductions — disables it, while the serving planner
//! enables it. With a single branch at k and the term disabled this is
//! exactly Eq. 5; with p = 0 it degenerates to Eq. 3 (plain DNN); with
//! s <= k it is Eq. 3 via "branch inactive" (Eq. 6's case split).

use crate::model::BranchyNetDesc;
use crate::network::bandwidth::LinkModel;
use crate::network::encoding::WireEncoding;

use super::exitprob::ExitChain;
use super::profile::{CloudSuffix, DelayProfile};

/// Expected-inference-time evaluator for one (network, profile, desc)
/// triple. Construction is O(N); each `expected_time` query is O(s).
///
/// The transfer term charges `alpha_s` *as it crosses the wire*:
/// [`BranchyNetDesc::transfer_wire_bytes`] under the configured
/// [`WireEncoding`] (raw by default — bit-identical to the pre-encoding
/// estimator). See [`Estimator::with_encoding`].
#[derive(Debug)]
pub struct Estimator<'a> {
    desc: &'a BranchyNetDesc,
    profile: &'a DelayProfile,
    link: LinkModel,
    chain: ExitChain,
    cloud_suffix: CloudSuffix,
    include_branch_cost: bool,
    encoding: WireEncoding,
}

impl<'a> Estimator<'a> {
    pub fn new(
        desc: &'a BranchyNetDesc,
        profile: &'a DelayProfile,
        link: LinkModel,
    ) -> Estimator<'a> {
        desc.validate().expect("invalid BranchyNet description");
        profile
            .validate(desc.num_stages())
            .expect("profile/desc mismatch");
        Estimator {
            desc,
            profile,
            link,
            chain: ExitChain::new(desc),
            cloud_suffix: CloudSuffix::new(profile),
            include_branch_cost: true,
            encoding: WireEncoding::Raw,
        }
    }

    /// Reproduce the paper's Eq. 5 exactly: side-branch evaluation itself
    /// costs nothing.
    pub fn paper_mode(mut self) -> Estimator<'a> {
        self.include_branch_cost = false;
        self
    }

    /// Price the activation transfer under `encoding`: every alpha in
    /// the cost model becomes
    /// [`BranchyNetDesc::transfer_wire_bytes`]`(s, encoding)` — the
    /// exact size the codec puts on the wire, so the optimum this
    /// estimator (and every solver built on it) reports is the optimum
    /// of the deployment actually shipping that encoding.
    pub fn with_encoding(mut self, encoding: WireEncoding) -> Estimator<'a> {
        self.encoding = encoding;
        self
    }

    /// The wire encoding the transfer term is priced at.
    pub fn encoding(&self) -> WireEncoding {
        self.encoding
    }

    pub fn exit_chain(&self) -> &ExitChain {
        &self.chain
    }

    pub fn desc(&self) -> &BranchyNetDesc {
        self.desc
    }

    pub fn num_splits(&self) -> usize {
        self.desc.num_stages() + 1
    }

    /// `E[T_inf]` for a split after stage `split` (0..=N).
    pub fn expected_time(&self, split: usize) -> f64 {
        let n = self.desc.num_stages();
        assert!(split <= n, "split {split} out of range 0..={n}");

        // Edge compute, survival-weighted per stage.
        let mut t = 0.0;
        for i in 1..=split {
            t += self.chain.survival_before_stage(i) * self.profile.t_edge[i - 1];
        }
        // Branch compute (optional; active branches only: position < split).
        if self.include_branch_cost {
            for (j, &pos) in self.chain.positions().iter().enumerate() {
                if pos < split {
                    t += self.chain.survival_after(j) * self.profile.branch_t_edge;
                }
            }
        }
        // Transfer + cloud, weighted by the survival at the cut.
        if split < n {
            let surv = self.chain.survival_at_split(split);
            if surv > 0.0 {
                let alpha = self.desc.transfer_wire_bytes(split, self.encoding);
                t += surv
                    * (self.link.transfer_time(alpha) + self.cloud_suffix.from_split(split));
            }
        }
        t
    }

    /// Eq. 3: inference time if the network had no branches (p = 0).
    pub fn plain_dnn_time(&self, split: usize) -> f64 {
        let n = self.desc.num_stages();
        assert!(split <= n);
        let mut t = self.profile.edge_prefix(split);
        if split < n {
            t += self
                .link
                .transfer_time(self.desc.transfer_wire_bytes(split, self.encoding))
                + self.cloud_suffix.from_split(split);
        }
        t
    }

    pub fn cloud_only_time(&self) -> f64 {
        self.expected_time(0)
    }

    pub fn edge_only_time(&self) -> f64 {
        self.expected_time(self.desc.num_stages())
    }

    /// All split costs (index = split-after value).
    pub fn all_times(&self) -> Vec<f64> {
        (0..self.num_splits()).map(|s| self.expected_time(s)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BranchDesc, BranchyNetDesc};

    /// 3 stages, one branch after stage 1 — the paper's Fig. 3 example.
    fn desc(p: f64) -> BranchyNetDesc {
        BranchyNetDesc {
            stage_names: vec!["v1".into(), "v2".into(), "v3".into()],
            stage_out_bytes: vec![1000, 500, 8],
            input_bytes: 800,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: p,
            }],
        }
    }

    fn profile() -> DelayProfile {
        DelayProfile::from_cloud_times(vec![1e-3, 2e-3, 3e-3], 4e-4, 10.0)
    }

    fn link() -> LinkModel {
        LinkModel::new(8.0, 0.0) // 1 byte = 1 us
    }

    #[test]
    fn cloud_only_is_eq3() {
        let d = desc(0.7);
        let p = profile();
        let e = Estimator::new(&d, &p, link()).paper_mode();
        // No edge stages -> branch never runs; upload raw input.
        let want = 800.0 * 8.0 / 8e6 + (1e-3 + 2e-3 + 3e-3);
        assert!((e.expected_time(0) - want).abs() < 1e-12);
        assert_eq!(e.expected_time(0), e.cloud_only_time());
    }

    #[test]
    fn split_at_branch_position_has_no_exit_effect() {
        // s = 1 and branch at k = 1: branch discarded (Eq. 6 first case).
        let d = desc(0.9);
        let p = profile();
        let e = Estimator::new(&d, &p, link()).paper_mode();
        let want = 1e-2 + 1000.0 * 8.0 / 8e6 + (2e-3 + 3e-3);
        assert!((e.expected_time(1) - want).abs() < 1e-12);
        // ... identical to the p = 0 network at this split:
        let d0 = desc(0.0);
        let e0 = Estimator::new(&d0, &p, link()).paper_mode();
        assert!((e.expected_time(1) - e0.expected_time(1)).abs() < 1e-15);
    }

    #[test]
    fn eq5_hand_computed_split2() {
        // s = 2, branch at 1 active with p = 0.5:
        //   t1_e + 0.5 * t2_e + 0.5 * (t_net(alpha_2) + t3_c)
        let d = desc(0.5);
        let p = profile();
        let e = Estimator::new(&d, &p, link()).paper_mode();
        let want = 1e-2 + 0.5 * 2e-2 + 0.5 * (500.0 * 8.0 / 8e6 + 3e-3);
        assert!((e.expected_time(2) - want).abs() < 1e-12, "{}", e.expected_time(2));
    }

    #[test]
    fn p_zero_reduces_to_plain_dnn_everywhere() {
        let d = desc(0.0);
        let p = profile();
        let e = Estimator::new(&d, &p, link()).paper_mode();
        for s in 0..=3 {
            assert!(
                (e.expected_time(s) - e.plain_dnn_time(s)).abs() < 1e-15,
                "split {s}"
            );
        }
    }

    #[test]
    fn p_one_pays_nothing_after_branch() {
        let d = desc(1.0);
        let p = profile();
        let e = Estimator::new(&d, &p, link()).paper_mode();
        // s = 3 (edge-only): t1_e + 1.0*t2_e*0 ... stage 2,3 never run.
        assert!((e.expected_time(3) - 1e-2).abs() < 1e-12);
        // s = 2: transfer and cloud are never paid either.
        assert!((e.expected_time(2) - 1e-2).abs() < 1e-12);
    }

    #[test]
    fn branch_cost_mode_adds_weighted_branch_time() {
        let d = desc(0.5);
        let p = profile();
        let paper = Estimator::new(&d, &p, link()).paper_mode();
        let real = Estimator::new(&d, &p, link());
        // Branch active only for splits >= 2; its cost is t_b^e * S(before b) = 4e-3 * 1.
        assert!((real.expected_time(1) - paper.expected_time(1)).abs() < 1e-15);
        assert!(
            (real.expected_time(2) - paper.expected_time(2) - 4e-3).abs() < 1e-12
        );
        assert!(
            (real.expected_time(3) - paper.expected_time(3) - 4e-3).abs() < 1e-12
        );
    }

    #[test]
    fn probability_monotonicity() {
        // For any fixed split past the branch, higher exit probability
        // can only reduce expected time (less downstream work).
        let p = profile();
        let l = link();
        let mut prev = f64::INFINITY;
        for q in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let d = desc(q);
            let e = Estimator::new(&d, &p, l).paper_mode();
            let t = e.expected_time(2);
            assert!(t <= prev + 1e-15, "p={q}");
            prev = t;
        }
    }

    #[test]
    fn all_times_shape() {
        let d = desc(0.3);
        let p = profile();
        let e = Estimator::new(&d, &p, link());
        let ts = e.all_times();
        assert_eq!(ts.len(), 4);
        assert!(ts.iter().all(|t| t.is_finite() && *t >= 0.0));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn split_out_of_range_panics() {
        let d = desc(0.3);
        let p = profile();
        Estimator::new(&d, &p, link()).expected_time(4);
    }
}
