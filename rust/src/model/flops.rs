//! Analytic cost model: planning-time estimates of per-stage processing
//! time when no measured profile is available, from FLOPs and an assumed
//! sustained throughput. The profiler's measured `t_c` supersedes this;
//! benches compare the two (ablation: analytic vs measured planning).

use super::manifest::Manifest;
use crate::timing::profile::DelayProfile;

/// Sustained FLOP/s assumption for the "cloud" device when estimating
/// analytically. The default is deliberately modest (CPU-class, matching
/// this testbed); the paper's model only needs *relative* layer times.
pub const DEFAULT_CLOUD_FLOPS: f64 = 5e9;

/// Build a [`DelayProfile`] from the manifest's analytic FLOPs.
///
/// `cloud_flops` — assumed sustained FLOP/s of the cloud device;
/// `gamma` — the paper's edge/cloud slowdown factor (t_e = gamma * t_c).
pub fn analytic_profile(m: &Manifest, cloud_flops: f64, gamma: f64) -> DelayProfile {
    assert!(cloud_flops > 0.0 && gamma >= 1.0);
    let t_c: Vec<f64> = m
        .stages
        .iter()
        .map(|s| s.flops_per_sample as f64 / cloud_flops)
        .collect();
    let branch_t_c = m.branch.flops_per_sample as f64 / cloud_flops;
    DelayProfile::from_cloud_times(t_c, branch_t_c, gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json::Json;
    use std::path::Path;

    fn manifest() -> Manifest {
        let doc = Json::parse(crate::model::manifest::tests::SAMPLE).unwrap();
        Manifest::from_json(Path::new("/tmp"), &doc).unwrap()
    }

    #[test]
    fn analytic_times_scale_with_flops() {
        let m = manifest();
        let p = analytic_profile(&m, 1e9, 10.0);
        // Sample manifest: stage flops 1000 and 10.
        assert!((p.t_cloud[0] - 1e-6).abs() < 1e-12);
        assert!((p.t_cloud[1] - 1e-8).abs() < 1e-14);
        assert!((p.t_edge[0] - 1e-5).abs() < 1e-11);
        assert!((p.branch_t_edge - 5e-7).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive_flops_rate() {
        analytic_profile(&manifest(), 0.0, 10.0);
    }
}
