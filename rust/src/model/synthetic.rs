//! Synthetic BranchyNet generators for property tests and solver
//! benchmarks: random chains of arbitrary depth with random side-branch
//! placements, output-size profiles and delay profiles.
//!
//! These let the optimality tests cross-check the shortest-path solver
//! against brute force on thousands of networks far from B-AlexNet's
//! shape, and let the solver bench scale to 10^4-layer chains.

use super::{BranchDesc, BranchyNetDesc};
use crate::testing::Gen;
use crate::timing::profile::DelayProfile;
use crate::util::rng::Pcg32;

/// A random BranchyNet description with `n_stages` stages and up to
/// `max_branches` side branches at random positions.
pub fn random_desc(g: &mut Gen, n_stages: usize, max_branches: usize) -> BranchyNetDesc {
    assert!(n_stages >= 1);
    let stage_names: Vec<String> = (1..=n_stages).map(|i| format!("s{i}")).collect();
    let stage_out_bytes: Vec<u64> = (0..n_stages)
        .map(|_| g.usize_in(1, 1 << 20) as u64)
        .collect();
    let input_bytes = g.usize_in(1, 1 << 20) as u64;

    let mut positions: Vec<usize> = (1..n_stages).collect();
    // Shuffle and take a prefix as branch positions.
    let n_branches = if n_stages <= 1 {
        0
    } else {
        g.usize_in(0, max_branches.min(n_stages - 1))
    };
    for i in (1..positions.len()).rev() {
        let j = g.usize_in(0, i);
        positions.swap(i, j);
    }
    let mut branches: Vec<BranchDesc> = positions[..n_branches]
        .iter()
        .map(|&after_stage| BranchDesc {
            after_stage,
            exit_prob: g.probability(),
        })
        .collect();
    branches.sort_by_key(|b| b.after_stage);

    let desc = BranchyNetDesc {
        stage_names,
        stage_out_bytes,
        input_bytes,
        branches,
    };
    desc.validate().expect("generator must produce valid descs");
    desc
}

/// A random delay profile matching `desc` (cloud times in [1us, 10ms],
/// edge = gamma * cloud).
pub fn random_profile(g: &mut Gen, desc: &BranchyNetDesc, gamma: f64) -> DelayProfile {
    let t_c: Vec<f64> = (0..desc.num_stages())
        .map(|_| g.f64_in(1e-6, 1e-2))
        .collect();
    let branch_t_c = g.f64_in(1e-7, 1e-3);
    DelayProfile::from_cloud_times(t_c, branch_t_c, gamma)
}

/// Deterministic deep chain for benchmarks: `n` stages, branches every
/// `branch_every` stages with the given conditional exit probability.
pub fn deep_chain(n: usize, branch_every: usize, exit_prob: f64, seed: u64) -> (BranchyNetDesc, DelayProfile) {
    let mut rng = Pcg32::seeded(seed);
    let stage_names = (1..=n).map(|i| format!("s{i}")).collect();
    let stage_out_bytes = (0..n).map(|_| rng.range_u64(64, 1 << 18)).collect();
    let branches = (1..n)
        .filter(|i| branch_every > 0 && i % branch_every == 0)
        .map(|after_stage| BranchDesc {
            after_stage,
            exit_prob,
        })
        .collect();
    let desc = BranchyNetDesc {
        stage_names,
        stage_out_bytes,
        input_bytes: 12_288,
        branches,
    };
    desc.validate().unwrap();
    let t_c: Vec<f64> = (0..n).map(|_| rng.range_f64(1e-5, 1e-3)).collect();
    let profile = DelayProfile::from_cloud_times(t_c, 1e-5, 100.0);
    (desc, profile)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_descs_are_valid() {
        crate::testing::property("random descs validate", 100, |g| {
            let n = g.usize_in(1, 40);
            let desc = random_desc(g, n, 5);
            desc.validate().unwrap();
            let profile = random_profile(g, &desc, 10.0);
            profile.validate(desc.num_stages()).unwrap();
        });
    }

    #[test]
    fn deep_chain_shape() {
        let (desc, profile) = deep_chain(100, 10, 0.3, 1);
        assert_eq!(desc.num_stages(), 100);
        assert_eq!(desc.branches.len(), 9); // 10, 20, ..., 90
        profile.validate(100).unwrap();
    }

    #[test]
    fn deep_chain_no_branches() {
        let (desc, _) = deep_chain(10, 0, 0.3, 2);
        assert!(desc.branches.is_empty());
    }
}
