//! `artifacts/manifest.json` binding — the bridge between the Python AOT
//! exporter and the Rust runtime/planner.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::{BranchDesc, BranchyNetDesc};
use crate::config::json::Json;
use crate::config::settings::Flavor;

/// One main-branch stage as exported.
#[derive(Debug, Clone)]
pub struct StageInfo {
    /// 1-based chain index.
    pub index: usize,
    pub name: String,
    /// "conv" or "fc".
    pub kind: String,
    /// Per-sample shapes (no batch dim).
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub out_bytes_per_sample: u64,
    pub flops_per_sample: u64,
    /// artifact file name per (flavor, batch size).
    artifacts: Json,
}

impl StageInfo {
    pub fn artifact(&self, flavor: Flavor, batch: usize) -> Result<&str> {
        artifact_lookup(&self.artifacts, flavor, batch)
            .ok_or_else(|| anyhow!("stage {} has no artifact for {flavor:?} b{batch}", self.name))
    }
}

#[derive(Debug, Clone)]
pub struct BranchInfo {
    /// 1-based main-branch stage the branch consumes the output of.
    pub after_stage: usize,
    pub name: String,
    pub in_shape: Vec<usize>,
    pub num_classes: usize,
    pub flops_per_sample: u64,
    artifacts: Json,
}

impl BranchInfo {
    pub fn artifact(&self, flavor: Flavor, batch: usize) -> Result<&str> {
        artifact_lookup(&self.artifacts, flavor, batch)
            .ok_or_else(|| anyhow!("branch {} has no artifact for {flavor:?} b{batch}", self.name))
    }
}

/// A named raw-f32 fixture file.
#[derive(Debug, Clone)]
pub struct FixtureInfo {
    pub path: PathBuf,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: String,
    pub num_classes: usize,
    pub input_shape: Vec<usize>,
    pub input_bytes_per_sample: u64,
    pub batch_sizes: Vec<usize>,
    pub entropy_max_nats: f64,
    pub stages: Vec<StageInfo>,
    pub branch: BranchInfo,
    full_artifacts: Json,
    fixtures: Json,
}

fn artifact_lookup<'a>(artifacts: &'a Json, flavor: Flavor, batch: usize) -> Option<&'a str> {
    artifacts
        .get(flavor.as_str())?
        .get(&batch.to_string())?
        .as_str()
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let doc = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(dir, &doc)
    }

    pub fn from_json(dir: &Path, doc: &Json) -> Result<Manifest> {
        let req_str = |key: &str| -> Result<String> {
            Ok(doc
                .path(key)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest missing string '{key}'"))?
                .to_string())
        };
        let req_u64 = |key: &str| -> Result<u64> {
            doc.path(key)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("manifest missing integer '{key}'"))
        };

        let stages_json = doc
            .get("stages")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'stages'"))?;
        let mut stages = Vec::with_capacity(stages_json.len());
        for (i, s) in stages_json.iter().enumerate() {
            let stage = StageInfo {
                index: s
                    .get("index")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("stage {i} missing index"))?,
                name: s
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("stage {i} missing name"))?
                    .to_string(),
                kind: s
                    .get("kind")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                in_shape: s
                    .get("in_shape")
                    .and_then(Json::as_usize_vec)
                    .ok_or_else(|| anyhow!("stage {i} missing in_shape"))?,
                out_shape: s
                    .get("out_shape")
                    .and_then(Json::as_usize_vec)
                    .ok_or_else(|| anyhow!("stage {i} missing out_shape"))?,
                out_bytes_per_sample: s
                    .get("out_bytes_per_sample")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow!("stage {i} missing out_bytes_per_sample"))?,
                flops_per_sample: s
                    .get("flops_per_sample")
                    .and_then(Json::as_u64)
                    .unwrap_or(0),
                artifacts: s
                    .get("artifacts")
                    .cloned()
                    .ok_or_else(|| anyhow!("stage {i} missing artifacts"))?,
            };
            if stage.index != i + 1 {
                bail!("stage {} has index {}, expected {}", stage.name, stage.index, i + 1);
            }
            stages.push(stage);
        }
        if stages.is_empty() {
            bail!("manifest has no stages");
        }
        // Chain consistency: in_shape[i] == out_shape[i-1].
        for w in stages.windows(2) {
            if w[1].in_shape != w[0].out_shape {
                bail!(
                    "stage chain broken: {} out {:?} != {} in {:?}",
                    w[0].name,
                    w[0].out_shape,
                    w[1].name,
                    w[1].in_shape
                );
            }
        }

        let b = doc
            .get("branch")
            .ok_or_else(|| anyhow!("manifest missing 'branch'"))?;
        let branch = BranchInfo {
            after_stage: b
                .get("after_stage")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("branch missing after_stage"))?,
            name: b
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("b1")
                .to_string(),
            in_shape: b
                .get("in_shape")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("branch missing in_shape"))?,
            num_classes: b
                .get("num_classes")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("branch missing num_classes"))?,
            flops_per_sample: b.get("flops_per_sample").and_then(Json::as_u64).unwrap_or(0),
            artifacts: b
                .get("artifacts")
                .cloned()
                .ok_or_else(|| anyhow!("branch missing artifacts"))?,
        };
        if branch.after_stage == 0 || branch.after_stage > stages.len() {
            bail!("branch after_stage {} out of range", branch.after_stage);
        }
        if branch.in_shape != stages[branch.after_stage - 1].out_shape {
            bail!("branch in_shape does not match its host stage output");
        }

        let m = Manifest {
            dir: dir.to_path_buf(),
            model: req_str("model")?,
            num_classes: req_u64("num_classes")? as usize,
            input_shape: doc
                .get("input_shape")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("manifest missing input_shape"))?,
            input_bytes_per_sample: req_u64("input_bytes_per_sample")?,
            batch_sizes: doc
                .get("batch_sizes")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("manifest missing batch_sizes"))?,
            entropy_max_nats: doc
                .path("entropy_max_nats")
                .and_then(Json::as_f64)
                .unwrap_or((2f64).ln()),
            stages,
            branch,
            full_artifacts: doc
                .path("full.artifacts")
                .cloned()
                .ok_or_else(|| anyhow!("manifest missing full.artifacts"))?,
            fixtures: doc.get("fixtures").cloned().unwrap_or(Json::Null),
        };
        Ok(m)
    }

    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    pub fn full_artifact(&self, flavor: Flavor, batch: usize) -> Result<&str> {
        artifact_lookup(&self.full_artifacts, flavor, batch)
            .ok_or_else(|| anyhow!("no full-model artifact for {flavor:?} b{batch}"))
    }

    /// Absolute path of an artifact file name.
    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    /// Named fixture (raw f32 file + shape).
    pub fn fixture(&self, key: &str) -> Result<FixtureInfo> {
        let f = self
            .fixtures
            .get(key)
            .ok_or_else(|| anyhow!("no fixture '{key}' in manifest"))?;
        Ok(FixtureInfo {
            path: self.dir.join("fixtures").join(
                f.get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("fixture '{key}' missing path"))?,
            ),
            shape: f
                .get("shape")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("fixture '{key}' missing shape"))?,
        })
    }

    /// Fig. 6 fixture for a blur level ("none" | "low" | "mid" | "high").
    pub fn fig6_fixture(&self, level: &str) -> Result<FixtureInfo> {
        let f = self
            .fixtures
            .path(&format!("fig6.{level}"))
            .ok_or_else(|| anyhow!("no fig6 fixture '{level}'"))?;
        Ok(FixtureInfo {
            path: self.dir.join("fixtures").join(
                f.get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("fig6 '{level}' missing path"))?,
            ),
            shape: f
                .get("shape")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("fig6 '{level}' missing shape"))?,
        })
    }

    pub fn fig6_labels(&self) -> Result<Vec<usize>> {
        self.fixtures
            .get("fig6_labels")
            .and_then(Json::as_usize_vec)
            .ok_or_else(|| anyhow!("no fig6_labels in manifest"))
    }

    /// Build an in-code manifest for the simulated runtime
    /// ([`crate::runtime::SimNet`]): a chain of flat stages with no
    /// on-disk artifacts. `stage_out_elems` gives each stage's flat
    /// output size; the last entry must equal `num_classes` so the final
    /// stage acts as the classifier head. Artifact lookups on the result
    /// error — only the sim backend can execute it.
    pub fn synthetic_sim(
        model: &str,
        input_shape: Vec<usize>,
        stage_out_elems: &[usize],
        branch_after: usize,
        num_classes: usize,
        batch_sizes: Vec<usize>,
    ) -> Result<Manifest> {
        if stage_out_elems.is_empty() {
            bail!("synthetic manifest needs at least one stage");
        }
        if stage_out_elems.iter().any(|&k| k == 0) {
            bail!("stage output sizes must be positive");
        }
        if num_classes < 2 {
            bail!("num_classes must be >= 2");
        }
        if *stage_out_elems.last().unwrap() != num_classes {
            bail!(
                "last stage must emit num_classes = {num_classes} values, got {}",
                stage_out_elems.last().unwrap()
            );
        }
        if branch_after == 0 || branch_after >= stage_out_elems.len() {
            bail!(
                "branch_after {branch_after} out of range 1..{}",
                stage_out_elems.len()
            );
        }
        if batch_sizes.is_empty() || batch_sizes.contains(&0) {
            bail!("batch_sizes must be non-empty and positive");
        }
        let input_elems: usize = input_shape.iter().product();
        if input_shape.is_empty() || input_elems == 0 {
            bail!("input_shape must have positive dimensions");
        }
        let mut stages = Vec::with_capacity(stage_out_elems.len());
        let mut in_shape = input_shape.clone();
        for (i, &k) in stage_out_elems.iter().enumerate() {
            let out_shape = vec![k];
            stages.push(StageInfo {
                index: i + 1,
                name: format!("sim{}", i + 1),
                kind: "sim".to_string(),
                in_shape: in_shape.clone(),
                out_shape: out_shape.clone(),
                out_bytes_per_sample: (k * 4) as u64,
                flops_per_sample: 0,
                artifacts: Json::Null,
            });
            in_shape = out_shape;
        }
        let branch = BranchInfo {
            after_stage: branch_after,
            name: "sim-b1".to_string(),
            in_shape: stages[branch_after - 1].out_shape.clone(),
            num_classes,
            flops_per_sample: 0,
            artifacts: Json::Null,
        };
        Ok(Manifest {
            dir: PathBuf::from("<sim>"),
            model: model.to_string(),
            num_classes,
            input_bytes_per_sample: (input_elems * 4) as u64,
            input_shape,
            batch_sizes,
            entropy_max_nats: (num_classes as f64).ln(),
            stages,
            branch,
            full_artifacts: Json::Null,
            fixtures: Json::Null,
        })
    }

    /// Abstract description for the partitioner, with a given conditional
    /// exit probability for the (single) side branch. Thin wrapper over
    /// [`Manifest::to_desc_with_probs`].
    pub fn to_desc(&self, exit_prob: f64) -> BranchyNetDesc {
        // The arity always matches (one branch, one p), so the only
        // reachable failure here is an out-of-range probability.
        self.to_desc_with_probs(&[exit_prob])
            .unwrap_or_else(|e| panic!("to_desc({exit_prob}): {e}"))
    }

    /// [`Manifest::to_desc`] generalized to per-branch conditional exit
    /// probabilities, one per side branch in branch-position order —
    /// the slice shape `Planner::with_exit_probs` consumes. Today's
    /// manifests carry exactly one branch, so `probs.len()` must be 1;
    /// the signature is the stable seam for multi-branch manifests.
    pub fn to_desc_with_probs(&self, probs: &[f64]) -> anyhow::Result<BranchyNetDesc> {
        // One BranchInfo per manifest for now; written as a slice so the
        // check generalizes when the manifest grows more branches.
        let branch_positions = [self.branch.after_stage];
        if probs.len() != branch_positions.len() {
            anyhow::bail!(
                "manifest has {} branch(es) but {} exit probabilities were given",
                branch_positions.len(),
                probs.len()
            );
        }
        for &p in probs {
            if !(0.0..=1.0).contains(&p) {
                anyhow::bail!("exit probability {p} not in [0, 1]");
            }
        }
        Ok(BranchyNetDesc {
            stage_names: self.stages.iter().map(|s| s.name.clone()).collect(),
            stage_out_bytes: self.stages.iter().map(|s| s.out_bytes_per_sample).collect(),
            input_bytes: self.input_bytes_per_sample,
            branches: branch_positions
                .iter()
                .zip(probs)
                .map(|(&after_stage, &exit_prob)| BranchDesc {
                    after_stage,
                    exit_prob,
                })
                .collect(),
        })
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) const SAMPLE: &str = r#"{
      "model": "b-alexnet",
      "num_classes": 2,
      "input_shape": [3, 32, 32],
      "input_bytes_per_sample": 12288,
      "batch_sizes": [1, 8],
      "entropy_max_nats": 0.6931471805599453,
      "stages": [
        {"index": 1, "name": "conv1", "kind": "conv",
         "in_shape": [3,32,32], "out_shape": [64,15,15],
         "out_bytes_per_sample": 57600, "flops_per_sample": 1000,
         "artifacts": {"pl": {"1": "s1_pl_b1.hlo.txt", "8": "s1_pl_b8.hlo.txt"},
                        "ref": {"1": "s1_ref_b1.hlo.txt", "8": "s1_ref_b8.hlo.txt"}}},
        {"index": 2, "name": "fc_out", "kind": "fc",
         "in_shape": [64,15,15], "out_shape": [2],
         "out_bytes_per_sample": 8, "flops_per_sample": 10,
         "artifacts": {"pl": {"1": "s2_pl_b1.hlo.txt", "8": "s2_pl_b8.hlo.txt"},
                        "ref": {"1": "s2_ref_b1.hlo.txt", "8": "s2_ref_b8.hlo.txt"}}}
      ],
      "branch": {"after_stage": 1, "name": "b1", "in_shape": [64,15,15],
                 "num_classes": 2, "flops_per_sample": 50,
                 "artifacts": {"pl": {"1": "b_pl_b1.hlo.txt", "8": "b_pl_b8.hlo.txt"},
                               "ref": {"1": "b_ref_b1.hlo.txt", "8": "b_ref_b8.hlo.txt"}}},
      "full": {"artifacts": {"ref": {"1": "full_ref_b1.hlo.txt"}}},
      "fixtures": {
        "input_b8": {"path": "input_b8.bin", "shape": [8,3,32,32]},
        "fig6": {"none": {"path": "fig6_none_b48.bin", "shape": [48,3,32,32]}},
        "fig6_labels": [0, 1]
      }
    }"#;

    fn sample() -> Manifest {
        let doc = Json::parse(SAMPLE).unwrap();
        Manifest::from_json(Path::new("/tmp/art"), &doc).unwrap()
    }

    #[test]
    fn parses_sample() {
        let m = sample();
        assert_eq!(m.num_stages(), 2);
        assert_eq!(m.stages[0].name, "conv1");
        assert_eq!(m.branch.after_stage, 1);
        assert_eq!(m.batch_sizes, vec![1, 8]);
    }

    #[test]
    fn artifact_lookup_by_flavor_batch() {
        let m = sample();
        assert_eq!(
            m.stages[0].artifact(Flavor::Pallas, 8).unwrap(),
            "s1_pl_b8.hlo.txt"
        );
        assert_eq!(
            m.stages[1].artifact(Flavor::Ref, 1).unwrap(),
            "s2_ref_b1.hlo.txt"
        );
        assert!(m.stages[0].artifact(Flavor::Pallas, 4).is_err());
        assert_eq!(m.full_artifact(Flavor::Ref, 1).unwrap(), "full_ref_b1.hlo.txt");
        assert!(m.full_artifact(Flavor::Pallas, 1).is_err());
    }

    #[test]
    fn fixtures_resolve() {
        let m = sample();
        let f = m.fixture("input_b8").unwrap();
        assert_eq!(f.shape, vec![8, 3, 32, 32]);
        assert!(f.path.ends_with("fixtures/input_b8.bin"));
        let g = m.fig6_fixture("none").unwrap();
        assert_eq!(g.shape[0], 48);
        assert!(m.fig6_fixture("blurry").is_err());
    }

    #[test]
    fn to_desc_roundtrip() {
        let m = sample();
        let d = m.to_desc(0.4);
        d.validate().unwrap();
        assert_eq!(d.num_stages(), 2);
        assert_eq!(d.transfer_bytes(0), 12288);
        assert_eq!(d.transfer_bytes(1), 57600);
        assert_eq!(d.branches[0].exit_prob, 0.4);
    }

    #[test]
    fn to_desc_with_probs_validates_shape_and_range() {
        let m = sample();
        // The single-p wrapper and the slice form agree exactly.
        let d = m.to_desc_with_probs(&[0.4]).unwrap();
        assert_eq!(d, m.to_desc(0.4));
        assert_eq!(d.branches.len(), 1);
        assert_eq!(d.branches[0].after_stage, 1);
        // Wrong arity: one probability per branch, no more, no fewer.
        assert!(m.to_desc_with_probs(&[]).is_err());
        assert!(m.to_desc_with_probs(&[0.3, 0.3]).is_err());
        // Out-of-range probabilities are a caller bug, caught here.
        assert!(m.to_desc_with_probs(&[1.5]).is_err());
        assert!(m.to_desc_with_probs(&[-0.1]).is_err());
        assert!(m.to_desc_with_probs(&[f64::NAN]).is_err());
    }

    #[test]
    fn rejects_broken_chain() {
        let bad = SAMPLE.replace("\"in_shape\": [64,15,15], \"out_shape\": [2]",
                                  "\"in_shape\": [9,9,9], \"out_shape\": [2]");
        let doc = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &doc).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let doc = Json::parse(r#"{"model": "x"}"#).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &doc).is_err());
    }

    #[test]
    fn synthetic_sim_manifest_is_consistent() {
        let m = Manifest::synthetic_sim("sim-x", vec![3, 8, 8], &[32, 16, 2], 1, 2, vec![1, 4])
            .unwrap();
        assert_eq!(m.num_stages(), 3);
        assert_eq!(m.input_bytes_per_sample, 3 * 8 * 8 * 4);
        assert_eq!(m.stages[0].out_shape, vec![32]);
        assert_eq!(m.stages[1].in_shape, vec![32]);
        assert_eq!(m.branch.in_shape, vec![32]);
        assert_eq!(m.stages[2].out_shape, vec![2]);
        // No artifacts back it: lookups must error, not panic.
        assert!(m.stages[0].artifact(Flavor::Ref, 1).is_err());
        assert!(m.full_artifact(Flavor::Ref, 1).is_err());
        let d = m.to_desc(0.5);
        d.validate().unwrap();
        assert_eq!(d.transfer_bytes(1), 32 * 4);
    }

    #[test]
    fn synthetic_sim_rejects_bad_specs() {
        // Last stage must be the classifier head.
        assert!(Manifest::synthetic_sim("x", vec![4], &[8, 3], 1, 2, vec![1]).is_err());
        // Branch after the last stage is pointless.
        assert!(Manifest::synthetic_sim("x", vec![4], &[8, 2], 2, 2, vec![1]).is_err());
        assert!(Manifest::synthetic_sim("x", vec![4], &[], 1, 2, vec![1]).is_err());
        assert!(Manifest::synthetic_sim("x", vec![4], &[8, 2], 1, 2, vec![]).is_err());
        assert!(Manifest::synthetic_sim("x", vec![], &[8, 2], 1, 2, vec![1]).is_err());
    }
}
