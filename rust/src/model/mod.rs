//! Model descriptors: the BranchyNet stage graph as the Rust side sees it.
//!
//! The source of truth is `artifacts/manifest.json`, written by
//! `python/compile/aot.py`. [`manifest::Manifest`] binds it; [`flops`]
//! supplies an analytic cost model for planning when no measured profile
//! exists; [`synthetic`] builds arbitrary BranchyNet descriptions for
//! property tests and solver benchmarks (deep random chains).

pub mod flops;
pub mod manifest;
pub mod synthetic;

pub use manifest::{BranchInfo, Manifest, StageInfo};

/// A BranchyNet as the partitioner sees it: a chain of N stages, side
/// branches after given stages, and per-stage output sizes. This is the
/// abstract description both the real manifest and synthetic generators
/// produce, so the solver is independent of artifact details.
#[derive(Debug, Clone, PartialEq)]
pub struct BranchyNetDesc {
    /// Stage names, input side excluded ("conv1", ..., "fc3").
    pub stage_names: Vec<String>,
    /// Output bytes per sample of each stage (alpha_i, i = 1..N).
    pub stage_out_bytes: Vec<u64>,
    /// Raw input bytes per sample (alpha_0 — the cloud-only upload).
    pub input_bytes: u64,
    /// Stage indices (1-based) that have a side branch after them, with
    /// the branch's conditional exit probability p_k.
    pub branches: Vec<BranchDesc>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct BranchDesc {
    /// 1-based main-branch stage index the branch is attached after.
    pub after_stage: usize,
    /// P[sample exits here | reached this branch] — the paper's p_k.
    pub exit_prob: f64,
}

impl BranchyNetDesc {
    pub fn num_stages(&self) -> usize {
        self.stage_names.len()
    }

    /// alpha_s: bytes transferred if we split after stage s (s=0 -> raw
    /// input; s=N -> nothing is ever sent, the value is irrelevant but
    /// defined as the final output size).
    pub fn transfer_bytes(&self, split_after: usize) -> u64 {
        if split_after == 0 {
            self.input_bytes
        } else {
            self.stage_out_bytes[split_after - 1]
        }
    }

    /// alpha_s as it actually crosses the uplink under a wire encoding:
    /// [`transfer_bytes`](Self::transfer_bytes) pushed through the
    /// encoding's deterministic size map. The planner charges this, the
    /// codec ships it — both via
    /// [`WireEncoding::payload_bytes`](crate::network::encoding::WireEncoding::payload_bytes),
    /// so the cost model and the wire can't disagree.
    pub fn transfer_wire_bytes(
        &self,
        split_after: usize,
        encoding: crate::network::encoding::WireEncoding,
    ) -> u64 {
        encoding.payload_bytes(self.transfer_bytes(split_after))
    }

    /// Branch attached after stage `i`, if any.
    pub fn branch_after(&self, i: usize) -> Option<&BranchDesc> {
        self.branches.iter().find(|b| b.after_stage == i)
    }

    /// Scale every data size by `factor` — the paper-scale calibration
    /// knob (DESIGN.md §4): the paper's B-AlexNet ingests 224x224 images,
    /// ours 32x32, so transfer sizes (and hence the communication-vs-
    /// compute balance of Figs. 4/5) differ by ~(224/32)^2 = 49. Scaling
    /// alpha reproduces the paper's ratio without retraining at 224x224.
    pub fn scale_alpha(&self, factor: f64) -> BranchyNetDesc {
        assert!(factor > 0.0);
        let mut d = self.clone();
        d.input_bytes = (d.input_bytes as f64 * factor).round().max(1.0) as u64;
        for b in &mut d.stage_out_bytes {
            *b = (*b as f64 * factor).round().max(1.0) as u64;
        }
        d
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::bail;
        if self.stage_names.is_empty() {
            bail!("BranchyNet must have at least one stage");
        }
        if self.stage_out_bytes.len() != self.stage_names.len() {
            bail!("stage_out_bytes length mismatch");
        }
        if self.input_bytes == 0 {
            bail!("input_bytes must be > 0");
        }
        let n = self.num_stages();
        let mut seen = std::collections::HashSet::new();
        for b in &self.branches {
            if b.after_stage == 0 || b.after_stage >= n {
                // A branch after the last stage is pointless: the main
                // output is right there.
                bail!(
                    "branch after_stage {} out of range 1..{}",
                    b.after_stage,
                    n - 1
                );
            }
            if !(0.0..=1.0).contains(&b.exit_prob) {
                bail!("branch exit_prob {} not in [0,1]", b.exit_prob);
            }
            if !seen.insert(b.after_stage) {
                bail!("duplicate branch after stage {}", b.after_stage);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn tiny() -> BranchyNetDesc {
        BranchyNetDesc {
            stage_names: vec!["s1".into(), "s2".into(), "s3".into()],
            stage_out_bytes: vec![100, 50, 10],
            input_bytes: 80,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: 0.5,
            }],
        }
    }

    #[test]
    fn transfer_bytes_indexing() {
        let d = tiny();
        assert_eq!(d.transfer_bytes(0), 80); // raw input
        assert_eq!(d.transfer_bytes(1), 100);
        assert_eq!(d.transfer_bytes(3), 10);
    }

    #[test]
    fn transfer_wire_bytes_applies_the_encoding_size_map() {
        use crate::network::encoding::WireEncoding;
        let d = tiny();
        assert_eq!(d.transfer_wire_bytes(1, WireEncoding::Raw), 100);
        assert_eq!(d.transfer_wire_bytes(1, WireEncoding::Q8), 8 + 25);
        assert_eq!(d.transfer_wire_bytes(1, WireEncoding::Q4), 8 + 13);
        assert_eq!(
            d.transfer_wire_bytes(0, WireEncoding::Q8),
            WireEncoding::Q8.payload_bytes(80)
        );
    }

    #[test]
    fn validate_ok_and_errors() {
        tiny().validate().unwrap();

        let mut d = tiny();
        d.branches[0].exit_prob = 1.5;
        assert!(d.validate().is_err());

        let mut d = tiny();
        d.branches[0].after_stage = 3; // after last stage: rejected
        assert!(d.validate().is_err());

        let mut d = tiny();
        d.branches.push(BranchDesc {
            after_stage: 1,
            exit_prob: 0.1,
        });
        assert!(d.validate().is_err()); // duplicate

        let mut d = tiny();
        d.stage_out_bytes.pop();
        assert!(d.validate().is_err());
    }

    #[test]
    fn branch_lookup() {
        let d = tiny();
        assert!(d.branch_after(1).is_some());
        assert!(d.branch_after(2).is_none());
    }
}
