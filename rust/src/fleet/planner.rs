//! Per-class planning: each link class owns a [`Planner`] fork — shared
//! precomputed prefix sums, private log-bucketed [`PlanCache`] — so a
//! WiFi burst and a 3G burst never evict each other's plans, and cache
//! hit rates are observable per class.
//!
//! [`PlanCache`]: crate::planner::PlanCache

use crate::network::bandwidth::LinkModel;
use crate::partition::plan::PartitionPlan;
use crate::planner::Planner;

use super::class::LinkClass;

#[derive(Debug)]
pub struct ClassPlanner {
    class: LinkClass,
    name: String,
    planner: Planner,
}

impl ClassPlanner {
    pub fn new(class: LinkClass, name: impl Into<String>, planner: Planner) -> ClassPlanner {
        ClassPlanner {
            class,
            name: name.into(),
            planner,
        }
    }

    pub fn class(&self) -> LinkClass {
        self.class
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Plan for a link observation through this class's bucket cache.
    pub fn plan(&self, link: LinkModel) -> PartitionPlan {
        self.planner.plan_cached(link)
    }

    /// O(1) model query at the observed link (used by hysteresis
    /// comparisons and tests cross-checking executed splits).
    pub fn expected_time(&self, split: usize, link: LinkModel) -> f64 {
        self.planner.expected_time(split, link)
    }

    /// (hits, misses) of this class's plan cache.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.planner.cache_stats()
    }

    pub fn planner(&self) -> &Planner {
        &self.planner
    }

    /// A planner for this class's adaptive replan thread (same shared
    /// core, separate cache — the thread takes ownership).
    pub fn fork_planner(&self) -> Planner {
        self.planner.fork()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BranchDesc, BranchyNetDesc};
    use crate::timing::DelayProfile;

    fn base() -> Planner {
        let desc = BranchyNetDesc {
            stage_names: (1..=4).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![40_000, 20_000, 8_000, 8],
            input_bytes: 12_288,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: 0.5,
            }],
        };
        let profile =
            DelayProfile::from_cloud_times(vec![1e-4, 2e-4, 1.5e-4, 5e-5], 2e-5, 100.0);
        Planner::new(&desc, &profile, 1e-9, false)
    }

    #[test]
    fn class_planners_share_sums_with_independent_caches() {
        let b = base();
        let slow = ClassPlanner::new(LinkClass(0), "3G", b.fork());
        let fast = ClassPlanner::new(LinkClass(1), "WiFi", b.fork());
        assert!(slow.planner().shares_core_with(fast.planner()));

        let p_slow = slow.plan(LinkModel::new(1.10, 0.0));
        let p_fast = fast.plan(LinkModel::new(50_000.0, 0.0));
        // A starved uplink keeps work on the edge; a huge one ships it out.
        assert!(p_slow.split_after > p_fast.split_after);
        assert!(p_fast.is_cloud_only());

        // Each class's cache only saw its own lookup.
        assert_eq!(slow.cache_stats(), (0, 1));
        assert_eq!(fast.cache_stats(), (0, 1));
        let _ = slow.plan(LinkModel::new(1.11, 0.0)); // same bucket: hit
        assert_eq!(slow.cache_stats(), (1, 1));
        assert_eq!(fast.cache_stats(), (0, 1));
    }
}
