//! Per-class planning: each link class owns a [`Planner`] that shares
//! the fleet-wide p-independent `StaticCore` but carries its **own**
//! exit-probability view and its own log-bucketed [`PlanCache`] — so a
//! WiFi burst and a 3G burst never evict each other's plans, a per-class
//! exit-rate update never leaks into a sibling class, and cache hit
//! rates, view rebuilds and epoch invalidations are observable per
//! class.
//!
//! [`PlanCache`]: crate::planner::PlanCache

use crate::network::bandwidth::LinkModel;
use crate::partition::plan::PartitionPlan;
use crate::planner::Planner;

use super::class::LinkClass;

#[derive(Debug)]
pub struct ClassPlanner {
    class: LinkClass,
    name: String,
    planner: Planner,
}

impl ClassPlanner {
    pub fn new(class: LinkClass, name: impl Into<String>, planner: Planner) -> ClassPlanner {
        ClassPlanner {
            class,
            name: name.into(),
            planner,
        }
    }

    pub fn class(&self) -> LinkClass {
        self.class
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Plan for a link observation through this class's bucket cache
    /// (epoch-checked: a p-update re-solves the bucket).
    pub fn plan(&self, link: LinkModel) -> PartitionPlan {
        self.planner.plan_cached(link)
    }

    /// O(1) model query at the observed link (used by hysteresis
    /// comparisons and tests cross-checking executed splits).
    pub fn expected_time(&self, split: usize, link: LinkModel) -> f64 {
        self.planner.expected_time(split, link)
    }

    /// Swap this class's exit-probability view in place (O(N·m), shared
    /// with every fork handed out for this class) and invalidate its
    /// plan cache via the view epoch. Fed by the fleet's online
    /// exit-rate estimation; also callable directly by operators/tools.
    pub fn set_exit_probs(&self, probs: &[f64]) {
        self.planner.set_exit_probs(probs);
    }

    /// The conditional exit probabilities the class is currently
    /// planning with, in branch-position order.
    pub fn exit_probs(&self) -> Vec<f64> {
        self.planner.exit_probs()
    }

    /// How many times this class's view was re-derived from exit-rate
    /// feedback (or direct `set_exit_probs` calls).
    pub fn view_rebuilds(&self) -> u64 {
        self.planner.view_rebuilds()
    }

    /// (hits, misses) of this class's plan cache.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.planner.cache_stats()
    }

    /// How many times a view swap flushed this class's plan cache.
    pub fn cache_invalidations(&self) -> u64 {
        self.planner.cache_invalidations()
    }

    pub fn planner(&self) -> &Planner {
        &self.planner
    }

    /// A planner for this class's adaptive replan thread: same shared
    /// core **and live view** (the thread sees every p-update), separate
    /// cache — the thread takes ownership.
    pub fn fork_planner(&self) -> Planner {
        self.planner.fork()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BranchDesc, BranchyNetDesc};
    use crate::timing::DelayProfile;

    fn base() -> Planner {
        let desc = BranchyNetDesc {
            stage_names: (1..=4).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![40_000, 20_000, 8_000, 8],
            input_bytes: 12_288,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: 0.5,
            }],
        };
        let profile =
            DelayProfile::from_cloud_times(vec![1e-4, 2e-4, 1.5e-4, 5e-5], 2e-5, 100.0);
        Planner::new(&desc, &profile, 1e-9, false)
    }

    #[test]
    fn class_planners_share_sums_with_independent_caches() {
        let b = base();
        let slow = ClassPlanner::new(LinkClass(0), "3G", b.with_exit_probs(&[0.5]));
        let fast = ClassPlanner::new(LinkClass(1), "WiFi", b.with_exit_probs(&[0.5]));
        assert!(slow.planner().shares_core_with(fast.planner()));

        let p_slow = slow.plan(LinkModel::new(1.10, 0.0));
        let p_fast = fast.plan(LinkModel::new(50_000.0, 0.0));
        // A starved uplink keeps work on the edge; a huge one ships it out.
        assert!(p_slow.split_after > p_fast.split_after);
        assert!(p_fast.is_cloud_only());

        // Each class's cache only saw its own lookup.
        assert_eq!(slow.cache_stats(), (0, 1));
        assert_eq!(fast.cache_stats(), (0, 1));
        let _ = slow.plan(LinkModel::new(1.11, 0.0)); // same bucket: hit
        assert_eq!(slow.cache_stats(), (1, 1));
        assert_eq!(fast.cache_stats(), (0, 1));
    }

    #[test]
    fn per_class_p_updates_do_not_leak_across_classes() {
        let b = base();
        let a = ClassPlanner::new(LinkClass(0), "a", b.with_exit_probs(&[0.5]));
        let c = ClassPlanner::new(LinkClass(1), "c", b.with_exit_probs(&[0.5]));
        let link = LinkModel::new(5.85, 0.0);
        let _ = a.plan(link);
        let _ = c.plan(link);

        a.set_exit_probs(&[0.05]);
        assert_eq!(a.exit_probs(), vec![0.05]);
        assert_eq!(c.exit_probs(), vec![0.5], "sibling class untouched");
        assert_eq!(a.view_rebuilds(), 1);
        assert_eq!(c.view_rebuilds(), 0);

        // a's cache re-solves once; c's cache still hits.
        let _ = a.plan(link);
        let _ = c.plan(link);
        assert_eq!(a.cache_stats(), (0, 2));
        assert_eq!(a.cache_invalidations(), 1);
        assert_eq!(c.cache_stats(), (1, 1));
        assert_eq!(c.cache_invalidations(), 0);

        // But a's own adaptive-thread fork *does* see a's update.
        let fork = a.fork_planner();
        assert_eq!(fork.exit_probs(), vec![0.05]);
        assert!(fork.shares_view_with(a.planner()));
    }
}
