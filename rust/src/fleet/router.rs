//! Shard selection within a link class: round-robin, stable hashing, or
//! least-loaded (by admission-queue depth).

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::util::rng::splitmix64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Rotate through the shards.
    RoundRobin,
    /// Stable for a given routing key: equal keys always land on the
    /// same shard. Affinity therefore depends on what the caller feeds
    /// as the key — `Fleet::submit_keyed` gives per-client stickiness,
    /// while `Fleet::submit` hashes a per-request counter, which
    /// degenerates to uniform random spread.
    Hash,
    /// Pick the shard with the shallowest admission queue (ties go to
    /// the lowest index).
    LeastLoaded,
}

impl RoutePolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::Hash => "hash",
            RoutePolicy::LeastLoaded => "least-loaded",
        }
    }

    pub fn parse(s: &str) -> Result<RoutePolicy> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Ok(RoutePolicy::RoundRobin),
            "hash" => Ok(RoutePolicy::Hash),
            "least-loaded" | "ll" => Ok(RoutePolicy::LeastLoaded),
            _ => bail!("unknown routing policy '{s}' (expected round-robin|hash|least-loaded)"),
        }
    }
}

/// One class group's shard picker. The round-robin cursor is part of the
/// router, so give each class group its *own* router — a cursor shared
/// across groups lets correlated multi-class arrival patterns (A,B,A,B…)
/// alias with the shard count and pin every class to one shard.
#[derive(Debug)]
pub struct FleetRouter {
    policy: RoutePolicy,
    rr: AtomicU64,
}

impl FleetRouter {
    pub fn new(policy: RoutePolicy) -> FleetRouter {
        FleetRouter {
            policy,
            rr: AtomicU64::new(0),
        }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Pick a shard among `depths.len()` candidates; `depths` carries
    /// each shard's current admission-queue depth and `key` seeds hash
    /// routing. Panics on zero candidates (a class group always has at
    /// least one shard).
    pub fn pick(&self, key: u64, depths: &[usize]) -> usize {
        match self.policy {
            RoutePolicy::LeastLoaded => {
                assert!(!depths.is_empty(), "cannot route into an empty shard group");
                depths
                    .iter()
                    .enumerate()
                    .min_by_key(|&(_, &d)| d)
                    .map(|(i, _)| i)
                    .unwrap()
            }
            _ => self.pick_index(key, depths.len()),
        }
    }

    /// Depth-free pick for the policies that never inspect load
    /// (round-robin, hash) — lets the admission path skip gathering
    /// queue depths. A least-loaded router falls back to round-robin
    /// here so a misuse still spreads.
    pub fn pick_index(&self, key: u64, n: usize) -> usize {
        assert!(n > 0, "cannot route into an empty shard group");
        if n == 1 {
            return 0;
        }
        match self.policy {
            RoutePolicy::Hash => {
                let mut s = key;
                (splitmix64(&mut s) % n as u64) as usize
            }
            RoutePolicy::RoundRobin | RoutePolicy::LeastLoaded => {
                (self.rr.fetch_add(1, Ordering::Relaxed) % n as u64) as usize
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_names_roundtrip() {
        for p in [
            RoutePolicy::RoundRobin,
            RoutePolicy::Hash,
            RoutePolicy::LeastLoaded,
        ] {
            assert_eq!(RoutePolicy::parse(p.as_str()).unwrap(), p);
        }
        assert_eq!(RoutePolicy::parse("RR").unwrap(), RoutePolicy::RoundRobin);
        assert!(RoutePolicy::parse("random").is_err());
    }

    #[test]
    fn round_robin_cycles() {
        let r = FleetRouter::new(RoutePolicy::RoundRobin);
        let depths = [0usize; 3];
        let picks: Vec<usize> = (0..6).map(|_| r.pick(0, &depths)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn hash_is_stable_and_spreads() {
        let r = FleetRouter::new(RoutePolicy::Hash);
        let depths = [0usize; 4];
        for key in 0..32u64 {
            assert_eq!(r.pick(key, &depths), r.pick(key, &depths));
        }
        let mut hit = [false; 4];
        for key in 0..256u64 {
            hit[r.pick(key, &depths)] = true;
        }
        assert!(hit.iter().all(|&h| h), "256 keys must reach all 4 shards");
    }

    #[test]
    fn least_loaded_picks_min_with_low_index_ties() {
        let r = FleetRouter::new(RoutePolicy::LeastLoaded);
        assert_eq!(r.pick(0, &[5, 2, 7]), 1);
        assert_eq!(r.pick(0, &[3, 1, 1]), 1);
        assert_eq!(r.pick(0, &[0, 0, 0]), 0);
        assert_eq!(r.pick(0, &[9]), 0);
    }

    #[test]
    fn picks_remap_in_bounds_across_resizes() {
        // One router instance survives its group growing and shrinking
        // (the autoscaler path): every policy must keep its picks
        // inside whatever candidate count the *current* call presents,
        // and hash must still cover the grown set.
        let hash = FleetRouter::new(RoutePolicy::Hash);
        for n in [1usize, 2, 3, 5, 8, 3, 1] {
            let mut hit = vec![false; n];
            for key in 0..256u64 {
                let p = hash.pick_index(key, n);
                assert!(p < n, "hash picked {p} of {n}");
                hit[p] = true;
            }
            assert!(hit.iter().all(|&h| h), "256 keys must cover {n} shards");
        }
        // Equal keys stay together between resizes at a given size.
        assert_eq!(hash.pick_index(42, 5), hash.pick_index(42, 5));

        // Round-robin's cursor is absolute, so a resize mid-cycle still
        // lands in bounds (the modulus follows the live count).
        let rr = FleetRouter::new(RoutePolicy::RoundRobin);
        for _ in 0..5 {
            assert!(rr.pick_index(0, 2) < 2);
        }
        for _ in 0..7 {
            assert!(rr.pick_index(0, 3) < 3);
        }
        for _ in 0..3 {
            assert_eq!(rr.pick_index(0, 1), 0);
        }

        // Least-loaded reads whatever depth slice the post-resize set
        // produced — fewer or more candidates than the last call.
        let ll = FleetRouter::new(RoutePolicy::LeastLoaded);
        assert_eq!(ll.pick(0, &[3, 1, 2, 9]), 1);
        assert_eq!(ll.pick(0, &[4, 2]), 1);
        assert_eq!(ll.pick(0, &[7]), 0);
    }

    #[test]
    fn pick_index_matches_pick_for_depth_free_policies() {
        let rr = FleetRouter::new(RoutePolicy::RoundRobin);
        assert_eq!(
            (0..6).map(|_| rr.pick_index(0, 3)).collect::<Vec<_>>(),
            vec![0, 1, 2, 0, 1, 2]
        );
        let hash = FleetRouter::new(RoutePolicy::Hash);
        for key in 0..16u64 {
            assert_eq!(hash.pick_index(key, 4), hash.pick(key, &[0; 4]));
        }
    }
}
