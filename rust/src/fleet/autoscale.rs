//! Per-class shard autoscaling: an elastic [`ShardGroup`] plus the
//! [`Autoscaler`] control loop that resizes it from observed load.
//!
//! # Why this exists
//!
//! The planner minimizes a *single* inference's expected time; it says
//! nothing about how many parallel pipelines a class needs. With a fixed
//! `shards_per_class`, a 3G-class burst either queues unboundedly or
//! saturates the admission queues while the WiFi shards idle beside it.
//! Neurosurgeon and Edgent both adapt the deployment to observed load,
//! not just link state — this module is that adaptation for the shard
//! dimension: the signals the fleet already produces (per-shard
//! admission-queue depth, admission rejections, remote-cloud
//! saturation) are sampled into a windowed [`LoadSignal`], and a pure
//! hysteresis rule ([`AutoscaleConfig::decide`]) drives
//! [`ShardGroup::grow`] / [`ShardGroup::shrink`] between
//! `min_shards..=max_shards`.
//!
//! # Elasticity without dropped requests
//!
//! [`ShardGroup`] is the live shard set every consumer reads through
//! one `RwLock`: the fleet's admission path holds the read lock across
//! *pick shard → submit*, so a shard can never be retired between being
//! chosen and receiving the request. Growing builds the new
//! [`Coordinator`] outside the lock (engine construction may compile
//! kernels) and pushes it in one write; shrinking pops the victim under
//! the write lock *first* — making it unreachable to routing, plan
//! pushes and metrics — and only then drains it
//! ([`Coordinator::drain`]: wait for every admitted request to be
//! answered, close the queues, join the workers). The victim's final
//! snapshot is retained so class aggregates never lose completed work.
//!
//! # Not flapping
//!
//! Three mechanisms, in order of activation: the *window* (a decision
//! looks at `window` consecutive samples, so one spiky tick decides
//! nothing), the *hysteresis band* (`scale_down_depth` must sit well
//! below `scale_up_depth`; mean depths inside the band hold), and the
//! *cooldown* (after any resize the class holds for `cooldown`, letting
//! the previous decision's effect reach the signals before the next).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock, RwLockReadGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::{Coordinator, MetricsSnapshot};

/// Every knob of one class's scaler. `shards_per_class` is the starting
/// point and must lie within `min_shards..=max_shards`.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Never shrink below this many shards (>= 1).
    pub min_shards: usize,
    /// Never grow beyond this many shards (<= 64, the fleet's hard cap).
    pub max_shards: usize,
    /// Mean admission-queue depth per shard at or above which the class
    /// grows. Any admission rejection in the window also grows,
    /// regardless of depth — a rejection is a dropped request, the one
    /// signal that must never need a second window to act on.
    pub scale_up_depth: f64,
    /// Mean depth per shard at or below which the class shrinks (when
    /// the window also saw zero rejections). Must be strictly below
    /// `scale_up_depth`; the gap is the hysteresis band.
    pub scale_down_depth: f64,
    /// Sampling tick of the control loop.
    pub interval: Duration,
    /// Samples aggregated into one [`LoadSignal`] before a decision.
    pub window: usize,
    /// Minimum time between two resizes of the same class.
    pub cooldown: Duration,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            min_shards: 1,
            max_shards: 8,
            scale_up_depth: 4.0,
            scale_down_depth: 0.5,
            interval: Duration::from_millis(100),
            window: 5,
            cooldown: Duration::from_secs(2),
        }
    }
}

impl AutoscaleConfig {
    pub fn validate(&self) -> Result<()> {
        if self.min_shards == 0 {
            bail!("autoscale min_shards must be >= 1");
        }
        if self.max_shards > 64 {
            bail!(
                "autoscale max_shards must be <= 64 (the fleet's shard cap); got {}",
                self.max_shards
            );
        }
        if self.min_shards > self.max_shards {
            bail!(
                "autoscale min_shards ({}) must be <= max_shards ({})",
                self.min_shards,
                self.max_shards
            );
        }
        if !(self.scale_up_depth.is_finite() && self.scale_up_depth > 0.0) {
            bail!(
                "autoscale scale_up_depth must be positive and finite; got {}",
                self.scale_up_depth
            );
        }
        if !(self.scale_down_depth.is_finite() && self.scale_down_depth >= 0.0) {
            bail!(
                "autoscale scale_down_depth must be non-negative and finite; got {}",
                self.scale_down_depth
            );
        }
        if self.scale_down_depth >= self.scale_up_depth {
            bail!(
                "autoscale scale_down_depth ({}) must be strictly below scale_up_depth \
                 ({}) — the gap is the hysteresis band that stops flapping",
                self.scale_down_depth,
                self.scale_up_depth
            );
        }
        if self.interval.is_zero() {
            bail!("autoscale interval must be > 0");
        }
        if self.window == 0 {
            bail!("autoscale window must be >= 1");
        }
        Ok(())
    }

    /// The pure scaling rule: window signal + current shard count →
    /// decision. Bounds and hysteresis live here; timing (window
    /// assembly, cooldown) lives in the [`Autoscaler`] loop so this
    /// stays unit-testable without threads.
    pub fn decide(&self, signal: &LoadSignal, shards: usize) -> ScaleDecision {
        if shards < self.max_shards {
            if signal.rejections > 0 {
                return ScaleDecision::Grow(format!(
                    "{} admission rejection(s) in window",
                    signal.rejections
                ));
            }
            if signal.mean_depth_per_shard >= self.scale_up_depth {
                return ScaleDecision::Grow(format!(
                    "mean queue depth {:.1}/shard >= {:.1}",
                    signal.mean_depth_per_shard, self.scale_up_depth
                ));
            }
        }
        if shards > self.min_shards
            && signal.rejections == 0
            // Remote saturation vetoes a shrink: a backed-up shared
            // cloud stalls work *behind* the admission queue, so quiet
            // admission depths are deceiving — shed capacity only when
            // the whole pipeline, cloud path included, is actually idle.
            // (It is deliberately not a grow trigger: the remote is
            // shared, so more shards would add load, not capacity.)
            && signal.remote_pressure == 0
            && signal.mean_depth_per_shard <= self.scale_down_depth
        {
            return ScaleDecision::Shrink(format!(
                "mean queue depth {:.1}/shard <= {:.1} (peak {})",
                signal.mean_depth_per_shard, self.scale_down_depth, signal.peak_depth
            ));
        }
        ScaleDecision::Hold
    }
}

/// What one class's scaler decided for one window.
#[derive(Debug, Clone, PartialEq)]
pub enum ScaleDecision {
    /// Add a shard; the string is the trigger, kept for `ScalerStats`.
    Grow(String),
    /// Retire a shard; the string is the trigger.
    Shrink(String),
    Hold,
}

/// One control-loop tick's raw reading of a class, taken by the fleet
/// (it owns the shard handles). Counters are cumulative; the
/// [`Autoscaler`] differences them across window boundaries.
#[derive(Debug, Clone, Copy, Default)]
pub struct LoadSample {
    /// Live shards at sampling time.
    pub shards: usize,
    /// Sum of the live shards' admission-queue depths.
    pub depth_total: usize,
    /// Cumulative admission rejections (live + retired shards).
    pub rejected_total: u64,
    /// Cumulative remote-cloud pressure (`saturated + fast_fails` of
    /// the fleet's shared remote client); 0 with an in-process cloud.
    pub remote_total: u64,
}

/// One decision window's aggregate — the input to
/// [`AutoscaleConfig::decide`], and what `last trigger` strings quote.
#[derive(Debug, Clone, Default)]
pub struct LoadSignal {
    /// Mean over the window of (total depth / live shards).
    pub mean_depth_per_shard: f64,
    /// Largest total depth any sample of the window saw; quoted in
    /// shrink triggers so `last_trigger` shows how quiet "quiet" was.
    pub peak_depth: usize,
    /// Admission rejections that happened during the window.
    pub rejections: u64,
    /// Remote-cloud saturation/fast-fail events during the window.
    /// Vetoes a scale-down (work is stalled *behind* the admission
    /// queue, so quiet depths are deceiving) but is not a grow trigger
    /// — the remote is shared, so more shards would add load to it, not
    /// capacity.
    pub remote_pressure: u64,
}

impl LoadSignal {
    /// Fold a window of samples; `prev` carries the cumulative counters
    /// at the previous window's end (saturating: a counter may appear
    /// to step back when a retired shard's tally moves between the live
    /// and retired sums mid-sample).
    pub fn from_window(window: &[LoadSample], prev: &LoadSample) -> LoadSignal {
        if window.is_empty() {
            return LoadSignal::default();
        }
        let mean = window
            .iter()
            .map(|s| s.depth_total as f64 / s.shards.max(1) as f64)
            .sum::<f64>()
            / window.len() as f64;
        let last = window.last().unwrap();
        LoadSignal {
            mean_depth_per_shard: mean,
            peak_depth: window.iter().map(|s| s.depth_total).max().unwrap_or(0),
            rejections: last.rejected_total.saturating_sub(prev.rejected_total),
            remote_pressure: last.remote_total.saturating_sub(prev.remote_total),
        }
    }
}

/// Scaling observability for one class, reported in `ClassReport`
/// (summary + JSON) whether autoscaling is on or off.
#[derive(Debug, Clone, Default)]
pub struct ScalerStats {
    /// False = the shard set is fixed at its startup size.
    pub enabled: bool,
    pub min_shards: usize,
    pub max_shards: usize,
    /// Live shards right now.
    pub current_shards: usize,
    /// Shards retired by shrinks over the class's lifetime.
    pub retired_shards: usize,
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// What caused the most recent resize, e.g. `"grow: 3 admission
    /// rejection(s) in window"`. `None` until the first resize.
    pub last_trigger: Option<String>,
}

/// A class's live, elastic shard set. All consumers — the router's
/// admission path, adaptive/estimator plan pushes, metrics rollup, the
/// autoscaler — read one `RwLock`'d vector, so every reader sees a
/// consistent set mid-resize. Never empty: shrinking below one shard is
/// refused. (No `Debug`: [`Coordinator`] handles aren't printable.)
pub struct ShardGroup {
    shards: RwLock<Vec<Arc<Coordinator>>>,
    /// Monotonic shard-label counter; indices are never reused, so
    /// `class-s3` in a log always means the same pipeline.
    next_label: AtomicU64,
    scale_ups: AtomicU64,
    scale_downs: AtomicU64,
    /// Final snapshots of retired shards: their completed work must not
    /// vanish from class aggregates when they do.
    retired: Mutex<Vec<MetricsSnapshot>>,
    last_trigger: Mutex<Option<String>>,
}

impl ShardGroup {
    /// An empty group; fill it with [`ShardGroup::install_initial`].
    /// Two-phase startup because exit observers must capture the group
    /// before the shards (whose workers run the observers) exist.
    pub fn new() -> ShardGroup {
        ShardGroup {
            shards: RwLock::new(Vec::new()),
            next_label: AtomicU64::new(0),
            scale_ups: AtomicU64::new(0),
            scale_downs: AtomicU64::new(0),
            retired: Mutex::new(Vec::new()),
            last_trigger: Mutex::new(None),
        }
    }

    /// Install the startup shard set (not counted as scale-ups) and
    /// anchor the label counter past it.
    pub fn install_initial(&self, shards: Vec<Arc<Coordinator>>) {
        self.next_label.store(shards.len() as u64, Ordering::Relaxed);
        *self.shards.write().unwrap() = shards;
    }

    pub fn len(&self) -> usize {
        self.shards.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the live shard handles (for plan pushes and metrics;
    /// the admission path uses [`ShardGroup::read`] instead so the set
    /// cannot change between picking a shard and submitting to it).
    pub fn handles(&self) -> Vec<Arc<Coordinator>> {
        self.shards.read().unwrap().clone()
    }

    /// Read-locked view of the live set. Hold the guard across *pick →
    /// submit*: a shrink's write lock then cannot retire the picked
    /// shard before the request lands in its admission queue.
    pub fn read(&self) -> RwLockReadGuard<'_, Vec<Arc<Coordinator>>> {
        self.shards.read().unwrap()
    }

    /// Add one shard built by `make_shard(label_index)`, refusing to
    /// exceed `cap` shards — the class's `max_shards` when autoscaling,
    /// the fleet-wide 64 otherwise; the autoscaler and the manual
    /// `Fleet::grow_class` path therefore respect the same ceiling.
    /// Construction runs *outside* the lock (engines may compile
    /// kernels for seconds); the install is one push under the write
    /// lock, where the cap is re-checked so concurrent grows cannot
    /// overshoot it. Returns the new shard count.
    pub fn grow(
        &self,
        trigger: &str,
        cap: usize,
        make_shard: impl FnOnce(u64) -> Result<Arc<Coordinator>>,
    ) -> Result<usize> {
        if self.len() >= cap {
            bail!("already at the {cap}-shard cap"); // don't build an engine to discard
        }
        let idx = self.next_label.fetch_add(1, Ordering::Relaxed);
        let shard = make_shard(idx)?;
        {
            let mut shards = self.shards.write().unwrap();
            if shards.len() < cap {
                shards.push(shard);
                let n = shards.len();
                drop(shards);
                self.scale_ups.fetch_add(1, Ordering::Relaxed);
                *self.last_trigger.lock().unwrap() = Some(format!("grow: {trigger}"));
                return Ok(n);
            }
        }
        // Lost the install race to a concurrent grow: the shard we just
        // built has live worker threads — retire it cleanly, not by
        // dropping it (its workers would block on their queues forever).
        shard.drain();
        bail!("already at the {cap}-shard cap (a concurrent grow won the race)")
    }

    /// Retire the highest-index shard, refusing to go below `floor`
    /// shards (the class's `min_shards` when autoscaling; never below
    /// one regardless — an empty group is unroutable): pop it under the
    /// write lock (new requests can no longer route to it), then drain
    /// it — every request it already admitted is answered before its
    /// workers join. Returns the new shard count.
    pub fn shrink(&self, trigger: &str, floor: usize) -> Result<usize> {
        let floor = floor.max(1);
        let (victim, n) = {
            let mut shards = self.shards.write().unwrap();
            if shards.len() <= floor {
                bail!("cannot shrink a class below {floor} shard(s)");
            }
            let victim = shards.pop().unwrap();
            (victim, shards.len())
        };
        let snapshot = victim.drain();
        self.retired.lock().unwrap().push(snapshot);
        self.scale_downs.fetch_add(1, Ordering::Relaxed);
        *self.last_trigger.lock().unwrap() = Some(format!("shrink: {trigger}"));
        Ok(n)
    }

    /// Record a trigger that did *not* resize the group — e.g. a grow
    /// denied by the fleet-wide shard budget. `last_trigger` is the
    /// operator's one-line answer to "why is this class this size?",
    /// and a denial is as much an answer as a resize.
    pub fn note_trigger(&self, trigger: &str) {
        *self.last_trigger.lock().unwrap() = Some(trigger.to_string());
    }

    /// Final snapshots of every shard retired so far.
    pub fn retired_snapshots(&self) -> Vec<MetricsSnapshot> {
        self.retired.lock().unwrap().clone()
    }

    /// Cumulative admission rejections across retired shards (the
    /// autoscaler's sampler adds the live shards' own counters).
    pub fn retired_rejected(&self) -> u64 {
        self.retired.lock().unwrap().iter().map(|s| s.rejected).sum()
    }

    /// Assemble this group's [`ScalerStats`]; `bounds` is the active
    /// autoscale range, `None` when the scaler is off.
    pub fn stats(&self, bounds: Option<(usize, usize)>) -> ScalerStats {
        let current = self.len();
        ScalerStats {
            enabled: bounds.is_some(),
            min_shards: bounds.map(|(lo, _)| lo).unwrap_or(current),
            max_shards: bounds.map(|(_, hi)| hi).unwrap_or(current),
            current_shards: current,
            retired_shards: self.retired.lock().unwrap().len(),
            scale_ups: self.scale_ups.load(Ordering::Relaxed),
            scale_downs: self.scale_downs.load(Ordering::Relaxed),
            last_trigger: self.last_trigger.lock().unwrap().clone(),
        }
    }

    /// Drain every live shard and return their final snapshots
    /// (fleet shutdown). The group is left empty; the observer/adaptive
    /// closures still holding the group see no shards, which breaks the
    /// group → shard → worker-closure → group reference cycle.
    pub fn drain_all(&self) -> Vec<MetricsSnapshot> {
        let shards = std::mem::take(&mut *self.shards.write().unwrap());
        shards.iter().map(|s| s.drain()).collect()
    }
}

impl Default for ShardGroup {
    fn default() -> Self {
        ShardGroup::new()
    }
}

/// Handle to one class's running control loop; [`AutoscalerHandle::stop`]
/// wakes and joins it.
pub struct AutoscalerHandle {
    stop: Arc<(Mutex<bool>, Condvar)>,
    thread: std::thread::JoinHandle<()>,
}

impl AutoscalerHandle {
    pub fn stop(self) {
        *self.stop.0.lock().unwrap() = true;
        self.stop.1.notify_all();
        let _ = self.thread.join();
    }
}

/// The per-class control loop: every `interval` it takes a
/// [`LoadSample`] via `sample`, every `window` samples it folds them
/// into a [`LoadSignal`], asks [`AutoscaleConfig::decide`], and — if
/// outside the cooldown — executes the decision via `grow` / `shrink`
/// (closures supplied by the fleet, which owns engine construction and
/// the shard set). Resize failures are logged and retried at the next
/// window, never fatal to serving.
pub struct Autoscaler;

impl Autoscaler {
    pub fn spawn(
        name: String,
        cfg: AutoscaleConfig,
        sample: impl Fn() -> LoadSample + Send + 'static,
        grow: impl Fn(&str) -> Result<usize> + Send + 'static,
        shrink: impl Fn(&str) -> Result<usize> + Send + 'static,
    ) -> AutoscalerHandle {
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let stop2 = stop.clone();
        let thread = std::thread::Builder::new()
            .name(format!("autoscale-{name}"))
            .spawn(move || {
                let mut window: Vec<LoadSample> = Vec::with_capacity(cfg.window);
                let mut prev = sample();
                let mut cooldown_until = Instant::now();
                let (lock, cvar) = &*stop2;
                loop {
                    // Interruptible tick: stop() must not wait a window.
                    let mut stopped = lock.lock().unwrap();
                    while !*stopped {
                        let (next, timeout) =
                            cvar.wait_timeout(stopped, cfg.interval).unwrap();
                        stopped = next;
                        if timeout.timed_out() {
                            break;
                        }
                    }
                    if *stopped {
                        return;
                    }
                    drop(stopped);

                    let s = sample();
                    window.push(s);
                    // During the cooldown the window keeps accumulating
                    // instead of being folded and discarded: the
                    // rejection delta is computed against `prev`, which
                    // only advances when a decision actually runs, so
                    // rejections that land mid-cooldown still force the
                    // first post-cooldown decision to grow. (The window
                    // length is bounded by cooldown/interval.)
                    if window.len() < cfg.window || Instant::now() < cooldown_until {
                        continue;
                    }
                    let signal = LoadSignal::from_window(&window, &prev);
                    prev = *window.last().unwrap();
                    window.clear();

                    match cfg.decide(&signal, s.shards) {
                        ScaleDecision::Grow(trigger) => {
                            match grow(&trigger) {
                                Ok(n) => {
                                    log::info!("[{name}] scaled up to {n} shard(s): {trigger}");
                                    cooldown_until = Instant::now() + cfg.cooldown;
                                }
                                Err(e) => log::warn!("[{name}] scale-up failed: {e:#}"),
                            }
                        }
                        ScaleDecision::Shrink(trigger) => {
                            match shrink(&trigger) {
                                Ok(n) => {
                                    log::info!("[{name}] scaled down to {n} shard(s): {trigger}");
                                    cooldown_until = Instant::now() + cfg.cooldown;
                                }
                                Err(e) => log::warn!("[{name}] scale-down failed: {e:#}"),
                            }
                        }
                        ScaleDecision::Hold => {}
                    }
                }
            })
            .expect("spawn autoscaler");
        AutoscalerHandle { stop, thread }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            min_shards: 1,
            max_shards: 4,
            scale_up_depth: 4.0,
            scale_down_depth: 0.5,
            ..Default::default()
        }
    }

    fn signal(mean: f64, rejections: u64) -> LoadSignal {
        LoadSignal {
            mean_depth_per_shard: mean,
            peak_depth: mean.ceil() as usize,
            rejections,
            remote_pressure: 0,
        }
    }

    #[test]
    fn validate_rejects_degenerate_configs() {
        cfg().validate().unwrap();
        AutoscaleConfig::default().validate().unwrap();
        for bad in [
            AutoscaleConfig { min_shards: 0, ..cfg() },
            AutoscaleConfig { max_shards: 65, ..cfg() },
            AutoscaleConfig { min_shards: 5, max_shards: 4, ..cfg() },
            AutoscaleConfig { scale_up_depth: 0.0, ..cfg() },
            AutoscaleConfig { scale_down_depth: -1.0, ..cfg() },
            // An inverted (or collapsed) hysteresis band flaps.
            AutoscaleConfig { scale_down_depth: 4.0, ..cfg() },
            AutoscaleConfig { interval: Duration::ZERO, ..cfg() },
            AutoscaleConfig { window: 0, ..cfg() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} must not validate");
        }
    }

    #[test]
    fn decide_hysteresis_band_holds() {
        let c = cfg();
        // Above the up threshold: grow; below the down threshold:
        // shrink; anywhere in the band between: hold.
        assert!(matches!(c.decide(&signal(5.0, 0), 2), ScaleDecision::Grow(_)));
        assert!(matches!(c.decide(&signal(4.0, 0), 2), ScaleDecision::Grow(_)));
        assert_eq!(c.decide(&signal(2.0, 0), 2), ScaleDecision::Hold);
        assert_eq!(c.decide(&signal(0.6, 0), 2), ScaleDecision::Hold);
        assert!(matches!(c.decide(&signal(0.2, 0), 2), ScaleDecision::Shrink(_)));
    }

    #[test]
    fn decide_respects_bounds() {
        let c = cfg();
        // Saturated load at max_shards: hold, not grow.
        assert_eq!(c.decide(&signal(100.0, 9), 4), ScaleDecision::Hold);
        // Idle at min_shards: hold, not shrink.
        assert_eq!(c.decide(&signal(0.0, 0), 1), ScaleDecision::Hold);
    }

    #[test]
    fn rejections_force_growth_even_at_zero_depth() {
        // A rejected request is a dropped request: the queue may look
        // empty the moment we sample it and still have overflowed
        // between samples.
        let c = cfg();
        match c.decide(&signal(0.0, 3), 1) {
            ScaleDecision::Grow(t) => assert!(t.contains("rejection"), "{t}"),
            other => panic!("{other:?}"),
        }
        // And rejections veto a shrink.
        assert_eq!(c.decide(&signal(0.0, 1), 4), ScaleDecision::Hold);
    }

    #[test]
    fn remote_pressure_vetoes_shrink_but_never_grows() {
        // A saturated shared remote stalls work behind the admission
        // queue: quiet depths must not shed capacity, but growing would
        // only add load to the shared bottleneck.
        let c = cfg();
        let sig = LoadSignal {
            remote_pressure: 3,
            ..signal(0.0, 0)
        };
        assert_eq!(c.decide(&sig, 4), ScaleDecision::Hold);
        assert_eq!(c.decide(&sig, 1), ScaleDecision::Hold);
        // Pressure gone: the same quiet class shrinks again, and the
        // trigger quotes the window's peak so operators see how quiet.
        match c.decide(&signal(0.0, 0), 4) {
            ScaleDecision::Shrink(t) => assert!(t.contains("peak 0"), "{t}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn signal_folds_window_and_differences_counters() {
        let prev = LoadSample {
            shards: 2,
            depth_total: 0,
            rejected_total: 10,
            remote_total: 5,
        };
        let window = [
            LoadSample { shards: 2, depth_total: 8, rejected_total: 10, remote_total: 5 },
            LoadSample { shards: 2, depth_total: 4, rejected_total: 12, remote_total: 9 },
        ];
        let s = LoadSignal::from_window(&window, &prev);
        assert!((s.mean_depth_per_shard - 3.0).abs() < 1e-12, "{s:?}");
        assert_eq!(s.peak_depth, 8);
        assert_eq!(s.rejections, 2);
        assert_eq!(s.remote_pressure, 4);
        // Counters that stepped back (shrink moved a shard's tally
        // between the live and retired sums) saturate to zero.
        let back = [LoadSample { shards: 1, depth_total: 0, rejected_total: 7, remote_total: 0 }];
        let s = LoadSignal::from_window(&back, &prev);
        assert_eq!(s.rejections, 0);
        assert_eq!(s.remote_pressure, 0);
        // Empty windows are inert.
        let s = LoadSignal::from_window(&[], &prev);
        assert_eq!(s.mean_depth_per_shard, 0.0);
        assert_eq!(s.rejections, 0);
    }

    #[test]
    fn shard_group_labels_are_never_reused() {
        // Pure bookkeeping test (no coordinators): grow with a failing
        // factory burns the label but adds nothing — the next grow's
        // label is still fresh, so logs never alias two pipelines.
        let g = ShardGroup::new();
        g.install_initial(Vec::new());
        let mut seen = Vec::new();
        let r = g.grow("t", 4, |idx| {
            seen.push(idx);
            bail!("factory down")
        });
        assert!(r.is_err());
        let r = g.grow("t", 4, |idx| {
            seen.push(idx);
            bail!("factory still down")
        });
        assert!(r.is_err());
        assert_eq!(seen, vec![0, 1]);
        assert_eq!(g.stats(None).scale_ups, 0, "failed grows are not scale-ups");
        assert!(g.stats(Some((1, 4))).enabled);
        // At (or above) the cap, grow refuses *before* building an
        // engine — the factory must not run.
        let r = g.grow("t", 0, |_| unreachable!("capped grow must not build"));
        assert!(r.is_err());
        // An empty group refuses to shrink whatever the floor says.
        assert!(g.shrink("t", 0).is_err());
    }
}
