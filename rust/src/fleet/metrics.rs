//! Fleet-wide observability: per-shard snapshots rolled up per class,
//! per-class reports rolled up into one fleet total.

use crate::config::json::Json;
use crate::coordinator::MetricsSnapshot;
use crate::network::bandwidth::LinkModel;
use crate::network::encoding::WireEncoding;
use crate::server::ServerStatsSnapshot;

use super::autoscale::ScalerStats;
use super::class::LinkClass;

/// Planner-side observability for one class: what p it is planning
/// with, what the exit-rate estimator believes, and how hard the plan
/// cache / view-rebuild machinery is working.
#[derive(Debug, Clone, Default)]
pub struct ClassPlannerStats {
    /// Conditional exit probability of the current planner view (the
    /// first branch's; fleets serve single-branch manifests today).
    pub exit_prob_planned: f64,
    /// Online EWMA estimate p̂ of the observed exit rate; `None` when
    /// online estimation is disabled for the fleet.
    pub p_hat: Option<f64>,
    /// Branch-gate observations the estimator has consumed.
    pub estimator_observations: u64,
    /// Times the exit view was re-derived (estimator drift triggers or
    /// direct `set_exit_probs` calls).
    pub view_rebuilds: u64,
    /// Plan-cache hits / misses of the class planner.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Times a view swap flushed the class's plan cache.
    pub cache_invalidations: u64,
    /// Per-request plans rerouted through the branch-active probe split
    /// so the exit-rate estimator keeps observing (0 when probing is
    /// off or the solved splits already keep the branch active).
    pub probe_overrides: u64,
}

/// One link class's view: the active split, every shard's snapshot, and
/// their aggregate.
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub class: LinkClass,
    pub name: String,
    pub link: LinkModel,
    /// Active partition point (stages `1..=split_after` on the edge).
    pub split_after: usize,
    /// Full cut vector when the class routes through a K-tier chain
    /// (`cuts[0] == split_after`, remaining entries are the downstream
    /// tiers' cut points); `None` for plain two-tier serving.
    pub cuts: Option<Vec<usize>>,
    /// Activation wire encoding the class ships to its cloud stage (and
    /// that its planner prices the transfer term at).
    pub wire_encoding: WireEncoding,
    /// Effective cloud-stage endpoint: the class's own override, else
    /// the fleet-wide `cloud_addr`; `None` = in-process cloud.
    pub cloud_addr: Option<String>,
    pub planner: ClassPlannerStats,
    /// Shard-count elasticity: current/min/max shards, resize counters
    /// and the last trigger (`enabled = false` for a fixed fleet).
    pub scaler: ScalerStats,
    /// Instantaneous admission-queue depth per live shard, sampled when
    /// the report was taken — the signal the autoscaler keys on, so an
    /// operator can see *why* a resize fired (or is about to).
    pub queue_depths: Vec<usize>,
    /// Live shards' snapshots. The `aggregate` additionally folds in
    /// shards already retired by scale-downs, so class totals never
    /// lose completed work to elasticity.
    pub shards: Vec<MetricsSnapshot>,
    pub aggregate: MetricsSnapshot,
}

/// Point-in-time view of the whole fleet.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub classes: Vec<ClassReport>,
    pub total: MetricsSnapshot,
    /// Front-end connection counters of the `Server` registered with
    /// this fleet; `None` when the fleet is driven without one
    /// (library use, the scenario harness, tests).
    pub server: Option<ServerStatsSnapshot>,
}

impl FleetReport {
    pub fn from_classes(classes: Vec<ClassReport>) -> FleetReport {
        let aggregates: Vec<MetricsSnapshot> =
            classes.iter().map(|c| c.aggregate.clone()).collect();
        FleetReport {
            classes,
            total: MetricsSnapshot::aggregate(&aggregates),
            server: None,
        }
    }

    /// Multi-line human-readable report: one line per class, one total.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for c in &self.classes {
            let p_hat = match c.planner.p_hat {
                Some(p) => format!(", p̂ {:.3} ({} obs)", p, c.planner.estimator_observations),
                None => String::new(),
            };
            let scaler = if c.scaler.enabled {
                format!(
                    " in {}..={}, +{}/-{} resizes",
                    c.scaler.min_shards,
                    c.scaler.max_shards,
                    c.scaler.scale_ups,
                    c.scaler.scale_downs
                )
            } else {
                String::new()
            };
            let cloud = match &c.cloud_addr {
                Some(a) => format!(" -> {a}"),
                None => String::new(),
            };
            let cuts = match &c.cuts {
                Some(v) => format!(" (chain cuts {v:?})"),
                None => String::new(),
            };
            out.push_str(&format!(
                "[{} @ {:.2} Mbps, split after {}{}, wire {}{}, p {:.3}{}, {} shard(s){}] {}\n",
                c.name,
                c.link.uplink_mbps,
                c.split_after,
                cuts,
                c.wire_encoding,
                cloud,
                c.planner.exit_prob_planned,
                p_hat,
                c.shards.len(),
                scaler,
                c.aggregate.summary()
            ));
        }
        out.push_str(&format!("[fleet total] {}", self.total.summary()));
        if let Some(s) = &self.server {
            out.push_str(&format!(
                "\n[server] {} accepted, {} active (peak {}), {} throttled, {} shed",
                s.accepted, s.active, s.conn_peak, s.throttled, s.conns_shed
            ));
        }
        out
    }

    /// JSON with the same flat totals a single pipeline reports (so
    /// existing metrics consumers keep working) plus per-class detail.
    /// Both levels splice [`MetricsSnapshot::to_json`] rather than
    /// re-listing its fields, so the two formats cannot drift apart.
    pub fn to_json(&self) -> String {
        // "{...}" -> "..." for embedding in an enclosing object.
        let flat_fields = |s: &MetricsSnapshot| {
            s.to_json()
                .trim_start_matches('{')
                .trim_end_matches('}')
                .to_string()
        };
        let classes = self
            .classes
            .iter()
            .map(|c| {
                let p_hat = match c.planner.p_hat {
                    Some(p) => format!("{p:.6}"),
                    None => "null".to_string(),
                };
                let depths = c
                    .queue_depths
                    .iter()
                    .map(usize::to_string)
                    .collect::<Vec<_>>()
                    .join(",");
                let last_trigger = match &c.scaler.last_trigger {
                    Some(t) => Json::Str(t.clone()).to_string(),
                    None => "null".to_string(),
                };
                let cloud_addr = match &c.cloud_addr {
                    Some(a) => Json::Str(a.clone()).to_string(),
                    None => "null".to_string(),
                };
                let cuts = match &c.cuts {
                    Some(v) => format!(
                        "[{}]",
                        v.iter().map(usize::to_string).collect::<Vec<_>>().join(",")
                    ),
                    None => "null".to_string(),
                };
                format!(
                    "{{\"name\":{},\"split_after\":{},\"cuts\":{},\
                     \"wire_encoding\":\"{}\",\"cloud_addr\":{},\
                     \"shards\":{},\
                     \"queue_depths\":[{}],\
                     \"autoscale\":{{\"enabled\":{},\"min_shards\":{},\
                     \"max_shards\":{},\"retired_shards\":{},\"scale_ups\":{},\
                     \"scale_downs\":{},\"last_trigger\":{}}},\
                     \"exit_prob_planned\":{:.6},\"p_hat\":{},\
                     \"estimator_observations\":{},\"view_rebuilds\":{},\
                     \"cache_hits\":{},\"cache_misses\":{},\
                     \"cache_invalidations\":{},\"probe_overrides\":{},{}}}",
                    Json::Str(c.name.clone()),
                    c.split_after,
                    cuts,
                    c.wire_encoding,
                    cloud_addr,
                    c.shards.len(),
                    depths,
                    c.scaler.enabled,
                    c.scaler.min_shards,
                    c.scaler.max_shards,
                    c.scaler.retired_shards,
                    c.scaler.scale_ups,
                    c.scaler.scale_downs,
                    last_trigger,
                    c.planner.exit_prob_planned,
                    p_hat,
                    c.planner.estimator_observations,
                    c.planner.view_rebuilds,
                    c.planner.cache_hits,
                    c.planner.cache_misses,
                    c.planner.cache_invalidations,
                    c.planner.probe_overrides,
                    flat_fields(&c.aggregate),
                )
            })
            .collect::<Vec<_>>()
            .join(",");
        let server = match &self.server {
            Some(s) => format!(
                "{{\"accepted\":{},\"active\":{},\"conn_peak\":{},\
                 \"throttled\":{},\"conns_shed\":{}}}",
                s.accepted, s.active, s.conn_peak, s.throttled, s.conns_shed
            ),
            None => "null".to_string(),
        };
        format!(
            "{{{},\"server\":{},\"classes\":[{}]}}",
            flat_fields(&self.total),
            server,
            classes
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(completed: u64, latency: f64) -> MetricsSnapshot {
        let mut s = MetricsSnapshot::zero();
        s.completed = completed;
        s.elapsed_s = 2.0;
        s.throughput_rps = completed as f64 / 2.0;
        for _ in 0..completed {
            s.latency_hist.push(latency);
        }
        s.mean_latency_s = s.latency_hist.mean();
        s
    }

    fn report() -> FleetReport {
        let shards_a = vec![snap(3, 0.01), snap(1, 0.03)];
        let shards_b = vec![snap(0, 0.0)];
        FleetReport::from_classes(vec![
            ClassReport {
                class: LinkClass(0),
                name: "3G".into(),
                link: LinkModel::new(1.10, 0.0),
                split_after: 5,
                cuts: Some(vec![5, 7]),
                wire_encoding: WireEncoding::Q8,
                cloud_addr: Some("cloud.internal:7879".into()),
                planner: ClassPlannerStats {
                    exit_prob_planned: 0.35,
                    p_hat: Some(0.62),
                    estimator_observations: 4,
                    view_rebuilds: 2,
                    cache_hits: 10,
                    cache_misses: 3,
                    cache_invalidations: 2,
                    probe_overrides: 1,
                },
                scaler: ScalerStats {
                    enabled: true,
                    min_shards: 1,
                    max_shards: 4,
                    current_shards: 2,
                    retired_shards: 1,
                    scale_ups: 3,
                    scale_downs: 2,
                    last_trigger: Some("grow: 2 admission rejection(s) in window".into()),
                },
                queue_depths: vec![5, 0],
                aggregate: MetricsSnapshot::aggregate(&shards_a),
                shards: shards_a,
            },
            ClassReport {
                class: LinkClass(1),
                name: "WiFi".into(),
                link: LinkModel::new(18.80, 0.0),
                split_after: 0,
                cuts: None,
                wire_encoding: WireEncoding::Raw,
                cloud_addr: None,
                planner: ClassPlannerStats {
                    exit_prob_planned: 0.5,
                    ..Default::default()
                },
                scaler: ScalerStats {
                    min_shards: 1,
                    max_shards: 1,
                    current_shards: 1,
                    ..Default::default()
                },
                queue_depths: vec![0],
                aggregate: MetricsSnapshot::aggregate(&shards_b),
                shards: shards_b,
            },
        ])
    }

    #[test]
    fn totals_roll_up_across_classes() {
        let r = report();
        assert_eq!(r.total.completed, 4);
        assert_eq!(r.classes[0].aggregate.completed, 4);
        assert_eq!(r.classes[1].aggregate.completed, 0);
        // The idle class contributes zeros, never NaN.
        assert_eq!(r.classes[1].aggregate.mean_latency_s, 0.0);
        let s = r.summary();
        assert!(s.contains("3G") && s.contains("WiFi") && s.contains("fleet total"));
        assert!(!s.contains("NaN"), "{s}");
    }

    #[test]
    fn server_counters_splice_into_json_and_summary() {
        let mut r = report();
        // Fleet driven without a front-end server: explicit null.
        let v = Json::parse(&r.to_json()).unwrap();
        assert!(matches!(v.get("server"), Some(Json::Null)));
        assert!(!r.summary().contains("[server]"));
        r.server = Some(ServerStatsSnapshot {
            accepted: 100,
            active: 7,
            conn_peak: 42,
            throttled: 9,
            conns_shed: 3,
        });
        let v = Json::parse(&r.to_json()).unwrap();
        let s = v.get("server").unwrap();
        assert_eq!(s.get("accepted").unwrap().as_u64(), Some(100));
        assert_eq!(s.get("active").unwrap().as_u64(), Some(7));
        assert_eq!(s.get("conn_peak").unwrap().as_u64(), Some(42));
        assert_eq!(s.get("throttled").unwrap().as_u64(), Some(9));
        assert_eq!(s.get("conns_shed").unwrap().as_u64(), Some(3));
        let text = r.summary();
        assert!(
            text.contains("[server] 100 accepted, 7 active (peak 42), 9 throttled, 3 shed"),
            "{text}"
        );
    }

    #[test]
    fn json_is_parseable_with_flat_totals_and_class_detail() {
        let j = report().to_json();
        let v = Json::parse(&j).unwrap();
        assert_eq!(v.get("completed").unwrap().as_u64(), Some(4));
        let classes = v.get("classes").unwrap().as_arr().unwrap();
        assert_eq!(classes.len(), 2);
        assert_eq!(classes[0].get("name").unwrap().as_str(), Some("3G"));
        assert_eq!(classes[0].get("split_after").unwrap().as_u64(), Some(5));
        assert_eq!(classes[1].get("completed").unwrap().as_u64(), Some(0));
        // Wire path: encoding always present; cloud_addr null when the
        // cloud half runs in-process.
        assert_eq!(classes[0].get("wire_encoding").unwrap().as_str(), Some("q8"));
        assert_eq!(
            classes[0].get("cloud_addr").unwrap().as_str(),
            Some("cloud.internal:7879")
        );
        assert_eq!(classes[1].get("wire_encoding").unwrap().as_str(), Some("raw"));
        assert!(matches!(classes[1].get("cloud_addr"), Some(Json::Null)));
        // Chain cut vectors: the full vector for chain-routed classes,
        // explicit null (not []) for plain two-tier serving.
        let cuts = classes[0].get("cuts").unwrap().as_arr().unwrap();
        assert_eq!(cuts.len(), 2);
        assert_eq!(cuts[0].as_u64(), Some(5));
        assert_eq!(cuts[1].as_u64(), Some(7));
        assert!(matches!(classes[1].get("cuts"), Some(Json::Null)));
        // Planner observability: planned p, estimated p̂, cache and
        // view-rebuild counters, all per class.
        let p0 = &classes[0];
        assert!(
            (p0.get("exit_prob_planned").unwrap().as_f64().unwrap() - 0.35).abs() < 1e-9
        );
        assert!((p0.get("p_hat").unwrap().as_f64().unwrap() - 0.62).abs() < 1e-9);
        assert_eq!(p0.get("estimator_observations").unwrap().as_u64(), Some(4));
        assert_eq!(p0.get("view_rebuilds").unwrap().as_u64(), Some(2));
        assert_eq!(p0.get("cache_hits").unwrap().as_u64(), Some(10));
        assert_eq!(p0.get("cache_misses").unwrap().as_u64(), Some(3));
        assert_eq!(p0.get("cache_invalidations").unwrap().as_u64(), Some(2));
        assert_eq!(p0.get("probe_overrides").unwrap().as_u64(), Some(1));
        // Estimation off: p_hat is JSON null, not 0 (an estimate of 0
        // and "no estimate" are different facts).
        assert!(matches!(classes[1].get("p_hat"), Some(Json::Null)));
        // Per-shard queue depths: the signal a resize keyed on must be
        // visible to operators, one entry per live shard.
        let depths = p0.get("queue_depths").unwrap().as_arr().unwrap();
        assert_eq!(depths.len(), 2);
        assert_eq!(depths[0].as_u64(), Some(5));
        assert_eq!(depths[1].as_u64(), Some(0));
        // Scaler observability nests under "autoscale".
        let scaler = p0.get("autoscale").unwrap();
        assert_eq!(scaler.get("enabled").unwrap().as_bool(), Some(true));
        assert_eq!(scaler.get("min_shards").unwrap().as_u64(), Some(1));
        assert_eq!(scaler.get("max_shards").unwrap().as_u64(), Some(4));
        assert_eq!(scaler.get("retired_shards").unwrap().as_u64(), Some(1));
        assert_eq!(scaler.get("scale_ups").unwrap().as_u64(), Some(3));
        assert_eq!(scaler.get("scale_downs").unwrap().as_u64(), Some(2));
        assert!(scaler
            .get("last_trigger")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("rejection"));
        // Fixed fleet: enabled false, last trigger null (not "").
        let fixed = classes[1].get("autoscale").unwrap();
        assert_eq!(fixed.get("enabled").unwrap().as_bool(), Some(false));
        assert!(matches!(fixed.get("last_trigger"), Some(Json::Null)));
        // And the human summary surfaces p̂ only where it exists, plus
        // the resize counters only for elastic classes.
        let s = report().summary();
        assert!(s.contains("p̂ 0.620"), "{s}");
        assert!(s.contains("p 0.500"), "{s}");
        assert!(s.contains("in 1..=4, +3/-2 resizes"), "{s}");
        assert!(s.contains("wire q8 -> cloud.internal:7879"), "{s}");
        assert!(s.contains("wire raw,"), "{s}");
        assert!(s.contains("split after 5 (chain cuts [5, 7])"), "{s}");
        assert!(
            !s.contains("WiFi @ 18.80 Mbps, split after 0, wire raw, p 0.500, 1 shard(s) in"),
            "{s}"
        );
    }
}
