//! Fleet-wide observability: per-shard snapshots rolled up per class,
//! per-class reports rolled up into one fleet total.

use crate::config::json::Json;
use crate::coordinator::MetricsSnapshot;
use crate::network::bandwidth::LinkModel;

use super::class::LinkClass;

/// One link class's view: the active split, every shard's snapshot, and
/// their aggregate.
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub class: LinkClass,
    pub name: String,
    pub link: LinkModel,
    /// Active partition point (stages `1..=split_after` on the edge).
    pub split_after: usize,
    pub shards: Vec<MetricsSnapshot>,
    pub aggregate: MetricsSnapshot,
}

/// Point-in-time view of the whole fleet.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub classes: Vec<ClassReport>,
    pub total: MetricsSnapshot,
}

impl FleetReport {
    pub fn from_classes(classes: Vec<ClassReport>) -> FleetReport {
        let aggregates: Vec<MetricsSnapshot> =
            classes.iter().map(|c| c.aggregate.clone()).collect();
        FleetReport {
            classes,
            total: MetricsSnapshot::aggregate(&aggregates),
        }
    }

    /// Multi-line human-readable report: one line per class, one total.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for c in &self.classes {
            out.push_str(&format!(
                "[{} @ {:.2} Mbps, split after {}, {} shard(s)] {}\n",
                c.name,
                c.link.uplink_mbps,
                c.split_after,
                c.shards.len(),
                c.aggregate.summary()
            ));
        }
        out.push_str(&format!("[fleet total] {}", self.total.summary()));
        out
    }

    /// JSON with the same flat totals a single pipeline reports (so
    /// existing metrics consumers keep working) plus per-class detail.
    /// Both levels splice [`MetricsSnapshot::to_json`] rather than
    /// re-listing its fields, so the two formats cannot drift apart.
    pub fn to_json(&self) -> String {
        // "{...}" -> "..." for embedding in an enclosing object.
        let flat_fields = |s: &MetricsSnapshot| {
            s.to_json()
                .trim_start_matches('{')
                .trim_end_matches('}')
                .to_string()
        };
        let classes = self
            .classes
            .iter()
            .map(|c| {
                format!(
                    "{{\"name\":{},\"split_after\":{},\"shards\":{},{}}}",
                    Json::Str(c.name.clone()),
                    c.split_after,
                    c.shards.len(),
                    flat_fields(&c.aggregate),
                )
            })
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{{},\"classes\":[{}]}}",
            flat_fields(&self.total),
            classes
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(completed: u64, latency: f64) -> MetricsSnapshot {
        let mut s = MetricsSnapshot::zero();
        s.completed = completed;
        s.elapsed_s = 2.0;
        s.throughput_rps = completed as f64 / 2.0;
        for _ in 0..completed {
            s.latency_hist.push(latency);
        }
        s.mean_latency_s = s.latency_hist.mean();
        s
    }

    fn report() -> FleetReport {
        let shards_a = vec![snap(3, 0.01), snap(1, 0.03)];
        let shards_b = vec![snap(0, 0.0)];
        FleetReport::from_classes(vec![
            ClassReport {
                class: LinkClass(0),
                name: "3G".into(),
                link: LinkModel::new(1.10, 0.0),
                split_after: 5,
                aggregate: MetricsSnapshot::aggregate(&shards_a),
                shards: shards_a,
            },
            ClassReport {
                class: LinkClass(1),
                name: "WiFi".into(),
                link: LinkModel::new(18.80, 0.0),
                split_after: 0,
                aggregate: MetricsSnapshot::aggregate(&shards_b),
                shards: shards_b,
            },
        ])
    }

    #[test]
    fn totals_roll_up_across_classes() {
        let r = report();
        assert_eq!(r.total.completed, 4);
        assert_eq!(r.classes[0].aggregate.completed, 4);
        assert_eq!(r.classes[1].aggregate.completed, 0);
        // The idle class contributes zeros, never NaN.
        assert_eq!(r.classes[1].aggregate.mean_latency_s, 0.0);
        let s = r.summary();
        assert!(s.contains("3G") && s.contains("WiFi") && s.contains("fleet total"));
        assert!(!s.contains("NaN"), "{s}");
    }

    #[test]
    fn json_is_parseable_with_flat_totals_and_class_detail() {
        let j = report().to_json();
        let v = Json::parse(&j).unwrap();
        assert_eq!(v.get("completed").unwrap().as_u64(), Some(4));
        let classes = v.get("classes").unwrap().as_arr().unwrap();
        assert_eq!(classes.len(), 2);
        assert_eq!(classes[0].get("name").unwrap().as_str(), Some("3G"));
        assert_eq!(classes[0].get("split_after").unwrap().as_u64(), Some(5));
        assert_eq!(classes[1].get("completed").unwrap().as_u64(), Some(0));
    }
}
