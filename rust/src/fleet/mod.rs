//! The sharded multi-class serving fleet: per-link-class planners behind
//! a routing coordinator of coordinators.
//!
//! # Why this exists
//!
//! The paper's optimal partition depends on the *link* (Eq. 5's
//! `alpha_s/B + rtt` term is the only link-dependent part), so a
//! deployment serving a mixed client population cannot hold one plan: a
//! 3G client's optimum keeps work on the edge while a WiFi client's
//! ships it to the cloud. Neurosurgeon-style per-condition partitioning
//! and Edgent's on-demand co-inference both put plan selection at
//! request admission, per link profile — this module is that seam, plus
//! horizontal scale.
//!
//! # Shape
//!
//! ```text
//!              FleetRouter (round-robin / hash / least-loaded)
//! request ──class tag──► ClassGroup[c] ──pick shard──► Coordinator
//!                         │                            (batcher → edge worker
//!                         │                             → channel → M cloud workers)
//!                         ├── ClassPlanner[c]: Planner fork (shared prefix
//!                         │     sums, per-class PlanCache)
//!                         ├── Channel[c]: the class's uplink (constant or
//!                         │     trace-driven)
//!                         ├── AdaptivePlanner[c] (optional): hysteresis
//!                         │     replan loop driving set_plan on every
//!                         │     shard of the class
//!                         └── Autoscaler[c] (optional): control loop
//!                               growing/shrinking the class's ShardGroup
//!                               from queue-depth and rejection signals
//! ```
//!
//! * **Classes own base plans; requests may override.** Every shard of
//!   a class runs the class's base partition plan, computed by that
//!   class's [`ClassPlanner`] and — when adaptive replanning is on —
//!   refreshed from the class channel's live bandwidth with the planner
//!   subsystem's hysteresis (see [`crate::planner::adaptive`]). With
//!   `per_request_planning` enabled, [`Fleet::submit`] additionally
//!   solves each sample's split at the channel's *instantaneous* link
//!   estimate (an O(1) epoch-checked cache lookup in the common case)
//!   and attaches it as a per-request plan override — so two requests
//!   admitted moments apart under a moving uplink execute different
//!   splits, without waiting for an adaptive-replan boundary.
//! * **Sharding is per class.** A class group holds N independent
//!   [`Coordinator`] pipelines (each its own batcher, edge worker and M
//!   cloud workers); the [`FleetRouter`] picks one per request. This
//!   scales the serving path horizontally without touching coordinator
//!   internals — the edge worker groups each batch by effective split,
//!   so overridden and default samples coexist safely.
//! * **Shard groups are elastic.** The shard set is a live
//!   [`ShardGroup`] every consumer — routing, plan pushes, metrics —
//!   reads consistently mid-resize. With autoscaling enabled, a
//!   per-class [`Autoscaler`] control loop samples the signals the
//!   fleet already produces (per-shard admission-queue depth, admission
//!   rejections, remote-cloud saturation) into a windowed
//!   [`LoadSignal`] and drives [`ShardGroup::grow`] /
//!   [`ShardGroup::shrink`] between `min_shards..=max_shards` with
//!   hysteresis and a cooldown. Growing forks a new [`Coordinator`]
//!   from the class's shared planner core at the current plan;
//!   shrinking drains the victim before its workers join, so no
//!   admitted request is ever dropped.
//! * **One p-independent precompute, one view per class.** Every class
//!   shares a single `StaticCore` (the p-independent planner layer) via
//!   [`Planner::with_exit_probs`]; each class's survival-weighted view
//!   is derived in one O(N·m) pass — including classes with an
//!   `exit_probability` override, which used to pay a full fresh
//!   precompute.
//! * **Exit rates feed back.** With `estimation` enabled, every shard's
//!   branch gate reports exit/survive observations to the class's
//!   [`ExitRateEstimator`]; when the EWMA p̂ drifts beyond the
//!   configured threshold, the class planner's view is re-derived at p̂
//!   (epoch-invalidating its plan cache) and the new plan is pushed to
//!   every shard — the configured prior stops mattering once traffic
//!   speaks for itself. Exit behaviour is only observable while the
//!   executed split keeps the branch active, so once feedback moves a
//!   class to a split at or before the branch (e.g. cloud-only) the
//!   gate goes silent; `probe_fraction` keeps the estimator alive by
//!   rerouting a small fraction of such requests through the smallest
//!   branch-active split (riding on per-request overrides), which is
//!   what lets p̂ recover *upward* after an overshoot.
//! * **The cloud half can be another machine.** With `cloud_addr` set,
//!   every shard's cloud worker ships its transferred split-groups as
//!   sequence-tagged INFER_PARTIAL frames to a remote cloud-stage
//!   server ([`crate::server::CloudStageServer`]) through a pipelined
//!   [`RemoteCloudEngine`] (pooled connections, many in-flight frames
//!   per connection, reconnect with backoff, in-flight cap); remote
//!   failures fall back to the shard's local engine and are counted in
//!   the metrics. A class may override the endpoint with its own
//!   `cloud_addr` (geo-split fleets keep each class's suffix stages
//!   near its clients); classes sharing an endpoint share one engine —
//!   and its connection pool — via an address-keyed dedup map.
//! * **Activations cross the wire encoded.** `wire_encoding` picks the
//!   transfer codec (raw f32 / q8 / q4); the remote engine encodes,
//!   the cloud stage dequantizes, the simulated channel charges the
//!   encoded size, and every class planner prices its transfer term at
//!   the same [`WireEncoding::payload_bytes`] map — so the optimum the
//!   fleet plans is the optimum of the bytes it actually ships.
//! * **The cloud half can be a chain.** With `tier_chain` set, each
//!   class's planner solves a full cut *vector* over the K-tier chain
//!   at startup ([`Planner::plan_chain`]): the edge runs `1..=cuts[0]`
//!   and ships sequence-tagged INFER_CHAIN frames to the chain head,
//!   which runs its own segment and forwards the remainder onward
//!   (`cloud-serve --forward-addr`). If the chain head fails, the
//!   group degrades to a direct single-hop offload against the
//!   terminal tier at the *same* edge split (counted per shard as
//!   `chain_fallbacks`), and only then to the shard's local engine —
//!   no admitted request is dropped at any rung.
//! * **Observability rolls up.** [`FleetReport`]: per-shard
//!   [`MetricsSnapshot`]s → per-class aggregate → fleet total, all
//!   NaN-free even for shards that served nothing — plus per-class
//!   planner stats (planned p, estimated p̂, cache hit/miss/invalidation,
//!   view-rebuild and probe counters).

pub mod autoscale;
pub mod class;
pub mod metrics;
pub mod planner;
pub mod router;

pub use autoscale::{
    AutoscaleConfig, Autoscaler, AutoscalerHandle, LoadSample, LoadSignal, ScaleDecision,
    ScalerStats, ShardGroup,
};
pub use class::{ClassProfile, ClassRegistry, LinkClass};
pub use metrics::{ClassPlannerStats, ClassReport, FleetReport};
pub use planner::ClassPlanner;
pub use router::{FleetRouter, RoutePolicy};

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::config::settings::Strategy;
use crate::network::bandwidth::LinkModel;
use crate::coordinator::{
    AdmitError, ChainRoute, CloudExec, Coordinator, CoordinatorConfig, ExitObserver,
    InferenceResponse, MetricsSnapshot, ReplyTo,
};
use crate::model::Manifest;
use crate::network::trace::BandwidthTrace;
use crate::network::{Channel, WireEncoding};
use crate::partition::plan::PartitionPlan;
use crate::planner::joint::accuracy_proxy;
use crate::planner::{
    AdaptiveConfig, AdaptiveHandle, AdaptivePlanner, EstimatorConfig, ExitRateEstimator,
    JointSearchSpace, Planner, TierChain,
};
use crate::runtime::{HostTensor, InferenceEngine};
use crate::server::remote::{RemoteCloudConfig, RemoteCloudEngine, RemoteCloudStats};
use crate::server::{ServeBackend, ServerStats, Submission};
use crate::timing::DelayProfile;

/// Typed fleet admission failure, for front ends that must map
/// backpressure to a protocol THROTTLE frame and everything else to an
/// ERROR. The blocking [`Fleet::submit`] path derives its string errors
/// from these, so the two can't drift.
#[derive(Debug)]
pub enum AdmitRejection {
    /// The picked shard's admission queue is full — transient; the
    /// client should back off and retry.
    Busy,
    /// Terminal: unknown class, or the shard is shut down.
    Failed(anyhow::Error),
}

/// One tier beyond the edge in a K-tier partition chain. Order matters:
/// the first spec is the chain head the edge ships to, the last is the
/// terminal tier that finishes every still-deferred sample.
#[derive(Debug, Clone)]
pub struct TierSpec {
    /// `HOST:PORT` of this tier's cloud-stage server.
    pub addr: String,
    /// Uplink from *this* tier to the *next* one, Mbit/s. Required on
    /// every tier but the last; hop 0 — edge to chain head — is each
    /// class's own modeled link, so it is never specified here.
    pub uplink_mbps: Option<f64>,
    /// RTT of the hop to the next tier, seconds.
    pub rtt_s: Option<f64>,
    /// Per-stage compute time of this tier relative to the profiled
    /// cloud (2.0 = half as fast, 1.0 = identical hardware). Must be
    /// finite and positive.
    pub compute_scale: f64,
}

#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Edge/cloud pipeline pairs per link class.
    pub shards_per_class: usize,
    /// Cloud worker threads per shard (sharing the shard's transfer queue).
    pub cloud_workers_per_shard: usize,
    pub routing: RoutePolicy,
    /// Entropy gate for the side branch, nats.
    pub entropy_threshold: f32,
    pub max_batch: usize,
    pub batch_timeout: Duration,
    pub queue_capacity: usize,
    /// Planning exit probability for classes without an override.
    pub default_exit_prob: f64,
    /// The paper's epsilon tie-breaker (§V).
    pub epsilon: f64,
    /// When set, every class runs a hysteresis replan loop against its
    /// channel's live bandwidth, pushing accepted plans to all shards.
    pub adaptive: Option<AdaptiveConfig>,
    /// When set, every class runs an [`Autoscaler`] control loop that
    /// grows/shrinks its shard group between
    /// `min_shards..=max_shards` from queue-depth and rejection
    /// signals. `shards_per_class` is the starting size and must lie
    /// within that range. A class may override the bounds via
    /// [`ClassProfile::min_shards`] / [`ClassProfile::max_shards`].
    pub autoscale: Option<AutoscaleConfig>,
    /// Enforce the autoscale bounds and the shard budget but do *not*
    /// spawn the per-class control loops: an external driver (the
    /// scenario harness) samples [`Fleet::load_sample_of`] and executes
    /// decisions through [`Fleet::grow_class_triggered`] /
    /// [`Fleet::shrink_class_triggered`] on its own clock. Ignored when
    /// `autoscale` is `None`.
    pub autoscale_external: bool,
    /// Fleet-wide shard budget: the sum of live shards across every
    /// class may never exceed this, whatever the per-class ceilings
    /// would individually allow. A grow that would bust it is denied
    /// and the class's `last_trigger` records the budget denial.
    /// `None` = unbounded.
    pub max_total_shards: Option<usize>,
    /// When set, every class tracks its observed exit rate (EWMA over
    /// branch-gate decisions) and re-derives its planner view — and its
    /// shards' plans — when the estimate drifts beyond the configured
    /// threshold.
    pub estimation: Option<EstimatorConfig>,
    /// Solve each request's split at the channel's instantaneous link
    /// estimate and attach it as a per-request plan override, instead
    /// of only replanning at adaptive boundaries.
    pub per_request_planning: bool,
    /// Exit-rate probing (requires `per_request_planning`): route this
    /// fraction of requests whose solved split would keep the side
    /// branch *inactive* through the smallest branch-active split
    /// instead, so the branch gate keeps producing observations. This
    /// is how online estimation recovers *upward*: once feedback moves
    /// a class to a split at or before the branch, the gate stops
    /// firing and p̂ would otherwise freeze there forever. 0 = off.
    pub probe_fraction: f64,
    /// When set (`HOST:PORT`), every shard's cloud worker ships its
    /// transferred split-groups to this remote cloud-stage server
    /// (`branchyserve cloud-serve`) instead of running them in-process;
    /// the shard's own cloud engine becomes the fallback for remote
    /// failures. A class's [`ClassProfile::cloud_addr`] overrides this
    /// per class; classes resolving to the same endpoint share one
    /// pooled connection set.
    pub cloud_addr: Option<String>,
    /// When non-empty, the cloud half is a *chain* of tiers rather than
    /// one endpoint: each class's planner solves a full cut vector over
    /// the layered K-tier graph at startup ([`Planner::plan_chain`],
    /// hop 0 = the class's own link) and its shards ship chain frames
    /// to the first tier, which runs its segment and forwards the rest
    /// (`cloud-serve --forward-addr`). Mutually exclusive with
    /// `cloud_addr`, per-class endpoint overrides, and the replanning
    /// knobs (`adaptive`, `estimation`, `per_request_planning`,
    /// `probe_fraction`): chain cut vectors are solved once and fixed.
    pub tier_chain: Vec<TierSpec>,
    /// Wire encoding of activations shipped to remote cloud stages
    /// (raw f32 / q8 / q4). Also the encoding every class planner
    /// prices its transfer term at and the simulated channel charges,
    /// so planned and shipped bytes agree.
    pub wire_encoding: WireEncoding,
    /// Run [`Planner::plan_joint`] per class at startup (branch set
    /// held fixed at the manifest's) and adopt the winning wire
    /// encoding + split for that class's planner and shards. A class
    /// may override via [`ClassProfile::joint_search`].
    pub joint_search: bool,
    /// Accuracy-proxy floor handed to the startup joint search
    /// (survival mass of the deferred path); 0 disables pruning.
    pub min_accuracy_proxy: f64,
    /// Multiplicative jitter stddev on the class channels (0 = none).
    pub channel_jitter: f64,
    /// False = channels account delays without sleeping (tests/benches).
    pub real_time_channel: bool,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            shards_per_class: 1,
            cloud_workers_per_shard: 1,
            routing: RoutePolicy::LeastLoaded,
            entropy_threshold: 0.3,
            max_batch: 8,
            batch_timeout: Duration::from_millis(2),
            queue_capacity: 1024,
            default_exit_prob: 0.5,
            epsilon: 1e-9,
            adaptive: None,
            autoscale: None,
            autoscale_external: false,
            max_total_shards: None,
            estimation: None,
            per_request_planning: false,
            probe_fraction: 0.0,
            cloud_addr: None,
            tier_chain: Vec::new(),
            wire_encoding: WireEncoding::Raw,
            joint_search: false,
            min_accuracy_proxy: 0.0,
            channel_jitter: 0.0,
            real_time_channel: true,
        }
    }
}

/// Builds one shard of a class on demand: the autoscaler's grow path
/// and `Fleet::grow_class` both go through this, so a grown shard is
/// provisioned exactly like a startup one (same engine factory, same
/// remote/observer wiring) and starts on the class's *current* plan.
type SpawnShard = Arc<dyn Fn(u64) -> Result<Arc<Coordinator>> + Send + Sync>;

/// What a triggered grow did. The scenario harness asserts on denials
/// (a diurnal peak *should* hit the budget), so both denial kinds are
/// ordinary outcomes, not errors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GrowOutcome {
    /// A shard was added; carries the new shard count.
    Grew(usize),
    /// Denied by the class's own `max_shards` ceiling.
    AtClassCap,
    /// Denied by the fleet-wide `max_total_shards` budget.
    AtBudget,
}

/// The fleet-wide shard budget, shared by every class's grow/shrink
/// path (autoscaler decisions, manual resizes, harness triggers). A
/// grow reserves a slot *before* building an engine and returns it if
/// the grow fails; a shrink releases its victim's slot.
struct ShardBudget {
    cap: usize,
    used: AtomicUsize,
}

impl ShardBudget {
    fn try_acquire(&self) -> bool {
        self.used
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
                (n < self.cap).then_some(n + 1)
            })
            .is_ok()
    }

    fn release(&self) {
        self.used.fetch_sub(1, Ordering::Relaxed);
    }

    fn denial(&self) -> String {
        format!("budget: fleet max_total_shards ({}) reached", self.cap)
    }
}

/// Grow `group` through the budget (if any): reserve a slot, build and
/// install the shard, return the slot on failure. A budget denial is
/// recorded as the group's `last_trigger` — it answers "why didn't
/// this class scale?" just like a resize answers "why did it?".
fn grow_with_budget(
    group: &ShardGroup,
    budget: Option<&ShardBudget>,
    trigger: &str,
    cap: usize,
    spawn: &(dyn Fn(u64) -> Result<Arc<Coordinator>> + Send + Sync),
) -> Result<usize> {
    if let Some(b) = budget {
        if !b.try_acquire() {
            let msg = b.denial();
            group.note_trigger(&msg);
            bail!("grow denied — {msg}");
        }
    }
    match group.grow(trigger, cap, spawn) {
        Ok(n) => Ok(n),
        Err(e) => {
            if let Some(b) = budget {
                b.release();
            }
            Err(e)
        }
    }
}

fn shrink_with_budget(
    group: &ShardGroup,
    budget: Option<&ShardBudget>,
    trigger: &str,
    floor: usize,
) -> Result<usize> {
    let n = group.shrink(trigger, floor)?;
    if let Some(b) = budget {
        b.release();
    }
    Ok(n)
}

/// A class's solved K-tier chain route, fixed at fleet start (the
/// replanning knobs are rejected in chain mode, so nothing moves it).
struct ClassChainState {
    /// Hop links *beyond* hop 0. Hop 0 is whatever link the class is
    /// priced at — kept out so [`Fleet::chain_expected_time_of`] can
    /// re-price the fixed cuts under a moved first hop.
    links_tail: Vec<LinkModel>,
    /// Per-tier compute scale, aligned with the chain's hops.
    scales: Vec<f64>,
    /// The full solved cut vector; `cuts[0]` is the edge split.
    cuts: Arc<Vec<usize>>,
    /// `cuts[1..]` — the tail every shard stamps on its chain frames.
    tail: Arc<Vec<usize>>,
    /// The edge-side plan at `cuts[0]`, priced at the whole chain's
    /// expected time.
    base_plan: PartitionPlan,
}

struct ClassGroup {
    profile: ClassProfile,
    /// Effective cloud endpoint (the class's override, else the
    /// fleet-wide default); `None` = in-process cloud.
    cloud_addr: Option<String>,
    /// `Arc`: the exit-observer closures running on shard edge-worker
    /// threads hold the same planner to rebuild its view on drift.
    planner: Arc<ClassPlanner>,
    /// The class's exit-rate tracker (None = estimation disabled).
    estimator: Option<Arc<Mutex<ExitRateEstimator>>>,
    channel: Arc<Channel>,
    /// The live, elastic shard set. `Arc`: the exit observer and the
    /// adaptive replan loop push plans to whatever shards are live at
    /// push time, and the autoscaler resizes it — all from shard/loop
    /// threads. Emptied (via `drain_all`) at shutdown, which also breaks
    /// the group → shard → worker-closure → group reference cycle.
    shards: Arc<ShardGroup>,
    spawn_shard: SpawnShard,
    /// This class's remote cloud client (shared with siblings on the
    /// same endpoint); `None` = in-process cloud. Kept so external
    /// drivers can sample remote pressure per class.
    remote: Option<Arc<RemoteCloudEngine>>,
    /// Active autoscale bounds — the fleet defaults with this class's
    /// overrides applied — kept for cap/floor enforcement and
    /// `ScalerStats` reporting (`None` = fixed-size shard set).
    autoscale: Option<AutoscaleConfig>,
    /// Per-group router: each class keeps its own round-robin cursor so
    /// correlated cross-class arrival patterns can't alias with the
    /// shard count and pin a class to one shard.
    router: FleetRouter,
    adaptive: Option<AdaptiveHandle>,
    autoscaler: Option<AutoscalerHandle>,
    /// The codec this class's planner prices and its shards ship at:
    /// the fleet-wide `wire_encoding`, unless the startup joint search
    /// adopted a better one for this class's link.
    wire_encoding: WireEncoding,
    /// Requests considered for exit-rate probing (solved split kept the
    /// branch inactive while probing was enabled).
    probe_counter: AtomicU64,
    /// Requests actually rerouted through the branch-active probe split.
    probe_overrides: AtomicU64,
    /// The class's solved chain route; `None` without a tier chain.
    chain: Option<ClassChainState>,
}

impl ClassGroup {
    fn scaler_stats(&self) -> ScalerStats {
        self.shards
            .stats(self.autoscale.as_ref().map(|a| (a.min_shards, a.max_shards)))
    }

    fn planner_stats(&self) -> ClassPlannerStats {
        let (cache_hits, cache_misses) = self.planner.cache_stats();
        let (p_hat, estimator_observations) = match &self.estimator {
            Some(est) => {
                let est = est.lock().unwrap();
                (Some(est.p_hat()), est.observations())
            }
            None => (None, 0),
        };
        ClassPlannerStats {
            exit_prob_planned: self.planner.exit_probs().first().copied().unwrap_or(0.0),
            p_hat,
            estimator_observations,
            view_rebuilds: self.planner.view_rebuilds(),
            cache_hits,
            cache_misses,
            cache_invalidations: self.planner.cache_invalidations(),
            probe_overrides: self.probe_overrides.load(Ordering::Relaxed),
        }
    }
}

/// Probing parameters, resolved once at fleet start: every `every`-th
/// branch-inactive per-request plan is rerouted through `split` (the
/// smallest branch-active split — minimal extra edge work for one gate
/// observation).
struct ProbeConfig {
    every: u64,
    split: usize,
}

/// A running fleet. `Send + Sync`; share it behind an [`Arc`] (the TCP
/// front-end does) and call [`Fleet::shutdown`] once every other handle
/// is gone.
pub struct Fleet {
    registry: ClassRegistry,
    groups: Vec<ClassGroup>,
    per_request_planning: bool,
    probe: Option<ProbeConfig>,
    /// 1-based position of the manifest's side branch.
    branch_pos: usize,
    /// One remote cloud client per distinct configured endpoint
    /// (fleet-wide and per-class overrides, deduped by address).
    remotes: Vec<Arc<RemoteCloudEngine>>,
    /// The chain-head tier's client(s) (subset of `remotes`), so the
    /// scenario harness can brown out just the middle tier while the
    /// terminal endpoint — the degraded direct path — stays up.
    tier_heads: Vec<Arc<RemoteCloudEngine>>,
    /// The activation transfer codec every engine/planner was built at.
    wire_encoding: WireEncoding,
    /// Fleet-wide shard budget; `None` = unbounded.
    budget: Option<Arc<ShardBudget>>,
    route_key: AtomicU64,
    /// Counters of the front-end `Server` currently serving this fleet
    /// (registered at server start); spliced into the report JSON so
    /// one metrics read covers the whole ingress path.
    server_stats: Mutex<Option<Arc<ServerStats>>>,
}

impl Fleet {
    /// Start `registry.len() × cfg.shards_per_class` pipelines.
    /// `make_engines(label)` provisions one shard's (edge, cloud) engine
    /// pair — e.g. `InferenceEngine::open` twice on the PJRT backend, or
    /// [`InferenceEngine::open_sim`] for the simulated one. `profile`
    /// carries the measured per-stage delays the planners sweep over.
    ///
    /// The factory is retained for the fleet's lifetime (hence `Send +
    /// Sync + 'static`): autoscaling and [`Fleet::grow_class`] provision
    /// new shards through it long after startup.
    pub fn start(
        registry: ClassRegistry,
        manifest: &Manifest,
        profile: &DelayProfile,
        cfg: FleetConfig,
        make_engines: impl Fn(&str) -> Result<(InferenceEngine, InferenceEngine)>
            + Send
            + Sync
            + 'static,
    ) -> Result<Fleet> {
        let make_engines: Arc<
            dyn Fn(&str) -> Result<(InferenceEngine, InferenceEngine)> + Send + Sync,
        > = Arc::new(make_engines);
        if cfg.shards_per_class == 0 || cfg.shards_per_class > 64 {
            bail!("shards_per_class must be in 1..=64; got {}", cfg.shards_per_class);
        }
        if let Some(acfg) = &cfg.autoscale {
            acfg.validate()?;
            if !(acfg.min_shards..=acfg.max_shards).contains(&cfg.shards_per_class) {
                bail!(
                    "shards_per_class ({}) must lie within the autoscale range {}..={}",
                    cfg.shards_per_class,
                    acfg.min_shards,
                    acfg.max_shards
                );
            }
        }
        if let Some(cap) = cfg.max_total_shards {
            let starting = registry.len() * cfg.shards_per_class;
            if cap < starting {
                bail!(
                    "max_total_shards ({cap}) is below the starting fleet size \
                     ({} class(es) x {} shard(s) = {starting})",
                    registry.len(),
                    cfg.shards_per_class
                );
            }
        }
        if cfg.cloud_workers_per_shard == 0 || cfg.cloud_workers_per_shard > 64 {
            bail!(
                "cloud_workers_per_shard must be in 1..=64; got {}",
                cfg.cloud_workers_per_shard
            );
        }
        if !(0.0..=1.0).contains(&cfg.probe_fraction) {
            bail!(
                "probe_fraction must be in [0, 1]; got {}",
                cfg.probe_fraction
            );
        }
        if cfg.probe_fraction > 0.0 && !cfg.per_request_planning {
            bail!("probe_fraction requires per_request_planning (probes ride on overrides)");
        }
        if !(cfg.min_accuracy_proxy.is_finite()
            && (0.0..=1.0).contains(&cfg.min_accuracy_proxy))
        {
            bail!(
                "min_accuracy_proxy must be in [0, 1]; got {}",
                cfg.min_accuracy_proxy
            );
        }
        if !cfg.tier_chain.is_empty() {
            if cfg.tier_chain.len() < 2 {
                bail!(
                    "tier_chain needs at least 2 tiers (a forwarding middle and a \
                     terminal); for a single remote tier use cloud_addr"
                );
            }
            if cfg.cloud_addr.is_some() {
                bail!(
                    "tier_chain and cloud_addr are mutually exclusive \
                     (the chain head *is* the cloud endpoint)"
                );
            }
            if registry.iter().any(|p| p.cloud_addr.is_some()) {
                bail!("tier_chain is incompatible with per-class cloud_addr overrides");
            }
            if cfg.per_request_planning || cfg.probe_fraction > 0.0 {
                bail!(
                    "tier_chain is incompatible with per_request_planning/probe_fraction \
                     (chain cut vectors are solved once at startup)"
                );
            }
            if cfg.adaptive.is_some() || cfg.estimation.is_some() {
                bail!(
                    "tier_chain is incompatible with adaptive replanning and online \
                     estimation (both re-solve the two-tier split; a chain's tail is fixed)"
                );
            }
            for (i, t) in cfg.tier_chain.iter().enumerate() {
                if !(t.compute_scale.is_finite() && t.compute_scale > 0.0) {
                    bail!(
                        "tier {i} ({}): compute_scale must be finite and > 0; got {}",
                        t.addr,
                        t.compute_scale
                    );
                }
                if i + 1 < cfg.tier_chain.len()
                    && (t.uplink_mbps.is_none() || t.rtt_s.is_none())
                {
                    bail!(
                        "tier {i} ({}) is not the terminal tier and needs \
                         uplink_mbps/rtt_ms for its hop to the next tier",
                        t.addr
                    );
                }
            }
        }

        let branch_pos = manifest.branch.after_stage;
        // Probing needs a branch-active split to route through; a branch
        // after the last stage can never be activated by a finite cut.
        let probe = if cfg.per_request_planning
            && cfg.probe_fraction > 0.0
            && branch_pos < manifest.num_stages()
        {
            Some(ProbeConfig {
                // ceil: never probe *more* often than the asked fraction.
                every: (1.0 / cfg.probe_fraction).ceil().max(1.0) as u64,
                split: branch_pos + 1,
            })
        } else {
            None
        };
        if probe.is_some() && cfg.estimation.is_none() {
            // Legal (the gate observations still surface in metrics and
            // an estimator can be enabled later) but probably not what
            // the operator meant: probes cost latency, and nothing is
            // learning from them.
            log::warn!(
                "probe_fraction {} is set but online estimation is off — probed requests \
                 reroute through a branch-active split with no estimator consuming the signal",
                cfg.probe_fraction
            );
        }

        // One remote cloud client per distinct endpoint, shared by
        // every class (and shard) resolving to it — one pooled
        // connection set and one backoff state per *server*, not per
        // pipeline. Construction is lazy: a fleet starts fine while a
        // cloud is down and falls back to local execution.
        let mut engines: Vec<(WireEncoding, Arc<RemoteCloudEngine>)> = Vec::new();
        let mut engine_for = |addr: &str, encoding: WireEncoding| -> Arc<RemoteCloudEngine> {
            if let Some((enc, e)) = engines.iter().find(|(_, e)| e.addr() == addr) {
                if *enc != encoding {
                    // Engines are deduped per endpoint, so the first
                    // class to resolve an address fixes its codec; a
                    // sibling that adopted a different one still plans
                    // at its own alpha but ships at the shared codec.
                    log::warn!(
                        "cloud-stage server {addr} already shares a client encoding {}; \
                         a class requesting {} reuses it",
                        enc.as_str(),
                        encoding.as_str()
                    );
                }
                return e.clone();
            }
            let mut rcfg = RemoteCloudConfig::new(addr.to_string());
            rcfg.encoding = encoding;
            let engine = Arc::new(RemoteCloudEngine::new(rcfg));
            engines.push((encoding, engine.clone()));
            // Reachability probe on a detached thread: its only output
            // is a log line, and a stalled resolver or a 2s connect
            // timeout must not delay fleet startup (the whole point of
            // the lazy client is that the edge serves while the cloud
            // is down).
            let probe = engine.clone();
            std::thread::Builder::new()
                .name("cloud-probe".into())
                .spawn(move || match probe.ping() {
                    Ok(()) => log::info!("cloud-stage server {} is reachable", probe.addr()),
                    Err(e) => log::warn!(
                        "cloud-stage server {} unreachable at startup ({e:#}); \
                         serving with local fallback until it comes up",
                        probe.addr()
                    ),
                })
                .ok();
            engine
        };

        // One p-independent precompute (`StaticCore`) for the whole
        // fleet; every class — override or not — derives its own cheap
        // exit-probability view from it. No class pays the full desc
        // clone + validation + graph-free precompute twice, and no two
        // classes share a live view (a per-class p-update must never
        // leak into a sibling).
        let mut base_planner = Planner::new(
            &manifest.to_desc(cfg.default_exit_prob),
            profile,
            cfg.epsilon,
            false,
        );
        if cfg.wire_encoding != WireEncoding::Raw {
            // Re-bake the shared core's alpha at the configured codec's
            // wire sizes, so every class view derived below prices its
            // transfer term at the bytes the fleet actually ships.
            base_planner = base_planner.with_wire_encoding(cfg.wire_encoding);
        }
        if let Some(ecfg) = &cfg.estimation {
            ecfg.validate()?;
        }

        // The budget starts fully charged for the startup shards; every
        // later grow/shrink settles against it.
        let budget = cfg.max_total_shards.map(|cap| {
            Arc::new(ShardBudget {
                cap,
                used: AtomicUsize::new(registry.len() * cfg.shards_per_class),
            })
        });

        let mut groups = Vec::with_capacity(registry.len());
        for (idx, prof) in registry.iter().enumerate() {
            let link_class = LinkClass(idx as u8);
            // Resolve this class's autoscale bounds: the fleet defaults
            // with the profile's overrides applied, re-validated (an
            // override can invert the range or strand the starting
            // size outside it).
            let autoscale = match &cfg.autoscale {
                Some(base) => {
                    let mut a = base.clone();
                    if let Some(lo) = prof.min_shards {
                        a.min_shards = lo;
                    }
                    if let Some(hi) = prof.max_shards {
                        a.max_shards = hi;
                    }
                    a.validate()
                        .map_err(|e| anyhow!("link class '{}': {e:#}", prof.name))?;
                    if !(a.min_shards..=a.max_shards).contains(&cfg.shards_per_class) {
                        bail!(
                            "link class '{}': shards_per_class ({}) must lie within \
                             its autoscale range {}..={}",
                            prof.name,
                            cfg.shards_per_class,
                            a.min_shards,
                            a.max_shards
                        );
                    }
                    Some(a)
                }
                None => None,
            };
            let p_class = prof.exit_probability.unwrap_or(cfg.default_exit_prob);
            // This class's cloud endpoint: its own override, else the
            // fleet-wide default; classes resolving to the same address
            // share one engine through the dedup map above.
            let cloud_addr = prof.cloud_addr.clone().or_else(|| cfg.cloud_addr.clone());
            // Startup joint search (fleet-wide flag, per-class
            // override): with the deployed branch set held fixed — a
            // serving fleet cannot re-train branches — sweep every
            // wire codec × split at this class's nominal link and
            // re-bake the class planner at the winner, so planned and
            // shipped bytes keep agreeing per class.
            let mut planner_for_class = base_planner.with_exit_probs(&[p_class]);
            let mut class_encoding = cfg.wire_encoding;
            if prof.joint_search.unwrap_or(cfg.joint_search) {
                let mut space = JointSearchSpace::restricted(&planner_for_class);
                space.encodings = WireEncoding::ALL.to_vec();
                if accuracy_proxy(&space.branch_sets[0]) < cfg.min_accuracy_proxy {
                    // The sole candidate is the deployed set; flooring
                    // it out would leave nothing to serve. Search
                    // unfloored instead of panicking in `plan_joint`.
                    log::warn!(
                        "[{}] joint search: deployed branch set misses the accuracy \
                         floor {} — searching without the floor",
                        prof.name,
                        cfg.min_accuracy_proxy
                    );
                } else {
                    space.min_accuracy_proxy = cfg.min_accuracy_proxy;
                }
                let joint = planner_for_class.plan_joint(prof.link, &space);
                if joint.encoding != class_encoding {
                    let fixed_ms = joint
                        .ranked
                        .iter()
                        .find(|c| c.encoding == class_encoding)
                        .map_or(f64::NAN, |c| c.expected_time * 1e3);
                    log::info!(
                        "[{}] joint search: adopting {} at split after {} \
                         ({:.3} ms vs {:.3} ms under {})",
                        prof.name,
                        joint.encoding.as_str(),
                        joint.split,
                        joint.expected_time * 1e3,
                        fixed_ms,
                        class_encoding.as_str()
                    );
                    planner_for_class = planner_for_class.with_wire_encoding(joint.encoding);
                    class_encoding = joint.encoding;
                } else {
                    log::info!(
                        "[{}] joint search: kept {} (split after {}, E[T] {:.3} ms)",
                        prof.name,
                        joint.encoding.as_str(),
                        joint.split,
                        joint.expected_time * 1e3
                    );
                }
            }
            // K-tier chain: solve this class's full cut vector over the
            // chain's layered graph — hop 0 is the class's own modeled
            // uplink, later hops come from the tier specs — and fix it
            // for the fleet's lifetime (the replanning knobs were
            // rejected above, so nothing ever moves it).
            let chain_state = if cfg.tier_chain.is_empty() {
                None
            } else {
                let mut links = vec![prof.link];
                let mut scales = Vec::with_capacity(cfg.tier_chain.len());
                for (i, t) in cfg.tier_chain.iter().enumerate() {
                    scales.push(t.compute_scale);
                    if i + 1 < cfg.tier_chain.len() {
                        links.push(
                            LinkModel::try_new(
                                t.uplink_mbps.unwrap_or(0.0),
                                t.rtt_s.unwrap_or(0.0),
                            )
                            .map_err(|e| anyhow!("tier {i} ({}): {e:#}", t.addr))?,
                        );
                    }
                }
                let chain = TierChain {
                    links,
                    compute_scale: scales,
                };
                let chain_plan = planner_for_class.plan_chain(&chain);
                log::info!(
                    "[{}] chain plan over {} tier(s): cuts {:?}, E[T] {:.3} ms",
                    prof.name,
                    cfg.tier_chain.len(),
                    chain_plan.cuts,
                    chain_plan.expected_time_s * 1e3
                );
                Some(ClassChainState {
                    links_tail: chain.links[1..].to_vec(),
                    scales: chain.compute_scale.clone(),
                    base_plan: PartitionPlan::from_split_encoded(
                        chain_plan.cuts[0],
                        chain_plan.expected_time_s,
                        Strategy::ShortestPath,
                        planner_for_class.desc(),
                        class_encoding,
                    ),
                    tail: Arc::new(chain_plan.cuts[1..].to_vec()),
                    cuts: Arc::new(chain_plan.cuts),
                })
            };
            // Chain mode reports (and dials) the chain head as the
            // class's cloud endpoint; the terminal tier doubles as the
            // degraded direct path when the head is down.
            let cloud_addr = match &chain_state {
                Some(_) => Some(cfg.tier_chain[0].addr.clone()),
                None => cloud_addr,
            };
            let remote = cloud_addr
                .as_deref()
                .map(|addr| engine_for(addr, class_encoding));
            let chain_direct = chain_state.as_ref().map(|_| {
                let terminal = &cfg.tier_chain[cfg.tier_chain.len() - 1].addr;
                engine_for(terminal, class_encoding)
            });
            let class_planner = Arc::new(ClassPlanner::new(
                link_class,
                prof.name.clone(),
                planner_for_class,
            ));
            let plan = class_planner.plan(prof.link);

            let trace = prof
                .trace
                .clone()
                .unwrap_or_else(|| BandwidthTrace::constant(prof.link.uplink_mbps));
            let mut channel =
                Channel::new(trace, prof.link.rtt_s, cfg.channel_jitter, idx as u64 + 1);
            if !cfg.real_time_channel {
                channel = channel.simulated_time();
            }
            let channel = Arc::new(channel);

            // Exit-rate feedback: the observer runs on each shard's edge
            // worker at the branch gate. It pushes rebuilt plans to
            // whatever shards are live at push time — the shard group is
            // created (empty) before the shards so the observer can
            // capture it.
            let estimator = cfg
                .estimation
                .map(|ecfg| Arc::new(Mutex::new(ExitRateEstimator::new(ecfg, p_class))));
            let shard_group = Arc::new(ShardGroup::new());
            let observer: Option<ExitObserver> = estimator.clone().map(|est| {
                let planner = class_planner.clone();
                let channel = channel.clone();
                let sinks = shard_group.clone();
                Arc::new(move |exited: bool| {
                    // The rebuild runs *inside* the estimator lock so
                    // concurrent shards' drift triggers serialize: the
                    // installed view/plans always correspond to the
                    // estimator's latest planned p (no out-of-order
                    // installs). Nothing below takes the estimator
                    // lock, so there is no cycle.
                    let mut est = est.lock().unwrap();
                    if let Some(p_hat) = est.observe(exited) {
                        // Re-derive the view at p̂ (O(N·m), epoch bump
                        // invalidates the class's plan cache) and move
                        // every shard's base plan to the new optimum at
                        // the current link.
                        planner.set_exit_probs(&[p_hat]);
                        let new_plan = planner.plan(channel.current_link());
                        log::info!(
                            "[{}] exit-rate drift: p̂ {:.3} -> split after {}",
                            planner.name(),
                            p_hat,
                            new_plan.split_after
                        );
                        for shard in sinks.handles() {
                            shard.set_plan(new_plan.clone());
                        }
                    }
                }) as ExitObserver
            });

            // One closure provisions one shard; startup, the
            // autoscaler's grow path and `Fleet::grow_class` all share
            // it, so a grown shard is wired exactly like a startup one.
            let spawn_shard: SpawnShard = {
                let make = make_engines.clone();
                let name = prof.name.clone();
                let channel = channel.clone();
                let planner = class_planner.clone();
                let remote = remote.clone();
                let observer = observer.clone();
                let chain_route = chain_state.as_ref().map(|st| ChainRoute {
                    tail: st.tail.clone(),
                    direct: chain_direct.clone(),
                });
                let chain_plan = chain_state.as_ref().map(|st| st.base_plan.clone());
                let ccfg = CoordinatorConfig {
                    entropy_threshold: cfg.entropy_threshold,
                    max_batch: cfg.max_batch,
                    batch_timeout: cfg.batch_timeout,
                    queue_capacity: cfg.queue_capacity,
                    cloud_workers: cfg.cloud_workers_per_shard,
                    wire_encoding: class_encoding,
                };
                Arc::new(move |shard_idx: u64| {
                    let label = format!("{name}-s{shard_idx}");
                    let (edge, cloud) = make(&label)?;
                    let cloud_exec = match &remote {
                        Some(r) => CloudExec::Remote {
                            remote: r.clone(),
                            fallback: cloud,
                            chain: chain_route.clone(),
                        },
                        None => CloudExec::Local(cloud),
                    };
                    // The class's *current* plan: the epoch-checked
                    // cached solve at the live link reflects every
                    // estimator/adaptive update so far, so a grown
                    // shard starts on the same split its siblings were
                    // last pushed. Chain mode instead pins every shard
                    // to the startup cut vector's edge split.
                    let plan = match &chain_plan {
                        Some(p) => p.clone(),
                        None => planner.plan(channel.current_link()),
                    };
                    Ok(Arc::new(Coordinator::start_observed(
                        edge,
                        cloud_exec,
                        channel.clone(),
                        plan,
                        ccfg.clone(),
                        observer.clone(),
                    )))
                })
            };

            let mut shards = Vec::with_capacity(cfg.shards_per_class);
            for s in 0..cfg.shards_per_class {
                shards.push(spawn_shard(s as u64)?);
            }
            shard_group.install_initial(shards);

            let adaptive = cfg.adaptive.map(|acfg| {
                let sinks = shard_group.clone();
                let source_channel = channel.clone();
                AdaptivePlanner::spawn_with(
                    class_planner.fork_planner(),
                    acfg,
                    Some(plan.split_after),
                    move || source_channel.current_link(),
                    move |new_plan: PartitionPlan| {
                        for shard in sinks.handles() {
                            shard.set_plan(new_plan.clone());
                        }
                    },
                )
            });

            let spawn_loop = autoscale.clone().filter(|_| !cfg.autoscale_external);
            let autoscaler = spawn_loop.map(|acfg| {
                let sample_group = shard_group.clone();
                let sample_remote = remote.clone();
                let grow_group = shard_group.clone();
                let grow_spawn = spawn_shard.clone();
                let grow_budget = budget.clone();
                let grow_cap = acfg.max_shards;
                let shrink_group = shard_group.clone();
                let shrink_budget = budget.clone();
                let shrink_floor = acfg.min_shards;
                Autoscaler::spawn(
                    prof.name.clone(),
                    acfg,
                    move || {
                        // Retired first, live second: a shard popped by a
                        // racing shrink then appears in *neither* sum
                        // (the counter steps back, which from_window
                        // saturates away) — never in both, which would
                        // fabricate a rejection delta and force a
                        // phantom grow.
                        let retired_rejected = sample_group.retired_rejected();
                        let handles = sample_group.handles();
                        LoadSample {
                            shards: handles.len(),
                            depth_total: handles.iter().map(|s| s.queue_depth()).sum(),
                            rejected_total: handles
                                .iter()
                                .map(|s| s.rejected_total())
                                .sum::<u64>()
                                + retired_rejected,
                            remote_total: sample_remote
                                .as_ref()
                                .map(|r| {
                                    let st = r.stats();
                                    st.saturated + st.fast_fails
                                })
                                .unwrap_or(0),
                        }
                    },
                    move |trigger| {
                        grow_with_budget(
                            &grow_group,
                            grow_budget.as_deref(),
                            trigger,
                            grow_cap,
                            &*grow_spawn,
                        )
                    },
                    move |trigger| {
                        shrink_with_budget(
                            &shrink_group,
                            shrink_budget.as_deref(),
                            trigger,
                            shrink_floor,
                        )
                    },
                )
            });

            groups.push(ClassGroup {
                profile: prof.clone(),
                cloud_addr,
                planner: class_planner,
                estimator,
                channel,
                shards: shard_group,
                spawn_shard,
                remote,
                autoscale,
                router: FleetRouter::new(cfg.routing),
                adaptive,
                autoscaler,
                wire_encoding: class_encoding,
                probe_counter: AtomicU64::new(0),
                probe_overrides: AtomicU64::new(0),
                chain: chain_state,
            });
        }

        let tier_heads = match cfg.tier_chain.first() {
            Some(head) => engines
                .iter()
                .filter(|(_, e)| e.addr() == head.addr)
                .map(|(_, e)| e.clone())
                .collect(),
            None => Vec::new(),
        };
        Ok(Fleet {
            registry,
            groups,
            per_request_planning: cfg.per_request_planning,
            probe,
            branch_pos,
            remotes: engines.into_iter().map(|(_, e)| e).collect(),
            tier_heads,
            wire_encoding: cfg.wire_encoding,
            budget,
            route_key: AtomicU64::new(1),
            server_stats: Mutex::new(None),
        })
    }

    pub fn registry(&self) -> &ClassRegistry {
        &self.registry
    }

    pub fn class_by_name(&self, name: &str) -> Option<LinkClass> {
        self.registry.id_of(name)
    }

    fn group(&self, class: LinkClass) -> Result<&ClassGroup> {
        self.groups.get(class.index()).ok_or_else(|| {
            anyhow!(
                "unknown link class id {} (fleet has {} classes)",
                class.0,
                self.groups.len()
            )
        })
    }

    /// The plan the class's shards are currently executing.
    pub fn plan_of(&self, class: LinkClass) -> Result<PartitionPlan> {
        // A shard group is never empty (shrinks refuse to empty it).
        Ok(self.group(class)?.shards.read()[0].plan())
    }

    /// Live shard count of a class.
    pub fn shards_of(&self, class: LinkClass) -> Result<usize> {
        Ok(self.group(class)?.shards.len())
    }

    /// The codec the class's planner prices and its shards ship at —
    /// the fleet-wide default unless the startup joint search adopted
    /// a different one for this class's link.
    pub fn encoding_of(&self, class: LinkClass) -> Result<WireEncoding> {
        Ok(self.group(class)?.wire_encoding)
    }

    /// `E[T_inf]` the class's planner prices for `split` at `link` —
    /// the scenario harness costs its virtual queue twin through this,
    /// so twin latencies and the plans the fleet executes come from the
    /// same model (same terms, same fold order).
    pub fn expected_time_of(&self, class: LinkClass, split: usize, link: LinkModel) -> Result<f64> {
        Ok(self.group(class)?.planner.expected_time(split, link))
    }

    /// Scaling observability for a class (current/min/max shards,
    /// scale-up/down counters, last trigger).
    pub fn scaler_stats_of(&self, class: LinkClass) -> Result<ScalerStats> {
        Ok(self.group(class)?.scaler_stats())
    }

    /// Manually add a shard to a class — the same provisioning path the
    /// autoscaler's grow decision takes (same engine factory, observer
    /// and remote wiring; the new shard starts on the class's current
    /// plan). Returns the new shard count. Bounded by the class's
    /// autoscale `max_shards` when autoscaling is on (the scaler could
    /// never walk an overshoot back under load), by the fleet-wide 64
    /// otherwise.
    pub fn grow_class(&self, class: LinkClass) -> Result<usize> {
        let group = self.group(class)?;
        let cap = group.autoscale.as_ref().map(|a| a.max_shards).unwrap_or(64);
        grow_with_budget(
            &group.shards,
            self.budget.as_deref(),
            "manual",
            cap,
            &*group.spawn_shard,
        )
    }

    /// [`Fleet::grow_class`] with an explicit trigger string and denial
    /// outcomes instead of errors — the drive API an external scaler
    /// (the scenario harness) executes its decisions through. A denial
    /// builds no engine; a budget denial additionally records itself as
    /// the class's `last_trigger`.
    pub fn grow_class_triggered(&self, class: LinkClass, trigger: &str) -> Result<GrowOutcome> {
        let group = self.group(class)?;
        let cap = group.autoscale.as_ref().map(|a| a.max_shards).unwrap_or(64);
        if group.shards.len() >= cap {
            return Ok(GrowOutcome::AtClassCap);
        }
        if let Some(b) = &self.budget {
            if !b.try_acquire() {
                group.shards.note_trigger(&b.denial());
                return Ok(GrowOutcome::AtBudget);
            }
        }
        match group.shards.grow(trigger, cap, &*group.spawn_shard) {
            Ok(n) => Ok(GrowOutcome::Grew(n)),
            Err(e) => {
                if let Some(b) = &self.budget {
                    b.release();
                }
                // A concurrent grow can win the locked re-check between
                // the len() peek above and the install; that is the cap
                // denial it looks like, not a provisioning failure.
                if group.shards.len() >= cap {
                    Ok(GrowOutcome::AtClassCap)
                } else {
                    Err(e)
                }
            }
        }
    }

    /// [`Fleet::shrink_class`] with an explicit trigger string — the
    /// external scaler's shrink path. Releases the victim's budget
    /// slot; errors when the class already sits at its floor.
    pub fn shrink_class_triggered(&self, class: LinkClass, trigger: &str) -> Result<usize> {
        let group = self.group(class)?;
        let floor = group.autoscale.as_ref().map(|a| a.min_shards).unwrap_or(1);
        shrink_with_budget(&group.shards, self.budget.as_deref(), trigger, floor)
    }

    /// Manually retire a class's highest-index shard: it is removed
    /// from routing first, then drained (every admitted request is
    /// answered) before its workers join. Returns the new shard count;
    /// refuses to drop below the class's autoscale `min_shards` (one
    /// shard on a fixed fleet).
    pub fn shrink_class(&self, class: LinkClass) -> Result<usize> {
        self.shrink_class_triggered(class, "manual")
    }

    /// One raw load reading of a class — the same sampling the
    /// autoscaler control loop performs, exposed so an external driver
    /// can assemble windows and run [`AutoscaleConfig::decide`] on its
    /// own clock.
    pub fn load_sample_of(&self, class: LinkClass) -> Result<LoadSample> {
        let group = self.group(class)?;
        // Retired first, live second — same ordering argument as the
        // control loop's sampler (see `Fleet::start`).
        let retired_rejected = group.shards.retired_rejected();
        let handles = group.shards.handles();
        Ok(LoadSample {
            shards: handles.len(),
            depth_total: handles.iter().map(|s| s.queue_depth()).sum(),
            rejected_total: handles.iter().map(|s| s.rejected_total()).sum::<u64>()
                + retired_rejected,
            remote_total: group
                .remote
                .as_ref()
                .map(|r| {
                    let st = r.stats();
                    st.saturated + st.fast_fails
                })
                .unwrap_or(0),
        })
    }

    /// The class's resolved autoscale config (fleet defaults with the
    /// class's overrides applied); `None` when autoscaling is off.
    pub fn autoscale_of(&self, class: LinkClass) -> Result<Option<AutoscaleConfig>> {
        Ok(self.group(class)?.autoscale.clone())
    }

    /// Re-point a class at a new nominal uplink mid-run (the scenario
    /// harness's link-churn event): re-solve the class's base plan at
    /// the new link and push it to every live shard. The class
    /// *channel* is deliberately untouched — it keeps charging its
    /// startup trace — so this models a control-plane retune whose
    /// effect shows up in planning, not in the simulated wire clock.
    /// Returns the new split.
    pub fn retune_class(&self, class: LinkClass, uplink_mbps: f64, rtt_s: f64) -> Result<usize> {
        let group = self.group(class)?;
        let link = LinkModel::try_new(uplink_mbps, rtt_s)?;
        let plan = group.planner.plan(link);
        let split = plan.split_after;
        for shard in group.shards.handles() {
            shard.set_plan(plan.clone());
        }
        log::info!(
            "[{}] retuned to {uplink_mbps} Mbit/s (rtt {rtt_s}s): split after {split}",
            group.profile.name
        );
        Ok(split)
    }

    /// Toggle every remote cloud endpoint's availability (the scenario
    /// harness's brownout/outage windows). `false` makes each remote
    /// client fail instantly — without touching its backoff/breaker
    /// state — so offloads fall back to the shards' local engines;
    /// `true` restores the wire path immediately. No-op for fleets
    /// whose cloud stages run in-process.
    pub fn set_cloud_available(&self, up: bool) {
        for r in &self.remotes {
            r.set_available(up);
        }
    }

    /// Toggle only the *chain-head* tier's availability (the scenario
    /// harness's tier-brownout window): chain frames fail fast and
    /// every chain-routed group degrades to a direct single-hop offload
    /// against the terminal tier, which stays up. No-op for fleets
    /// without a tier chain.
    pub fn set_tier_available(&self, up: bool) {
        for r in &self.tier_heads {
            r.set_available(up);
        }
    }

    /// The class's solved chain cut vector (`None` without a tier
    /// chain). `cuts[0]` is the edge split its shards execute.
    pub fn chain_cuts_of(&self, class: LinkClass) -> Result<Option<Vec<usize>>> {
        Ok(self
            .group(class)?
            .chain
            .as_ref()
            .map(|c| c.cuts.as_ref().clone()))
    }

    /// `E[T]` of the class's *fixed* chain cut vector with hop 0
    /// re-priced at `link` — the chain analogue of
    /// [`Fleet::expected_time_of`], so the scenario twin's latencies
    /// and the route the fleet executes come from the same pricing
    /// fold ([`Planner::chain_expected_time`]).
    pub fn chain_expected_time_of(&self, class: LinkClass, link: LinkModel) -> Result<f64> {
        let group = self.group(class)?;
        let st = group.chain.as_ref().ok_or_else(|| {
            anyhow!("link class '{}' has no tier chain", group.profile.name)
        })?;
        let mut links = Vec::with_capacity(st.links_tail.len() + 1);
        links.push(link);
        links.extend(st.links_tail.iter().copied());
        let chain = TierChain {
            links,
            compute_scale: st.scales.clone(),
        };
        Ok(group.planner.planner().chain_expected_time(&chain, &st.cuts))
    }

    /// This class's planner (for cross-checking plans in tests/tools).
    pub fn planner_of(&self, class: LinkClass) -> Result<&ClassPlanner> {
        Ok(&*self.group(class)?.planner)
    }

    /// The class's simulated uplink.
    pub fn channel_of(&self, class: LinkClass) -> Result<&Channel> {
        Ok(self.group(class)?.channel.as_ref())
    }

    /// Wire-level counters of the remote cloud clients, summed across
    /// every distinct endpoint (`inflight_peak` takes the max — peaks
    /// on different servers don't add); `None` when the fleet runs its
    /// cloud stages in-process.
    pub fn remote_stats(&self) -> Option<RemoteCloudStats> {
        if self.remotes.is_empty() {
            return None;
        }
        let mut total = RemoteCloudStats::default();
        for r in &self.remotes {
            let s = r.stats();
            total.requests += s.requests;
            total.failures += s.failures;
            total.fast_fails += s.fast_fails;
            total.saturated += s.saturated;
            total.connects += s.connects;
            total.stale_retries += s.stale_retries;
            total.bytes_sent += s.bytes_sent;
            total.bytes_received += s.bytes_received;
            total.inflight_peak = total.inflight_peak.max(s.inflight_peak);
        }
        Some(total)
    }

    /// The fleet-wide activation transfer codec. Individual classes may
    /// ship a different one when the startup joint search adopted it —
    /// see [`Fleet::encoding_of`].
    pub fn wire_encoding(&self) -> WireEncoding {
        self.wire_encoding
    }

    /// Route one request: pick a shard of the class's group and submit.
    /// The routing key is a per-request counter, so hash routing spreads
    /// uniformly; use [`Fleet::submit_keyed`] for session affinity.
    ///
    /// # Example
    ///
    /// A one-class fleet on the simulated runtime (no artifacts
    /// needed), serving a single request end to end:
    ///
    /// ```
    /// use branchyserve::fleet::{ClassProfile, ClassRegistry, Fleet, FleetConfig};
    /// use branchyserve::model::Manifest;
    /// use branchyserve::runtime::{HostTensor, InferenceEngine};
    /// use branchyserve::timing::DelayProfile;
    ///
    /// let manifest =
    ///     Manifest::synthetic_sim("doc-fleet", vec![4], &[16, 8, 2], 1, 2, vec![1, 2, 4])?;
    /// let profile = DelayProfile::from_cloud_times(vec![1e-4; 3], 2e-5, 50.0);
    /// let registry = ClassRegistry::single(ClassProfile::custom("4g", 5.85, 0.0)?);
    /// let m = manifest.clone();
    /// let fleet = Fleet::start(
    ///     registry,
    ///     &manifest,
    ///     &profile,
    ///     FleetConfig { real_time_channel: false, ..Default::default() },
    ///     move |label| {
    ///         Ok((
    ///             InferenceEngine::open_sim(m.clone(), &format!("{label}-edge"))?,
    ///             InferenceEngine::open_sim(m.clone(), &format!("{label}-cloud"))?,
    ///         ))
    ///     },
    /// )?;
    /// let class = fleet.class_by_name("4g").unwrap();
    /// let (_id, rx) = fleet.submit(class, HostTensor::zeros(vec![4]))?;
    /// let response = rx.recv()?;
    /// assert!(response.class < 2);
    /// fleet.shutdown();
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn submit(
        &self,
        class: LinkClass,
        image: HostTensor,
    ) -> Result<(u64, mpsc::Receiver<InferenceResponse>)> {
        self.submit_keyed(class, self.route_key.fetch_add(1, Ordering::Relaxed), image)
    }

    /// [`Fleet::submit`] with an explicit routing key: under hash
    /// routing, equal keys (e.g. a client/session id) always land on the
    /// same shard. Round-robin and least-loaded ignore the key.
    ///
    /// With per-request planning enabled, the sample's split is solved
    /// here, at admission, against the class channel's *instantaneous*
    /// link estimate — an O(1) `expected_time` sweep through the
    /// planner's epoch-checked bucket cache — and rides along as a plan
    /// override; the shard's base plan is untouched.
    pub fn submit_keyed(
        &self,
        class: LinkClass,
        key: u64,
        image: HostTensor,
    ) -> Result<(u64, mpsc::Receiver<InferenceResponse>)> {
        let (tx, rx) = mpsc::channel();
        match self.admit_keyed(class, key, image, ReplyTo::Channel(tx)) {
            Ok(id) => Ok((id, rx)),
            Err(AdmitRejection::Busy) => Err(anyhow!("admission queue full")),
            Err(AdmitRejection::Failed(e)) => Err(e),
        }
    }

    /// Non-blocking admission with a typed rejection and an arbitrary
    /// reply destination — the reactor front end's entry point. The
    /// routing key is drawn from the same per-request counter as
    /// [`Fleet::submit`].
    pub fn admit(
        &self,
        class: LinkClass,
        image: HostTensor,
        reply: ReplyTo,
    ) -> std::result::Result<u64, AdmitRejection> {
        self.admit_keyed(
            class,
            self.route_key.fetch_add(1, Ordering::Relaxed),
            image,
            reply,
        )
    }

    /// Shared admission core: shard pick, per-request planning and
    /// probe rerouting, then a typed submit into the picked shard.
    /// Every submit path — blocking channel or reactor sink — funnels
    /// through here.
    pub fn admit_keyed(
        &self,
        class: LinkClass,
        key: u64,
        image: HostTensor,
        reply: ReplyTo,
    ) -> std::result::Result<u64, AdmitRejection> {
        let group = self.group(class).map_err(AdmitRejection::Failed)?;
        // The read guard spans *pick → submit*: a concurrent shrink
        // (write lock) cannot retire the picked shard before the
        // request lands in its admission queue, so no request is ever
        // routed into a draining pipeline.
        let shards = group.shards.read();
        let n = shards.len();
        let shard = if n == 1 {
            0
        } else if group.router.policy() == RoutePolicy::LeastLoaded {
            // Queue depths are only gathered when the policy reads them:
            // they cost one lock per shard on the admission path. The
            // depths are read from this same consistent view of the
            // set, so a mid-resize pick never indexes out of bounds.
            let depths: Vec<usize> = shards.iter().map(|s| s.queue_depth()).collect();
            group.router.pick(key, &depths)
        } else {
            group.router.pick_index(key, n)
        };
        let plan = if self.per_request_planning {
            let link = group.channel.current_link();
            let mut plan = group.planner.plan(link);
            // Exit-rate probing: when the solved split keeps the branch
            // inactive (no gate ⇒ no observations ⇒ p̂ frozen), reroute
            // every `every`-th such request through the smallest
            // branch-active split so the estimator keeps learning.
            if let Some(probe) = &self.probe {
                if plan.split_after <= self.branch_pos {
                    let k = group.probe_counter.fetch_add(1, Ordering::Relaxed);
                    if k % probe.every == 0 {
                        let t = group.planner.expected_time(probe.split, link);
                        plan = PartitionPlan::from_split(
                            probe.split,
                            t,
                            Strategy::ShortestPath,
                            group.planner.planner().desc(),
                        );
                        group.probe_overrides.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            Some(plan)
        } else {
            None
        };
        shards[shard]
            .submit_reply(image, plan, reply)
            .map_err(|e| match e {
                AdmitError::Busy => AdmitRejection::Busy,
                AdmitError::Closed => {
                    AdmitRejection::Failed(anyhow!("coordinator shut down"))
                }
            })
    }

    /// Convenience: submit and block for the response.
    pub fn infer_sync(&self, class: LinkClass, image: HostTensor) -> Result<InferenceResponse> {
        let (_, rx) = self.submit(class, image)?;
        rx.recv().map_err(|_| anyhow!("response channel dropped"))
    }

    /// Live per-class / per-shard / total metrics, including each
    /// class's planner-side stats (planned p, estimated p̂, cache and
    /// view-rebuild counters).
    pub fn report(&self) -> FleetReport {
        let classes = self
            .groups
            .iter()
            .map(|g| {
                let handles = g.shards.handles();
                let shards: Vec<MetricsSnapshot> =
                    handles.iter().map(|s| s.metrics()).collect();
                let queue_depths: Vec<usize> =
                    handles.iter().map(|s| s.queue_depth()).collect();
                // Retired shards' completed work stays in the class
                // aggregate after a shrink — elasticity must never make
                // served traffic disappear from the books.
                let mut all = shards.clone();
                all.extend(g.shards.retired_snapshots());
                ClassReport {
                    class: g.planner.class(),
                    name: g.profile.name.clone(),
                    link: g.profile.link,
                    split_after: handles[0].plan().split_after,
                    cuts: g.chain.as_ref().map(|c| c.cuts.as_ref().clone()),
                    wire_encoding: g.wire_encoding,
                    cloud_addr: g.cloud_addr.clone(),
                    planner: g.planner_stats(),
                    scaler: g.scaler_stats(),
                    queue_depths,
                    aggregate: MetricsSnapshot::aggregate(&all),
                    shards,
                }
            })
            .collect();
        let mut report = FleetReport::from_classes(classes);
        report.server = self.server_stats.lock().unwrap().as_ref().map(|s| s.snapshot());
        report
    }

    /// Stop the autoscalers and replan loops, drain and join every
    /// shard, and return the final report.
    pub fn shutdown(mut self) -> FleetReport {
        // Control loops first: no resize or replan may race the drain.
        // Joining the shard workers (drain_all below) then drops the
        // observer closures, which is what breaks the group → shard →
        // worker-closure → group reference cycle.
        for g in &mut self.groups {
            if let Some(handle) = g.autoscaler.take() {
                handle.stop();
            }
            if let Some(handle) = g.adaptive.take() {
                handle.stop();
            }
        }
        let mut classes = Vec::with_capacity(self.groups.len());
        for g in self.groups.drain(..) {
            let split_after = g.shards.read()[0].plan().split_after;
            let scaler = g.scaler_stats();
            let shards = g.shards.drain_all();
            let queue_depths = vec![0; shards.len()]; // drained by construction
            let mut all = shards.clone();
            all.extend(g.shards.retired_snapshots());
            classes.push(ClassReport {
                class: g.planner.class(),
                name: g.profile.name.clone(),
                link: g.profile.link,
                split_after,
                cuts: g.chain.as_ref().map(|c| c.cuts.as_ref().clone()),
                wire_encoding: g.wire_encoding,
                cloud_addr: g.cloud_addr.clone(),
                // After the drain/join, so gate observations that landed
                // while shards were draining are counted.
                planner: g.planner_stats(),
                scaler,
                queue_depths,
                aggregate: MetricsSnapshot::aggregate(&all),
                shards,
            });
        }
        let mut report = FleetReport::from_classes(classes);
        report.server = self.server_stats.lock().unwrap().as_ref().map(|s| s.snapshot());
        report
    }
}

impl ServeBackend for Fleet {
    fn serve_infer(&self, class: Option<u8>, image: HostTensor) -> Result<InferenceResponse> {
        self.infer_sync(LinkClass(class.unwrap_or(LinkClass::DEFAULT.0)), image)
    }

    fn submit_infer(&self, class: Option<u8>, image: HostTensor, reply: ReplyTo) -> Submission {
        let lc = LinkClass(class.unwrap_or(LinkClass::DEFAULT.0));
        match self.admit(lc, image, reply) {
            Ok(id) => Submission::Queued(id),
            Err(AdmitRejection::Busy) => Submission::Busy,
            Err(AdmitRejection::Failed(e)) => Submission::Ready(Err(e)),
        }
    }

    fn register_server_stats(&self, stats: Arc<ServerStats>) {
        *self.server_stats.lock().unwrap() = Some(stats);
    }

    fn metrics_json(&self) -> String {
        self.report().to_json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim_fleet(cfg: FleetConfig) -> Fleet {
        let manifest =
            Manifest::synthetic_sim("sim-fleet", vec![4], &[16, 8, 2], 1, 2, vec![1, 2, 4, 8])
                .unwrap();
        let profile = DelayProfile::from_cloud_times(vec![1e-4, 1e-4, 1e-4], 2e-5, 50.0);
        let m = manifest.clone();
        Fleet::start(
            ClassRegistry::single(ClassProfile::custom("only", 5.85, 0.0).unwrap()),
            &manifest,
            &profile,
            cfg,
            move |label| {
                Ok((
                    InferenceEngine::open_sim(m.clone(), &format!("{label}-e"))?,
                    InferenceEngine::open_sim(m.clone(), &format!("{label}-c"))?,
                ))
            },
        )
        .unwrap()
    }

    #[test]
    fn single_class_fleet_serves_and_shuts_down() {
        let fleet = sim_fleet(FleetConfig {
            real_time_channel: false,
            batch_timeout: Duration::from_millis(1),
            ..Default::default()
        });
        let class = fleet.class_by_name("ONLY").unwrap();
        for _ in 0..4 {
            let x = HostTensor::new(vec![4], vec![0.3, -0.1, 0.8, 0.2]).unwrap();
            let r = fleet.infer_sync(class, x).unwrap();
            assert!(r.class < 2);
        }
        // Unknown class id is a routable error, not a panic.
        assert!(fleet
            .infer_sync(LinkClass(7), HostTensor::zeros(vec![4]))
            .is_err());
        let report = fleet.shutdown();
        assert_eq!(report.total.completed, 4);
        assert_eq!(report.classes.len(), 1);
        assert_eq!(report.classes[0].shards.len(), 1);
    }

    #[test]
    fn per_class_cloud_endpoints_dedupe_and_surface_in_the_report() {
        let manifest = Manifest::synthetic_sim(
            "sim-fleet-addr",
            vec![4],
            &[16, 8, 2],
            1,
            2,
            vec![1, 2, 4, 8],
        )
        .unwrap();
        let profile = DelayProfile::from_cloud_times(vec![1e-4, 1e-4, 1e-4], 2e-5, 50.0);
        let registry = ClassRegistry::new(vec![
            ClassProfile::custom("a", 1.10, 0.0).unwrap(),
            ClassProfile::custom("b", 5.85, 0.0)
                .unwrap()
                .with_cloud_addr("127.0.0.1:19"),
            ClassProfile::custom("c", 18.8, 0.0).unwrap(),
        ])
        .unwrap();
        let m = manifest.clone();
        let fleet = Fleet::start(
            registry,
            &manifest,
            &profile,
            FleetConfig {
                real_time_channel: false,
                cloud_addr: Some("127.0.0.1:9".into()),
                wire_encoding: WireEncoding::Q8,
                ..Default::default()
            },
            move |label| {
                Ok((
                    InferenceEngine::open_sim(m.clone(), &format!("{label}-e"))?,
                    InferenceEngine::open_sim(m.clone(), &format!("{label}-c"))?,
                ))
            },
        )
        .unwrap();
        // 'a' and 'c' share the fleet-wide endpoint's engine (one
        // pooled connection set per server); 'b' gets its own.
        assert_eq!(fleet.remotes.len(), 2);
        assert!(fleet.remotes.iter().all(|e| e.encoding() == WireEncoding::Q8));
        assert_eq!(fleet.wire_encoding(), WireEncoding::Q8);
        // Nothing was served over the wire, but the aggregate exists.
        assert!(fleet.remote_stats().is_some());
        let report = fleet.report();
        assert_eq!(report.classes[0].cloud_addr.as_deref(), Some("127.0.0.1:9"));
        assert_eq!(report.classes[1].cloud_addr.as_deref(), Some("127.0.0.1:19"));
        assert_eq!(report.classes[2].cloud_addr.as_deref(), Some("127.0.0.1:9"));
        assert!(report
            .classes
            .iter()
            .all(|c| c.wire_encoding == WireEncoding::Q8));
        fleet.shutdown();
    }

    #[test]
    fn joint_search_adopts_per_class_encoding_at_startup() {
        // A fat first stage makes the transfer term dominate on the
        // slow class's link, so a quantized codec strictly beats raw
        // there at every split that ships anything.
        let manifest = Manifest::synthetic_sim(
            "sim-joint",
            vec![64],
            &[4096, 8, 2],
            1,
            2,
            vec![1, 2, 4, 8],
        )
        .unwrap();
        let profile = DelayProfile::from_cloud_times(vec![1e-4, 1e-4, 1e-4], 2e-5, 200.0);
        let mut opted_out = ClassProfile::custom("fast", 18.8, 0.0).unwrap();
        opted_out.joint_search = Some(false);
        let registry = ClassRegistry::new(vec![
            ClassProfile::custom("slow", 1.10, 0.0).unwrap(),
            opted_out,
        ])
        .unwrap();

        // Ground truth from the same planner construction the fleet
        // performs: p = default_exit_prob, full encoding sweep.
        let base =
            Planner::new(&manifest.to_desc(0.5), &profile, 1e-9, false).with_exit_probs(&[0.5]);
        let mut space = JointSearchSpace::restricted(&base);
        space.encodings = WireEncoding::ALL.to_vec();
        let joint = base.plan_joint(LinkModel::new(1.10, 0.0), &space);
        assert_eq!(joint.encoding, WireEncoding::Q4, "fixture no longer favors q4");

        let m = manifest.clone();
        let fleet = Fleet::start(
            registry,
            &manifest,
            &profile,
            FleetConfig {
                real_time_channel: false,
                joint_search: true,
                ..Default::default()
            },
            move |label| {
                Ok((
                    InferenceEngine::open_sim(m.clone(), &format!("{label}-e"))?,
                    InferenceEngine::open_sim(m.clone(), &format!("{label}-c"))?,
                ))
            },
        )
        .unwrap();
        let slow = fleet.class_by_name("slow").unwrap();
        let fast = fleet.class_by_name("fast").unwrap();
        assert_eq!(fleet.encoding_of(slow).unwrap(), WireEncoding::Q4);
        assert_eq!(fleet.plan_of(slow).unwrap().split_after, joint.split);
        // The per-class opt-out wins over the fleet flag.
        assert_eq!(fleet.encoding_of(fast).unwrap(), WireEncoding::Raw);
        // The fleet-wide default is untouched; per-class codecs surface
        // in the report.
        assert_eq!(fleet.wire_encoding(), WireEncoding::Raw);
        let report = fleet.report();
        assert_eq!(report.classes[0].wire_encoding, WireEncoding::Q4);
        assert_eq!(report.classes[1].wire_encoding, WireEncoding::Raw);
        fleet.shutdown();
    }

    #[test]
    fn start_rejects_bad_accuracy_floor() {
        let manifest =
            Manifest::synthetic_sim("sim-floor", vec![4], &[16, 8, 2], 1, 2, vec![1])
                .unwrap();
        let profile = DelayProfile::from_cloud_times(vec![1e-4, 1e-4, 1e-4], 2e-5, 50.0);
        for bad in [-0.1, 1.5, f64::NAN] {
            let m = manifest.clone();
            let err = Fleet::start(
                ClassRegistry::single(ClassProfile::custom("only", 5.85, 0.0).unwrap()),
                &manifest,
                &profile,
                FleetConfig {
                    real_time_channel: false,
                    min_accuracy_proxy: bad,
                    ..Default::default()
                },
                move |label| {
                    Ok((
                        InferenceEngine::open_sim(m.clone(), &format!("{label}-e"))?,
                        InferenceEngine::open_sim(m.clone(), &format!("{label}-c"))?,
                    ))
                },
            )
            .unwrap_err();
            assert!(err.to_string().contains("min_accuracy_proxy"), "{err:#}");
        }
    }

    #[test]
    fn per_class_bounds_and_fleet_budget_govern_grows() {
        let manifest =
            Manifest::synthetic_sim("sim-budget", vec![4], &[16, 8, 2], 1, 2, vec![1, 2, 4, 8])
                .unwrap();
        let profile = DelayProfile::from_cloud_times(vec![1e-4, 1e-4, 1e-4], 2e-5, 50.0);
        let mut a = ClassProfile::custom("a", 1.10, 0.0).unwrap();
        a.max_shards = Some(2);
        let registry =
            ClassRegistry::new(vec![a, ClassProfile::custom("b", 5.85, 0.0).unwrap()]).unwrap();
        let m = manifest.clone();
        let fleet = Fleet::start(
            registry,
            &manifest,
            &profile,
            FleetConfig {
                real_time_channel: false,
                autoscale: Some(AutoscaleConfig {
                    min_shards: 1,
                    max_shards: 4,
                    ..Default::default()
                }),
                // No control loops: this test is the external driver.
                autoscale_external: true,
                max_total_shards: Some(3),
                ..Default::default()
            },
            move |label| {
                Ok((
                    InferenceEngine::open_sim(m.clone(), &format!("{label}-e"))?,
                    InferenceEngine::open_sim(m.clone(), &format!("{label}-c"))?,
                ))
            },
        )
        .unwrap();
        let a = fleet.class_by_name("a").unwrap();
        let b = fleet.class_by_name("b").unwrap();
        // 'a' grows to its own (overridden) ceiling of 2, then is
        // denied by that ceiling, not the budget.
        assert_eq!(fleet.grow_class_triggered(a, "t").unwrap(), GrowOutcome::Grew(2));
        assert_eq!(fleet.grow_class_triggered(a, "t").unwrap(), GrowOutcome::AtClassCap);
        // 'b' may go to 4 by its own range, but the fleet budget (3)
        // is spent: 2 + 1. The denial is recorded as its last trigger.
        assert_eq!(fleet.grow_class_triggered(b, "t").unwrap(), GrowOutcome::AtBudget);
        let st = fleet.scaler_stats_of(b).unwrap();
        assert!(st.last_trigger.unwrap().contains("budget"), "budget denial not recorded");
        assert_eq!((st.min_shards, st.max_shards), (1, 4));
        let st = fleet.scaler_stats_of(a).unwrap();
        assert_eq!((st.min_shards, st.max_shards, st.current_shards), (1, 2, 2));
        // Shrinking 'a' returns its slot; 'b' can then grow.
        assert_eq!(fleet.shrink_class_triggered(a, "t").unwrap(), 1);
        assert_eq!(fleet.grow_class_triggered(b, "t").unwrap(), GrowOutcome::Grew(2));
        fleet.shutdown();
    }

    #[test]
    fn start_rejects_degenerate_shard_counts() {
        let manifest =
            Manifest::synthetic_sim("sim-bad", vec![4], &[8, 2], 1, 2, vec![1]).unwrap();
        let profile = DelayProfile::from_cloud_times(vec![1e-4, 1e-4], 2e-5, 10.0);
        let r = Fleet::start(
            ClassRegistry::builtin(),
            &manifest,
            &profile,
            FleetConfig {
                shards_per_class: 0,
                ..Default::default()
            },
            |_| unreachable!("no shards should be provisioned"),
        );
        assert!(r.is_err());
    }
}
