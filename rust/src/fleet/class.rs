//! Link classes: the named client populations a fleet serves (3G / 4G /
//! WiFi out of the box, or TOML-defined), each with its own nominal
//! uplink, optional bandwidth trace, and optional planning
//! exit-probability override.

use std::collections::HashSet;

use anyhow::{bail, Result};

use crate::config::settings::LinkClassSettings;
use crate::network::bandwidth::{LinkModel, Profile};
use crate::network::trace::BandwidthTrace;

/// Wire-level identity of a link class: an index into the fleet's
/// [`ClassRegistry`], small enough to ride in the request protocol's
/// one-byte class tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LinkClass(pub u8);

impl LinkClass {
    /// The class untagged (legacy `INFER`) requests land in.
    pub const DEFAULT: LinkClass = LinkClass(0);

    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Everything the fleet knows about one client class.
#[derive(Debug, Clone)]
pub struct ClassProfile {
    pub name: String,
    /// Nominal uplink, used for the initial per-class plan and as the
    /// class channel's constant rate when no trace is given.
    pub link: LinkModel,
    /// Optional time-varying uplink driving the class channel (and, when
    /// the fleet's adaptive replanning is on, per-class replans).
    pub trace: Option<BandwidthTrace>,
    /// Planning exit-probability override for this class; `None` uses
    /// the fleet default. A class with an override cannot share the
    /// planner prefix sums (they depend on p), so it gets its own.
    pub exit_probability: Option<f64>,
    /// Cloud-stage server this class offloads to; `None` uses the
    /// fleet-wide `cloud_addr` (or in-process cloud if that is unset
    /// too). Lets a geographically split fleet keep each class's
    /// suffix stages near its clients.
    pub cloud_addr: Option<String>,
    /// Per-class autoscale floor override; `None` inherits the fleet's
    /// `min_shards`.
    pub min_shards: Option<usize>,
    /// Per-class autoscale ceiling override; `None` inherits the
    /// fleet's `max_shards`.
    pub max_shards: Option<usize>,
    /// Per-class joint-search override (`Planner::plan_joint` at class
    /// startup, adopting the winning wire encoding); `None` inherits
    /// the fleet's `joint_search`.
    pub joint_search: Option<bool>,
}

impl ClassProfile {
    /// One of the paper's named profiles: "3g", "4g", "wifi".
    pub fn named(name: &str) -> Result<ClassProfile> {
        let p = Profile::parse(name)?;
        Ok(ClassProfile {
            name: p.name().to_string(),
            link: LinkModel::from_profile(p),
            trace: None,
            exit_probability: None,
            cloud_addr: None,
            min_shards: None,
            max_shards: None,
            joint_search: None,
        })
    }

    /// A custom class; rejects degenerate links (config path — fail
    /// fast, don't clamp).
    pub fn custom(name: &str, uplink_mbps: f64, rtt_s: f64) -> Result<ClassProfile> {
        if name.trim().is_empty() {
            bail!("link class name must be non-empty");
        }
        Ok(ClassProfile {
            name: name.to_string(),
            link: LinkModel::try_new(uplink_mbps, rtt_s)?,
            trace: None,
            exit_probability: None,
            cloud_addr: None,
            min_shards: None,
            max_shards: None,
            joint_search: None,
        })
    }

    pub fn with_trace(mut self, trace: BandwidthTrace) -> ClassProfile {
        self.trace = Some(trace);
        self
    }

    /// Offload this class to its own cloud-stage server instead of the
    /// fleet-wide one.
    pub fn with_cloud_addr(mut self, addr: impl Into<String>) -> ClassProfile {
        self.cloud_addr = Some(addr.into());
        self
    }

    pub fn with_exit_probability(mut self, p: f64) -> Result<ClassProfile> {
        if !(0.0..=1.0).contains(&p) {
            bail!("exit probability {p} not in [0, 1]");
        }
        self.exit_probability = Some(p);
        Ok(self)
    }
}

/// Ordered set of class profiles; a profile's position is its wire id.
#[derive(Debug, Clone)]
pub struct ClassRegistry {
    classes: Vec<ClassProfile>,
}

impl ClassRegistry {
    pub fn new(classes: Vec<ClassProfile>) -> Result<ClassRegistry> {
        if classes.is_empty() {
            bail!("a fleet needs at least one link class");
        }
        if classes.len() > u8::MAX as usize + 1 {
            bail!(
                "at most 256 link classes fit the u8 wire tag; got {}",
                classes.len()
            );
        }
        let mut seen = HashSet::new();
        for c in &classes {
            if c.name.trim().is_empty() {
                bail!("link class name must be non-empty");
            }
            if !seen.insert(c.name.to_ascii_lowercase()) {
                bail!("duplicate link class '{}'", c.name);
            }
        }
        Ok(ClassRegistry { classes })
    }

    /// A one-class fleet (the degenerate single-pipeline deployment).
    pub fn single(profile: ClassProfile) -> ClassRegistry {
        ClassRegistry {
            classes: vec![profile],
        }
    }

    /// The paper's three uplink profiles as one fleet.
    pub fn builtin() -> ClassRegistry {
        ClassRegistry::new(vec![
            ClassProfile::named("3g").unwrap(),
            ClassProfile::named("4g").unwrap(),
            ClassProfile::named("wifi").unwrap(),
        ])
        .unwrap()
    }

    /// From config `[[link_class]]` entries (field values were already
    /// validated by `Settings::validate`).
    pub fn from_settings(entries: &[LinkClassSettings]) -> Result<ClassRegistry> {
        let mut classes = Vec::with_capacity(entries.len());
        for e in entries {
            let mut c = ClassProfile::custom(&e.name, e.uplink_mbps, e.rtt_s)?;
            c.exit_probability = e.exit_probability;
            c.cloud_addr = e.cloud_addr.clone();
            c.min_shards = e.min_shards;
            c.max_shards = e.max_shards;
            c.joint_search = e.joint_search;
            classes.push(c);
        }
        ClassRegistry::new(classes)
    }

    pub fn len(&self) -> usize {
        self.classes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.classes.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &ClassProfile> {
        self.classes.iter()
    }

    pub fn get(&self, class: LinkClass) -> Option<&ClassProfile> {
        self.classes.get(class.index())
    }

    /// Case-insensitive name lookup.
    pub fn id_of(&self, name: &str) -> Option<LinkClass> {
        self.classes
            .iter()
            .position(|c| c.name.eq_ignore_ascii_case(name))
            .map(|i| LinkClass(i as u8))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_has_paper_profiles_in_order() {
        let r = ClassRegistry::builtin();
        assert_eq!(r.len(), 3);
        assert_eq!(r.id_of("3G"), Some(LinkClass(0)));
        assert_eq!(r.id_of("4g"), Some(LinkClass(1)));
        assert_eq!(r.id_of("WiFi"), Some(LinkClass(2)));
        assert_eq!(r.id_of("5g"), None);
        assert!((r.get(LinkClass(0)).unwrap().link.uplink_mbps - 1.10).abs() < 1e-12);
        assert!(r.get(LinkClass(9)).is_none());
    }

    #[test]
    fn registry_rejects_duplicates_and_empties() {
        assert!(ClassRegistry::new(vec![]).is_err());
        let dup = vec![
            ClassProfile::named("4g").unwrap(),
            ClassProfile::custom("4G", 5.0, 0.0).unwrap(),
        ];
        assert!(ClassRegistry::new(dup).is_err());
    }

    #[test]
    fn custom_profile_validates_link_and_probability() {
        assert!(ClassProfile::custom("", 5.0, 0.0).is_err());
        assert!(ClassProfile::custom("x", 0.0, 0.0).is_err());
        assert!(ClassProfile::custom("x", 5.0, -1.0).is_err());
        let c = ClassProfile::custom("sat", 0.5, 0.3).unwrap();
        assert_eq!(c.link.rtt_s, 0.3);
        assert!(c.clone().with_exit_probability(1.5).is_err());
        assert_eq!(
            c.with_exit_probability(0.7).unwrap().exit_probability,
            Some(0.7)
        );
    }
}
