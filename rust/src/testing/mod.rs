//! Property-test mini-framework (proptest is unavailable offline).
//!
//! Usage:
//! ```no_run
//! use branchyserve::testing::{property, Gen};
//! property("sum is commutative", 200, |g| {
//!     let a = g.f64_in(-1e6, 1e6);
//!     let b = g.f64_in(-1e6, 1e6);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case draws from a seeded PCG32; on panic the harness re-raises
//! with the case number and seed so the failure is reproducible with
//! `Gen::replay(seed)`.

use crate::util::rng::Pcg32;

/// Case-local generator handed to property closures.
pub struct Gen {
    rng: Pcg32,
    seed: u64,
}

impl Gen {
    pub fn replay(seed: u64) -> Gen {
        Gen {
            rng: Pcg32::seeded(seed),
            seed,
        }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Probability in [0, 1] with occasional exact endpoints — the
    /// endpoints are where the paper's model degenerates (p=0 plain DNN,
    /// p=1 always-exit), so generators visit them deliberately.
    pub fn probability(&mut self) -> f64 {
        match self.rng.below(10) {
            0 => 0.0,
            1 => 1.0,
            _ => self.rng.f64(),
        }
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. Panics with the failing seed on the
/// first failure. `BRANCHYSERVE_PROP_SEED` pins the base seed;
/// `BRANCHYSERVE_PROP_CASES` overrides the case count (e.g. a nightly soak).
pub fn property<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    prop: F,
) {
    let base_seed = std::env::var("BRANCHYSERVE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_0000u64);
    let cases = std::env::var("BRANCHYSERVE_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);

    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::replay(seed);
            prop(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed:#x}):\n{msg}\n\
                 reproduce with Gen::replay({seed:#x})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        property("reflexivity", 50, |g| {
            let x = g.f64_in(-10.0, 10.0);
            assert_eq!(x, x);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let result = std::panic::catch_unwind(|| {
            property("always fails after threshold", 100, |g| {
                let v = g.usize_in(0, 100);
                assert!(v < 1000, "drawn {v}");
            });
        });
        assert!(result.is_ok(), "property should hold");

        let result = std::panic::catch_unwind(|| {
            property("fails", 100, |g| {
                let v = g.usize_in(0, 100);
                assert!(v < 50, "drawn {v}");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("reproduce"), "{msg}");
    }

    #[test]
    fn replay_is_deterministic() {
        let mut a = Gen::replay(99);
        let mut b = Gen::replay(99);
        for _ in 0..10 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn probability_hits_endpoints() {
        let mut g = Gen::replay(3);
        let draws: Vec<f64> = (0..200).map(|_| g.probability()).collect();
        assert!(draws.iter().any(|&p| p == 0.0));
        assert!(draws.iter().any(|&p| p == 1.0));
        assert!(draws.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }
}
