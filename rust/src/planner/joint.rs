//! Joint configuration search: branch placement × partition × precision.
//!
//! The paper optimizes one axis — the partition point — for a *fixed*
//! BranchyNet shipping f32 activations. But the shortest-path
//! equivalence the planner collapses into a sweep (see the module doc
//! of [`crate::planner`]) holds for every (branch-set, wire-encoding)
//! configuration independently: each candidate defines its own layered
//! graph over the *same* physical stages, and the layered graphs differ
//! only in the survival weights (branch geometry) and the `alpha_s`
//! transfer sizes (encoding). [`Planner::plan_joint`] therefore
//! searches the whole space at sweep cost:
//!
//! * **one shared [`StaticCore`]** — raw stage times, cloud suffix,
//!   branch-evaluation cost — validated once, reused by every
//!   candidate (no desc clone, no re-validation, no graph work);
//! * **one alpha table per encoding** (the core's own table is reused
//!   for its baked encoding) — `transfer_wire_bytes` through the same
//!   size map the codec ships with;
//! * **one `ExitView` per branch-set candidate** — derived by the same
//!   generalized fold `with_exit_probs` uses, so a candidate equal to
//!   the planner's live configuration prices **bit-identically** to
//!   [`Planner::plan_for`] (property-tested in
//!   `rust/tests/planner_equivalence.rs`);
//! * an **accuracy proxy floor**: a branch set's proxy is its final
//!   survival mass `Π (1 − p_k)` — the fraction of traffic that still
//!   reaches the full network's exit (the same quantity
//!   `ablation::branch_placement` reports). Sets below
//!   `min_accuracy_proxy` are pruned before any sweep runs, so the
//!   search can never "win" latency by exiting everything early.
//!
//! The exhaustive-oracle layer (`rust/tests/joint_optimality.rs`)
//! enumerates every (branch-set, encoding, split) triple on small nets
//! and holds the result bit-identical to the brute-force argmin.

use crate::model::{BranchDesc, BranchyNetDesc};
use crate::network::bandwidth::LinkModel;
use crate::network::encoding::WireEncoding;

use super::{ExitView, Planner};

/// The candidate space [`Planner::plan_joint`] searches: the cross
/// product of `branch_sets` × `encodings` × every split, filtered by
/// the accuracy-proxy floor.
///
/// Branch sets are given as [`BranchDesc`] lists (any order; each is
/// sorted by position internally, like `Planner::new` sorts the desc's
/// branches). An empty list is a valid candidate: the plain DNN with no
/// early exit (proxy 1.0).
#[derive(Debug, Clone, PartialEq)]
pub struct JointSearchSpace {
    /// Branch-set candidates, evaluated in order (first match wins
    /// exact latency ties).
    pub branch_sets: Vec<Vec<BranchDesc>>,
    /// Wire encodings to price each branch set under, evaluated in
    /// order within a branch set.
    pub encodings: Vec<WireEncoding>,
    /// Minimum final survival mass `Π (1 − p_k)` a branch set must
    /// keep to be considered. 0.0 admits everything; 1.0 admits only
    /// branch-free (or p = 0) sets.
    pub min_accuracy_proxy: f64,
}

impl JointSearchSpace {
    /// The degenerate space: exactly the planner's current branch set
    /// (live-view probabilities) under its baked wire encoding, no
    /// floor. `plan_joint` over this space returns `plan_for`'s split
    /// and expected time bit-for-bit — the joint search collapses to
    /// the paper's optimizer.
    pub fn restricted(planner: &Planner) -> JointSearchSpace {
        let probs = planner.exit_probs();
        let branch_set = planner
            .core
            .branch_positions
            .iter()
            .zip(&probs)
            .map(|(&after_stage, &exit_prob)| BranchDesc {
                after_stage,
                exit_prob,
            })
            .collect();
        JointSearchSpace {
            branch_sets: vec![branch_set],
            encodings: vec![planner.wire_encoding()],
            min_accuracy_proxy: 0.0,
        }
    }
}

/// One evaluated (branch-set, encoding) candidate: its optimal split
/// under the queried link, the expected time that split achieves, and
/// the branch set's accuracy proxy.
#[derive(Debug, Clone, PartialEq)]
pub struct JointCandidate {
    /// The candidate's branches, sorted by position.
    pub branch_set: Vec<BranchDesc>,
    pub encoding: WireEncoding,
    /// Optimal split for this candidate (0 = cloud-only, N = edge-only),
    /// under the same epsilon tie-break as [`Planner::plan_for`].
    pub split: usize,
    /// `E[T]` at that split — the model value, without the tie-break
    /// epsilon, exactly as `plan_for` reports it.
    pub expected_time: f64,
    /// Final survival mass `Π (1 − p_k)` of the branch set.
    pub accuracy_proxy: f64,
}

/// The joint search result: the latency-optimal surviving candidate
/// plus the full ranked table.
#[derive(Debug, Clone, PartialEq)]
pub struct JointPlan {
    /// Winning branch set, sorted by position.
    pub branch_set: Vec<BranchDesc>,
    /// Winning wire encoding.
    pub encoding: WireEncoding,
    /// Winning split (0 = cloud-only, N = edge-only).
    pub split: usize,
    /// `E[T]` of the winner at its split.
    pub expected_time: f64,
    /// Accuracy proxy of the winning branch set.
    pub accuracy_proxy: f64,
    /// Every surviving candidate, best first (stable on exact ties, so
    /// equal-latency candidates rank in enumeration order).
    pub ranked: Vec<JointCandidate>,
    /// How many branch-set candidates the accuracy floor rejected.
    pub pruned: usize,
}

impl JointPlan {
    /// The model description realized by the winner: `template` with
    /// its branches replaced by the winning branch set. What a
    /// deployment adopting this plan would serve.
    pub fn realized_desc(&self, template: &BranchyNetDesc) -> BranchyNetDesc {
        let mut desc = template.clone();
        desc.branches = self.branch_set.clone();
        desc
    }
}

/// Final survival mass of a branch set: `Π (1 − p_k)` folded in
/// position order — the identical left fold the survival chain uses, so
/// the proxy equals the planner's `S(N)` bit for bit.
pub fn accuracy_proxy(branch_set: &[BranchDesc]) -> f64 {
    let mut sorted: Vec<&BranchDesc> = branch_set.iter().collect();
    sorted.sort_by_key(|b| b.after_stage);
    let mut mass = 1.0f64;
    for b in sorted {
        mass *= 1.0 - b.exit_prob;
    }
    mass
}

impl Planner {
    /// Search (branch-set × wire-encoding × split) for the
    /// latency-optimal configuration under `link`.
    ///
    /// Cost: one O(N) alpha table per encoding not already baked into
    /// the core, one O(N·m) view derivation per branch set that clears
    /// the accuracy floor, and one O(N) sweep per surviving
    /// (branch-set, encoding) pair — the desc is validated zero times.
    /// Each sweep applies the same epsilon tie-break as
    /// [`Planner::plan_for`] (cut options carry `+epsilon`; exact ties
    /// resolve toward the edge), and across candidates exact
    /// expected-time ties resolve toward the earlier candidate in
    /// `space` order — so the result is deterministic for a fixed
    /// space.
    ///
    /// Panics on an empty space, a malformed branch set (position
    /// outside `1..N`, duplicate positions, probability outside
    /// `[0, 1]`), a `min_accuracy_proxy` outside `[0, 1]`, or when the
    /// floor prunes every candidate.
    pub fn plan_joint(&self, link: LinkModel, space: &JointSearchSpace) -> JointPlan {
        let core = &*self.core;
        let n = core.n;
        assert!(
            !space.branch_sets.is_empty(),
            "joint search space has no branch-set candidates"
        );
        assert!(
            !space.encodings.is_empty(),
            "joint search space has no encodings"
        );
        assert!(
            (0.0..=1.0).contains(&space.min_accuracy_proxy),
            "min_accuracy_proxy {} not in [0, 1]",
            space.min_accuracy_proxy
        );

        // One alpha table per encoding, shared across branch sets
        // (alpha is branch-independent). The core's own table *is* the
        // table for its baked encoding — reusing it keeps the
        // restricted search bit-identical to `plan_for`.
        let alphas: Vec<Vec<u64>> = space
            .encodings
            .iter()
            .map(|&enc| {
                if enc == core.wire_encoding {
                    core.alpha_bytes.clone()
                } else {
                    (0..n)
                        .map(|s| core.desc.transfer_wire_bytes(s, enc))
                        .collect()
                }
            })
            .collect();

        let mut ranked: Vec<JointCandidate> = Vec::new();
        let mut pruned = 0usize;
        for set in &space.branch_sets {
            // Sort by position (stable, like `Planner::new`) and check
            // the same structural invariants desc validation enforces —
            // without touching the desc.
            let mut branches: Vec<BranchDesc> = set.clone();
            branches.sort_by_key(|b| b.after_stage);
            for b in &branches {
                assert!(
                    b.after_stage >= 1 && b.after_stage < n,
                    "branch position {} outside 1..{n}",
                    b.after_stage
                );
            }
            for w in branches.windows(2) {
                assert_ne!(
                    w[0].after_stage, w[1].after_stage,
                    "duplicate branch position {}",
                    w[0].after_stage
                );
            }
            let positions: Vec<usize> = branches.iter().map(|b| b.after_stage).collect();
            let probs: Vec<f64> = branches.iter().map(|b| b.exit_prob).collect();
            let active_at: Vec<usize> = (0..=n)
                .map(|s| positions.partition_point(|&pos| pos < s))
                .collect();
            // The candidate's layered graph, collapsed: the same
            // survival-weighted folds `with_exit_probs` derives, over
            // the candidate's geometry.
            let view = ExitView::derive_for(core, &active_at, &probs);
            // S(N): the fraction of traffic still answered by the full
            // network — the accuracy proxy.
            let proxy = view.surv[n];
            if proxy < space.min_accuracy_proxy {
                pruned += 1;
                continue;
            }

            for (alpha, &encoding) in alphas.iter().zip(&space.encodings) {
                let (split, expected_time) = sweep(core, &view, alpha, link, self.epsilon);
                ranked.push(JointCandidate {
                    branch_set: branches.clone(),
                    encoding,
                    split,
                    expected_time,
                    accuracy_proxy: proxy,
                });
            }
        }
        assert!(
            !ranked.is_empty(),
            "accuracy floor {} pruned every branch-set candidate",
            space.min_accuracy_proxy
        );
        // Stable: exact ties keep enumeration order, so the search is
        // deterministic for a fixed space.
        ranked.sort_by(|a, b| a.expected_time.total_cmp(&b.expected_time));
        let best = ranked[0].clone();
        JointPlan {
            branch_set: best.branch_set,
            encoding: best.encoding,
            split: best.split,
            expected_time: best.expected_time,
            accuracy_proxy: best.accuracy_proxy,
            ranked,
            pruned,
        }
    }
}

/// The argmin sweep of `plan_with_epsilon`, parameterized by the
/// candidate's view and alpha table: same terms, same fold order, same
/// `<=` tie-break toward the larger split. Returns (split, model time).
fn sweep(
    core: &super::StaticCore,
    view: &ExitView,
    alpha: &[u64],
    link: LinkModel,
    epsilon: f64,
) -> (usize, f64) {
    let n = core.n;
    let mut best_split = 0usize;
    let mut best_model = f64::INFINITY;
    let mut best_decision = f64::INFINITY;
    for s in 0..=n {
        let mut model = view.edge_cost[s];
        if s < n {
            let surv = view.surv[s];
            if surv > 0.0 {
                model += surv * (link.transfer_time(alpha[s]) + core.cloud_suffix[s]);
            }
        }
        let decision = if s < n { model + epsilon } else { model };
        // `<=`: on an exact tie the larger split (more edge work) wins.
        if decision <= best_decision {
            best_decision = decision;
            best_model = model;
            best_split = s;
        }
    }
    (best_split, best_model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::profile::DelayProfile;

    fn branch(after_stage: usize, exit_prob: f64) -> BranchDesc {
        BranchDesc {
            after_stage,
            exit_prob,
        }
    }

    fn fixture(p: f64) -> (BranchyNetDesc, DelayProfile) {
        let desc = BranchyNetDesc {
            stage_names: (1..=5).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![57_600, 18_816, 25_088, 3_456, 8],
            input_bytes: 12_288,
            branches: vec![branch(1, p)],
        };
        let profile = DelayProfile::from_cloud_times(
            vec![1e-3, 2e-3, 1.5e-3, 8e-4, 2e-4],
            3e-4,
            100.0,
        );
        (desc, profile)
    }

    #[test]
    fn restricted_space_degenerates_to_plan_for() {
        let (desc, profile) = fixture(0.6);
        for paper in [true, false] {
            let planner = Planner::new(&desc, &profile, 1e-9, paper);
            for mbps in [0.05, 1.10, 5.85, 18.80, 500.0] {
                let link = LinkModel::new(mbps, 0.01);
                let fixed = planner.plan_for(link);
                let joint = planner.plan_joint(link, &JointSearchSpace::restricted(&planner));
                assert_eq!(joint.split, fixed.split_after, "mbps={mbps} paper={paper}");
                assert_eq!(
                    joint.expected_time.to_bits(),
                    fixed.expected_time_s.to_bits(),
                    "mbps={mbps} paper={paper}"
                );
                assert_eq!(joint.branch_set, desc.branches);
                assert_eq!(joint.encoding, WireEncoding::Raw);
                assert_eq!(joint.ranked.len(), 1);
                assert_eq!(joint.pruned, 0);
            }
        }
    }

    #[test]
    fn restricted_space_tracks_live_view_and_baked_encoding() {
        // After a p-update *and* an encoding re-bake, the restricted
        // space must describe the planner as it prices now — not as it
        // was constructed.
        let (desc, profile) = fixture(0.6);
        let planner = Planner::new(&desc, &profile, 1e-9, false).with_wire_encoding(WireEncoding::Q8);
        planner.set_exit_probs(&[0.25]);
        let space = JointSearchSpace::restricted(&planner);
        assert_eq!(space.branch_sets, vec![vec![branch(1, 0.25)]]);
        assert_eq!(space.encodings, vec![WireEncoding::Q8]);

        let link = LinkModel::new(1.10, 0.0);
        let fixed = planner.plan_for(link);
        let joint = planner.plan_joint(link, &space);
        assert_eq!(joint.split, fixed.split_after);
        assert_eq!(joint.expected_time.to_bits(), fixed.expected_time_s.to_bits());
    }

    #[test]
    fn quantized_encoding_wins_a_transfer_dominated_link() {
        // Same setup as the planner's compression-relocation test: raw
        // transfer is prohibitive, q4 makes the fast cloud reachable.
        // The joint search must discover that on its own.
        let desc = BranchyNetDesc {
            stage_names: vec!["s1".into(), "s2".into()],
            stage_out_bytes: vec![1_000_000, 8],
            input_bytes: 1_000_000,
            branches: vec![],
        };
        let profile = DelayProfile::from_cloud_times(vec![0.0005, 0.1], 0.0, 20.0);
        let link = LinkModel::new(1.0, 0.0);
        let planner = Planner::new(&desc, &profile, 1e-9, false);

        let space = JointSearchSpace {
            branch_sets: vec![vec![]],
            encodings: WireEncoding::ALL.to_vec(),
            min_accuracy_proxy: 0.0,
        };
        let joint = planner.plan_joint(link, &space);
        assert_eq!(joint.encoding, WireEncoding::Q4);
        assert_eq!(joint.split, 0, "q4 makes cloud-only optimal");
        assert_eq!(joint.accuracy_proxy, 1.0, "no branch: full accuracy");
        let fixed = planner.plan_for(link);
        assert!(joint.expected_time < fixed.expected_time_s);
        // The ranked table covers all three encodings, best first.
        assert_eq!(joint.ranked.len(), 3);
        for pair in joint.ranked.windows(2) {
            assert!(pair[0].expected_time <= pair[1].expected_time);
        }
    }

    #[test]
    fn accuracy_floor_prunes_before_latency_ranks() {
        // An aggressive early exit (p = 0.95) is the latency winner on
        // a slow link, but keeps only 5% of traffic for the full net.
        // With a 0.5 floor it must be pruned, not out-ranked.
        let (desc, profile) = fixture(0.6);
        let planner = Planner::new(&desc, &profile, 1e-9, true);
        let link = LinkModel::new(0.05, 0.0);
        let space = JointSearchSpace {
            branch_sets: vec![vec![branch(1, 0.95)], vec![branch(1, 0.4)]],
            encodings: vec![WireEncoding::Raw],
            min_accuracy_proxy: 0.5,
        };
        let joint = planner.plan_joint(link, &space);
        assert_eq!(joint.pruned, 1);
        assert_eq!(joint.ranked.len(), 1);
        assert_eq!(joint.branch_set, vec![branch(1, 0.4)]);
        assert!((joint.accuracy_proxy - 0.6).abs() < 1e-12);

        // Floor 0.0: nothing pruned, and the aggressive exit wins.
        let open = JointSearchSpace {
            min_accuracy_proxy: 0.0,
            ..space
        };
        let joint = planner.plan_joint(link, &open);
        assert_eq!(joint.pruned, 0);
        assert_eq!(joint.branch_set, vec![branch(1, 0.95)]);
    }

    #[test]
    fn exact_ties_rank_in_enumeration_order() {
        let (desc, profile) = fixture(0.6);
        let planner = Planner::new(&desc, &profile, 1e-9, true);
        let link = LinkModel::new(5.85, 0.0);
        // The same branch set twice: identical expected times; the
        // first enumeration must win and stay first in the table.
        let space = JointSearchSpace {
            branch_sets: vec![vec![branch(2, 0.5)], vec![branch(2, 0.5)]],
            encodings: vec![WireEncoding::Raw],
            min_accuracy_proxy: 0.0,
        };
        let a = planner.plan_joint(link, &space);
        let b = planner.plan_joint(link, &space);
        assert_eq!(a, b, "deterministic across runs");
        assert_eq!(a.ranked.len(), 2);
        assert_eq!(
            a.ranked[0].expected_time.to_bits(),
            a.ranked[1].expected_time.to_bits()
        );
    }

    #[test]
    fn accuracy_proxy_is_the_survival_left_fold() {
        let set = vec![branch(3, 0.3), branch(1, 0.5)];
        // Sorted by position: (1 - 0.5) then (1 - 0.3).
        assert_eq!(
            accuracy_proxy(&set).to_bits(),
            ((1.0f64 - 0.5) * (1.0 - 0.3)).to_bits()
        );
        assert_eq!(accuracy_proxy(&[]), 1.0);
    }

    #[test]
    fn realized_desc_swaps_branches_only() {
        let (desc, profile) = fixture(0.6);
        let planner = Planner::new(&desc, &profile, 1e-9, true);
        let space = JointSearchSpace {
            branch_sets: vec![vec![branch(2, 0.7)]],
            encodings: vec![WireEncoding::Raw],
            min_accuracy_proxy: 0.0,
        };
        let joint = planner.plan_joint(LinkModel::new(1.10, 0.0), &space);
        let realized = joint.realized_desc(&desc);
        assert_eq!(realized.branches, vec![branch(2, 0.7)]);
        assert_eq!(realized.stage_out_bytes, desc.stage_out_bytes);
        realized.validate().expect("realized desc must be servable");
    }

    #[test]
    #[should_panic(expected = "pruned every branch-set candidate")]
    fn all_pruned_panics() {
        let (desc, profile) = fixture(0.6);
        let planner = Planner::new(&desc, &profile, 1e-9, true);
        let space = JointSearchSpace {
            branch_sets: vec![vec![branch(1, 0.9)]],
            encodings: vec![WireEncoding::Raw],
            min_accuracy_proxy: 0.5,
        };
        let _ = planner.plan_joint(LinkModel::new(5.85, 0.0), &space);
    }

    #[test]
    #[should_panic(expected = "duplicate branch position")]
    fn duplicate_positions_panic() {
        let (desc, profile) = fixture(0.6);
        let planner = Planner::new(&desc, &profile, 1e-9, true);
        let space = JointSearchSpace {
            branch_sets: vec![vec![branch(2, 0.5), branch(2, 0.6)]],
            encodings: vec![WireEncoding::Raw],
            min_accuracy_proxy: 0.0,
        };
        let _ = planner.plan_joint(LinkModel::new(5.85, 0.0), &space);
    }
}
