//! The planner subsystem: one owner of "model + profile + epsilon +
//! strategy → plan", built for *continuous* replanning as the uplink
//! fluctuates (the on-demand co-inference regime Edgent argues for:
//! cheap re-optimization on every bandwidth sample, not a one-shot
//! solve).
//!
//! # Why a prefix-sum sweep solves the paper's shortest-path problem
//!
//! The paper reduces BranchyNet partitioning to a shortest `input →
//! output` path in `G'_BDNN` (Eqs. 7–8). The compact construction
//! (`partition::compact`) already observes that once a path cuts to the
//! cloud after stage `s`, no further decision exists — the remaining
//! cost is a constant for that cut. The [`Planner`] takes the final
//! step: it never builds a graph at all. For a split after stage `s`
//! (0 = cloud-only, N = edge-only), Eq. 5 generalized to any number of
//! branches is
//!
//! ```text
//! E[T(s)] =  A(s)  +  S(s) · ( alpha_s/B + rtt + C(s) )
//!
//! A(s) = Σ_{i≤s} S(before i) · t_i^e   [+ Σ_{b_j < s} S_j · t_b^e]
//! S(s) = Π_{b_j < s} (1 − p_j)            (survival at the cut, Eq. 4)
//! C(s) = Σ_{i>s} t_i^c                    (cloud suffix, Eq. 2)
//! ```
//!
//! Everything except `alpha_s/B + rtt` is **link-independent**:
//! `A(·)` is a survival-weighted prefix sum over edge stage times,
//! `C(·)` a suffix sum over cloud stage times, and `S(·)` the running
//! survival product — all computed once at construction in O(N·m) and
//! stored. A `plan_for(link)` query is then a pure O(N) arithmetic
//! sweep: evaluate `E[T(s)]` for every `s`, add the paper's epsilon
//! tie-breaker to the cut options (so exact ties resolve toward the
//! edge, exactly as the `(v*c, output)` epsilon link does in §V), and
//! take the argmin. No graph rebuild, no Dijkstra heap, no allocation
//! beyond the returned plan.
//!
//! The sweep reproduces [`crate::timing::Estimator::expected_time`]
//! operation-for-operation (same fold order), so the reported
//! `expected_time_s` is bit-identical to what the paper-faithful
//! oracle [`crate::partition::solver::solve_faithful`] reports for the
//! same split — property-tested in `rust/tests/planner_equivalence.rs`.
//!
//! On top of the sweep sit two replanning layers:
//!
//! * [`cache::PlanCache`] — plans memoized by *log-bucketed* bandwidth
//!   (default ~24 buckets per decade ≈ 10% quantization) with hit/miss
//!   counters, so a jittering-but-stable uplink costs a hash lookup;
//! * [`adaptive`] — the replan loop promoted out of
//!   `examples/adaptive_bandwidth.rs`: it consumes bandwidth estimates
//!   (e.g. `network::trace` through a `Channel`), applies hysteresis so
//!   the split doesn't flap between adjacent buckets, and drives
//!   [`crate::coordinator::Coordinator::set_plan`], which records plan
//!   switches in `coordinator::metrics`.

pub mod adaptive;
pub mod cache;

pub use adaptive::{AdaptiveConfig, AdaptiveHandle, AdaptivePlanner, ReplanState, ReplanStats};
pub use cache::PlanCache;

use std::sync::Arc;

use crate::config::settings::Strategy;
use crate::model::BranchyNetDesc;
use crate::network::bandwidth::LinkModel;
use crate::partition::plan::PartitionPlan;
use crate::timing::exitprob::ExitChain;
use crate::timing::profile::DelayProfile;

/// The immutable precomputed state shared by a planner and all its
/// [`Planner::fork`]s: everything below is a pure function of
/// (model, profile, mode), independent of both the link and epsilon.
#[derive(Debug)]
struct PlannerCore {
    desc: BranchyNetDesc,
    paper_mode: bool,
    n: usize,
    /// A(s): survival-weighted edge compute through stage s, plus (in
    /// serving mode) the survival-weighted branch-evaluation terms —
    /// folded in the same order as `Estimator::expected_time`.
    edge_cost: Vec<f64>,
    /// S(s): survival probability at a cut after stage s.
    surv: Vec<f64>,
    /// C(s): cloud time of stages s+1..=N.
    cloud_suffix: Vec<f64>,
    /// alpha_s: bytes transferred for a cut after stage s (s < N).
    alpha_bytes: Vec<u64>,
}

/// Precomputed link-independent planning state for one
/// (model, profile, epsilon, mode) tuple. Construction is O(N·m); each
/// [`Planner::plan_for`] is an O(N) sweep and each
/// [`Planner::expected_time`] query is O(1).
///
/// The prefix/suffix sums live behind an [`Arc`], so a fleet holding one
/// planner per link class pays the O(N·m) precompute once and
/// [`Planner::fork`]s it per class — each fork gets its own
/// [`PlanCache`] (plans are link-dependent; the sums are not). The
/// planner is `Send + Sync` and can be moved into a replan thread.
#[derive(Debug)]
pub struct Planner {
    core: Arc<PlannerCore>,
    epsilon: f64,
    cache: PlanCache,
}

impl Planner {
    /// Precompute all link-independent state. `paper_mode = true`
    /// reproduces Eq. 5 exactly (no branch-evaluation cost); `false` is
    /// the serving default — the same convention as
    /// [`crate::partition::solver::solve`].
    ///
    /// Panics on an invalid description/profile pair or a non-positive
    /// epsilon, like the estimator and the graph constructions do.
    pub fn new(
        desc: &BranchyNetDesc,
        profile: &DelayProfile,
        epsilon: f64,
        paper_mode: bool,
    ) -> Planner {
        desc.validate().expect("invalid BranchyNet description");
        profile
            .validate(desc.num_stages())
            .expect("profile/desc mismatch");
        assert!(
            epsilon > 0.0 && epsilon.is_finite(),
            "epsilon must be positive (paper §V)"
        );

        let n = desc.num_stages();
        let chain = ExitChain::new(desc);
        let include_branch_cost = !paper_mode;

        // Prefix sums of survival-weighted edge times. Incremental
        // left-fold, so edge_cost[s] carries exactly the partial sums
        // the estimator's edge loop would produce for split s.
        let mut edge_cost = vec![0.0f64; n + 1];
        for i in 1..=n {
            edge_cost[i] =
                edge_cost[i - 1] + chain.survival_before_stage(i) * profile.t_edge[i - 1];
        }
        // Branch-evaluation terms are folded *after* the edge sum
        // (mirroring the estimator's second loop) so the fp result
        // stays identical to a direct `expected_time` evaluation.
        if include_branch_cost {
            for s in 0..=n {
                let mut t = edge_cost[s];
                for (j, &pos) in chain.positions().iter().enumerate() {
                    if pos < s {
                        t += chain.survival_after(j) * profile.branch_t_edge;
                    }
                }
                edge_cost[s] = t;
            }
        }

        let surv: Vec<f64> = (0..=n).map(|s| chain.survival_at_split(s)).collect();

        // Suffix sums of cloud times, accumulated back-to-front exactly
        // like `timing::profile::CloudSuffix`.
        let mut cloud_suffix = vec![0.0f64; n + 1];
        for i in (0..n).rev() {
            cloud_suffix[i] = cloud_suffix[i + 1] + profile.t_cloud[i];
        }

        let alpha_bytes: Vec<u64> = (0..n).map(|s| desc.transfer_bytes(s)).collect();

        Planner {
            core: Arc::new(PlannerCore {
                desc: desc.clone(),
                paper_mode,
                n,
                edge_cost,
                surv,
                cloud_suffix,
                alpha_bytes,
            }),
            epsilon,
            cache: PlanCache::default(),
        }
    }

    /// A planner sharing this one's precomputed prefix/suffix sums (the
    /// `Arc`'d core) but with its own empty [`PlanCache`] and cache
    /// counters — one per link class in a serving fleet.
    pub fn fork(&self) -> Planner {
        Planner {
            core: self.core.clone(),
            epsilon: self.epsilon,
            cache: PlanCache::default(),
        }
    }

    /// True if `other` shares this planner's precomputed core (i.e. one
    /// is a [`Planner::fork`] of the other).
    pub fn shares_core_with(&self, other: &Planner) -> bool {
        Arc::ptr_eq(&self.core, &other.core)
    }

    pub fn desc(&self) -> &BranchyNetDesc {
        &self.core.desc
    }

    pub fn num_stages(&self) -> usize {
        self.core.n
    }

    pub fn epsilon(&self) -> f64 {
        self.epsilon
    }

    pub fn paper_mode(&self) -> bool {
        self.core.paper_mode
    }

    /// E[T_inf] for a split after stage `split` under `link` — O(1),
    /// and bit-identical to `Estimator::expected_time` for the same
    /// mode (same terms, same fold order).
    pub fn expected_time(&self, split: usize, link: LinkModel) -> f64 {
        let core = &*self.core;
        assert!(split <= core.n, "split {split} out of range 0..={}", core.n);
        let mut t = core.edge_cost[split];
        if split < core.n {
            let surv = core.surv[split];
            if surv > 0.0 {
                t += surv
                    * (link.transfer_time(core.alpha_bytes[split]) + core.cloud_suffix[split]);
            }
        }
        t
    }

    /// Solve for the optimal split under `link`: an O(N) sweep over the
    /// precomputed state. Cut options carry the epsilon tie-breaker
    /// (paper §V), so exact ties resolve toward keeping work on the
    /// edge — the same direction as the graph solvers and the
    /// brute-force oracle.
    pub fn plan_for(&self, link: LinkModel) -> PartitionPlan {
        self.plan_with_epsilon(link, self.epsilon)
    }

    /// [`Planner::plan_for`] with an explicit tie-breaker. The
    /// precomputed state is epsilon-independent, so epsilon-sensitivity
    /// sweeps (the ablation) pay one precompute and K O(N) sweeps
    /// instead of K full constructions. Bypasses the plan cache.
    pub fn plan_with_epsilon(&self, link: LinkModel, epsilon: f64) -> PartitionPlan {
        assert!(
            epsilon > 0.0 && epsilon.is_finite(),
            "epsilon must be positive (paper §V)"
        );
        let n = self.core.n;
        let mut best_split = 0usize;
        let mut best_model = f64::INFINITY;
        let mut best_decision = f64::INFINITY;
        for s in 0..=n {
            let model = self.expected_time(s, link);
            let decision = if s < n { model + epsilon } else { model };
            // `<=`: on an exact tie the larger split (more edge work) wins.
            if decision <= best_decision {
                best_decision = decision;
                best_model = model;
                best_split = s;
            }
        }
        PartitionPlan::from_split(best_split, best_model, Strategy::ShortestPath, &self.core.desc)
    }

    /// Like [`Planner::plan_for`], but memoized by quantized bandwidth:
    /// the link is log-bucketed (see [`PlanCache`]) and the plan is
    /// computed once per bucket, at the bucket's representative
    /// bandwidth. Repeated samples from a jittering-but-stable uplink
    /// are cache hits.
    pub fn plan_cached(&self, link: LinkModel) -> PartitionPlan {
        self.cache.get_or_insert_with(link, |rep| self.plan_for(rep))
    }

    /// The representative link `plan_cached` would actually solve for.
    pub fn cache_representative(&self, link: LinkModel) -> LinkModel {
        self.cache.representative(self.cache.key_for(link))
    }

    /// (hits, misses) of the plan cache.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic, BranchDesc};
    use crate::partition::brute;
    use crate::testing::property;
    use crate::timing::Estimator;

    fn fixture(p: f64) -> (BranchyNetDesc, DelayProfile) {
        let desc = BranchyNetDesc {
            stage_names: (1..=5).map(|i| format!("s{i}")).collect(),
            stage_out_bytes: vec![57_600, 18_816, 25_088, 3_456, 8],
            input_bytes: 12_288,
            branches: vec![BranchDesc {
                after_stage: 1,
                exit_prob: p,
            }],
        };
        let profile = DelayProfile::from_cloud_times(
            vec![1e-3, 2e-3, 1.5e-3, 8e-4, 2e-4],
            3e-4,
            100.0,
        );
        (desc, profile)
    }

    #[test]
    fn expected_time_is_bit_identical_to_estimator() {
        property("planner == estimator, bitwise", 150, |g| {
            let n = g.usize_in(1, 30);
            let desc = synthetic::random_desc(g, n, 4);
            let gamma = g.f64_in(1.0, 1000.0);
            let profile = synthetic::random_profile(g, &desc, gamma);
            let link = LinkModel::new(g.f64_in(0.05, 100.0), g.f64_in(0.0, 0.05));
            let paper = g.bool(0.5);

            let planner = Planner::new(&desc, &profile, 1e-9, paper);
            let est = Estimator::new(&desc, &profile, link);
            let est = if paper { est.paper_mode() } else { est };
            for s in 0..=n {
                let a = planner.expected_time(s, link);
                let b = est.expected_time(s);
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "split {s}: planner {a} vs estimator {b} (n={n}, paper={paper})"
                );
            }
        });
    }

    #[test]
    fn plan_for_matches_brute_force_within_epsilon() {
        const EPS: f64 = 1e-9;
        property("planner == brute force", 200, |g| {
            let n = g.usize_in(1, 24);
            let desc = synthetic::random_desc(g, n, 3);
            let profile = synthetic::random_profile(g, &desc, g.f64_in(1.0, 2000.0));
            let link = LinkModel::new(g.f64_in(0.05, 100.0), g.f64_in(0.0, 0.02));
            let paper = g.bool(0.5);

            let planner = Planner::new(&desc, &profile, EPS, paper);
            let plan = planner.plan_for(link);
            let est = Estimator::new(&desc, &profile, link);
            let est = if paper { est.paper_mode() } else { est };
            let bf = brute::solve(&est);
            assert!(
                (plan.expected_time_s - bf.expected_time_s).abs()
                    <= EPS + 1e-12 * bf.expected_time_s.max(1.0),
                "planner {} vs brute {} (n={n})",
                plan.expected_time_s,
                bf.expected_time_s
            );
            // The reported split must achieve the reported time exactly.
            assert_eq!(
                planner.expected_time(plan.split_after, link).to_bits(),
                plan.expected_time_s.to_bits()
            );
        });
    }

    #[test]
    fn p_one_tie_resolves_toward_edge() {
        // With p = 1 every cut at or past the branch costs exactly the
        // edge prefix through the branch; the epsilon tie-breaker must
        // keep the work on the edge (no spurious zero-cost cloud hop).
        let (desc, profile) = fixture(1.0);
        let planner = Planner::new(&desc, &profile, 1e-9, true);
        let plan = planner.plan_for(LinkModel::new(0.05, 0.0));
        assert!(plan.is_edge_only(5), "{plan:?}");
        assert_eq!(plan.expected_time_s.to_bits(), profile.t_edge[0].to_bits());
    }

    #[test]
    fn cached_plans_hit_within_a_bucket() {
        let (desc, profile) = fixture(0.5);
        let planner = Planner::new(&desc, &profile, 1e-9, false);

        let a = planner.plan_cached(LinkModel::new(5.85, 0.0));
        let (h, m) = planner.cache_stats();
        assert_eq!((h, m), (0, 1));

        // Same bucket (~10% wide): a hit, byte-identical plan.
        let b = planner.plan_cached(LinkModel::new(5.87, 0.0));
        let (h, m) = planner.cache_stats();
        assert_eq!((h, m), (1, 1));
        assert_eq!(a, b);

        // A different decade: a miss.
        let _ = planner.plan_cached(LinkModel::new(58.5, 0.0));
        let (h, m) = planner.cache_stats();
        assert_eq!((h, m), (1, 2));

        // The cached plan is the exact plan at the bucket representative.
        let rep = planner.cache_representative(LinkModel::new(5.87, 0.0));
        assert_eq!(b, planner.plan_for(rep));
    }

    #[test]
    fn fork_shares_sums_but_not_the_cache() {
        let (desc, profile) = fixture(0.5);
        let base = Planner::new(&desc, &profile, 1e-9, false);
        let fork = base.fork();
        assert!(base.shares_core_with(&fork));

        // Identical math, bit for bit.
        let link = LinkModel::new(5.85, 0.01);
        for s in 0..=base.num_stages() {
            assert_eq!(
                base.expected_time(s, link).to_bits(),
                fork.expected_time(s, link).to_bits()
            );
        }
        assert_eq!(base.plan_for(link), fork.plan_for(link));

        // Cache state is per-instance: a fork's lookups never touch the
        // base planner's counters.
        let _ = fork.plan_cached(link);
        let _ = fork.plan_cached(link);
        assert_eq!(fork.cache_stats(), (1, 1));
        assert_eq!(base.cache_stats(), (0, 0));

        // A fresh construction is not the same core.
        let other = Planner::new(&desc, &profile, 1e-9, false);
        assert!(!base.shares_core_with(&other));
    }

    #[test]
    fn serving_mode_adds_branch_cost() {
        let (desc, profile) = fixture(0.5);
        let link = LinkModel::new(5.85, 0.0);
        let paper = Planner::new(&desc, &profile, 1e-9, true);
        let serving = Planner::new(&desc, &profile, 1e-9, false);
        // Branch active only for splits >= 2.
        assert_eq!(
            paper.expected_time(1, link).to_bits(),
            serving.expected_time(1, link).to_bits()
        );
        assert!(serving.expected_time(2, link) > paper.expected_time(2, link));
    }
}
